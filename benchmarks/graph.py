"""Task-graph co-execution benchmark — the transformer-block case study,
emitted as ``BENCH_graph.json`` (a CI artifact alongside the timeline and
streaming benches).

Three sections per machine (DESIGN.md §10):

* **coexec** — the HEFT-style list schedule's makespan vs the best single
  device for the transformer-block DAG (grouped QKV/attention heads →
  projection → residual → grouped MLP).  Acceptance: DAG co-execution
  speedup > 1.0 — the width the DAG exposes is work the divisible GEMM
  domain cannot express.
* **list_vs_naive** — rank/EFT list scheduling vs the naive topo-order
  baseline (myopic fastest-device placement) on the same case study and on
  a fork-join diamond.
* **moe** — the MoE expert fan-out (``moe_stack``, dbrx/llama4 configs):
  each expert branch is an independent up/down chain, so DAG width scales
  with the expert count.  Acceptance: co-execution never regresses, and
  each machine shows real gain on at least one config (copy-bound expert
  slabs legitimately stay single-device).
* **ssm** — mamba2-style scan-chain stacks (``ssm_stack``): the serial
  state recurrence bounds DAG width, so co-execution must *never lose* to
  the best single device but is not required to gain — the section records
  the measured speedups and the scan-dominated critical-path fraction.
* **runtime** — a short stream of DAG jobs through ``CoExecutionRuntime``
  (deterministic virtual time) with a mid-stream throttle: per-task
  observations must re-fit the models and the dependency invariants must
  hold on every measured timeline.
* **straggler** — the mid-DAG straggler scenario (DESIGN.md §11): the
  fastest device throttles while a DAG job is in flight, planned with
  stale models.  Mid-graph re-planning (straggler detection → frontier
  freeze → pinned re-solve → ticket re-issue) must beat the locked-in
  plan by ≥ 1.10x measured makespan in BOTH deterministic virtual time
  and the real threaded StreamCore, with the dependency and per-link
  invariants clean across the splice point.
"""
from __future__ import annotations

import json
import os

from repro.core import (CoExecutionRuntime, TaskGraphDomain, diamond,
                        graph_finish_times, moe_stack, solve_list_schedule,
                        ssm_stack, transformer_block, truth_from_profiles,
                        verify_graph_dependencies, verify_stream_invariants)

from .common import MACHINES, emit, timed

OUT_PATH = os.environ.get("BENCH_GRAPH_PATH", "BENCH_graph.json")
CASE_STUDY = dict(d_model=4096, seq=16384, ff_mult=4, groups=8)
MOE_CASES = (("dbrx-132b", dict(layers=1, seq=8192, groups=4)),
             ("llama4-maverick-400b-a17b", dict(layers=2, seq=8192,
                                                groups=4)))
SSM_CASES = (("mamba2-2_7b-2x8k", "mamba2-2_7b", dict(layers=2, seq=8192)),
             ("mamba2-2_7b-1x16k", "mamba2-2_7b",
              dict(layers=1, seq=16384)))
RUNTIME_BLOCK = dict(d_model=1024, seq=2048, groups=4)
N_JOBS = 8
THROTTLE_AT = 3
THROTTLE = 3.0
STRAGGLER_THROTTLE = 6.0
STRAGGLER_SPEEDUP_FLOOR = 1.10
_THREAD_REPEATS = 3


def _best_single(devs, g, order) -> tuple[str, float]:
    singles = {d.name: max(graph_finish_times(
        devs, g.task_specs(), g.edge_indices(), [j] * len(g),
        topology="serialized", order=order)) for j, d in enumerate(devs)}
    name = min(singles, key=singles.get)
    return name, singles[name]


def coexec_rows(machine: str) -> dict:
    devs = MACHINES[machine]()
    g = transformer_block(**CASE_STUDY)
    res = solve_list_schedule(devs, g.task_specs(), g.edge_indices(),
                              bus="serialized")
    single_name, single_t = _best_single(devs, g, res.order)
    cp_ops, _ = g.critical_path()
    assignment = {}
    for i, a in enumerate(res.assign):
        assignment.setdefault(devs[a].name, []).append(g.nodes[i].name)
    return {
        "case_study": CASE_STUDY,
        "n_tasks": len(g),
        "total_tops": g.total_ops() / 1e12,
        "critical_path_ops_fraction": cp_ops / g.total_ops(),
        "coexec_makespan_s": res.makespan,
        "best_single_device": single_name,
        "best_single_makespan_s": single_t,
        "speedup_vs_best_single": single_t / res.makespan,
        "tasks_per_device": {k: len(v) for k, v in assignment.items()},
    }


def naive_rows(machine: str) -> dict:
    devs = MACHINES[machine]()
    out = {}
    for key, g in (("transformer_block", transformer_block(**CASE_STUDY)),
                   ("diamond", diamond(ops=5e11, bytes_per_edge=32e6,
                                       width=4))):
        smart = solve_list_schedule(devs, g.task_specs(), g.edge_indices(),
                                    bus="serialized")
        naive = solve_list_schedule(devs, g.task_specs(), g.edge_indices(),
                                    bus="serialized", priority="topo",
                                    refine=False)
        out[key] = {
            "list_makespan_s": smart.makespan,
            "naive_topo_makespan_s": naive.makespan,
            "list_vs_naive_speedup": naive.makespan / smart.makespan,
        }
    return out


def moe_rows(machine: str) -> dict:
    """MoE expert fan-out (``moe_stack``): each expert branch is an
    independent up/down chain, so the DAG width scales with the config's
    expert count — list-scheduled co-execution vs the best single device,
    per config-zoo MoE model."""
    devs = MACHINES[machine]()
    out = {}
    for cfg, kw in MOE_CASES:
        g = moe_stack(cfg, **kw)
        res = solve_list_schedule(devs, g.task_specs(), g.edge_indices(),
                                  bus="serialized")
        single_name, single_t = _best_single(devs, g, res.order)
        out[cfg] = {
            "params": kw,
            "n_tasks": len(g),
            "total_tops": g.total_ops() / 1e12,
            "coexec_makespan_s": res.makespan,
            "best_single_device": single_name,
            "best_single_makespan_s": single_t,
            "speedup_vs_best_single": single_t / res.makespan,
        }
    return out


def ssm_rows(machine: str) -> dict:
    """Scan-chain stacks (``ssm_stack``): the serial state recurrence
    caps the exploitable width, so the contract is never-loses rather
    than must-gain — the rows record the measured co-execution speedup
    and how much of the critical path the scan spine owns."""
    devs = MACHINES[machine]()
    out = {}
    for label, cfg, kw in SSM_CASES:
        g = ssm_stack(cfg, **kw)
        res = solve_list_schedule(devs, g.task_specs(), g.edge_indices(),
                                  bus="serialized")
        single_name, single_t = _best_single(devs, g, res.order)
        cp_ops, path = g.critical_path()
        out[label] = {
            "config": cfg,
            "params": kw,
            "n_tasks": len(g),
            "total_tops": g.total_ops() / 1e12,
            "critical_path_ops_fraction": cp_ops / g.total_ops(),
            "scan_nodes_on_critical_path": sum(
                1 for p in path if ".state" in p),
            "coexec_makespan_s": res.makespan,
            "best_single_device": single_name,
            "best_single_makespan_s": single_t,
            "ssm_vs_best_single_x": single_t / res.makespan,
        }
    return out


def runtime_rows(machine: str) -> dict:
    base = MACHINES[machine]()
    throttled_dev = max(base, key=lambda d: d.effective_speed).name
    truth = truth_from_profiles(
        base, lambda uid, name: THROTTLE
        if uid >= THROTTLE_AT and name == throttled_dev else 1.0)
    g = transformer_block(**RUNTIME_BLOCK)
    dom = TaskGraphDomain(MACHINES[machine](), bus="serialized",
                          dynamic=True)
    with CoExecutionRuntime(dom, executor="virtual", truth=truth,
                            feedback=True, max_inflight=1) as rt:
        jobs = rt.run_stream([g] * N_JOBS)
        stats = rt.stats()
        violations = list(verify_stream_invariants(jobs))
        for j in jobs:
            violations += verify_graph_dependencies(j.plan.schedule.spec,
                                                    j.measured)
    return {
        "n_jobs": N_JOBS,
        "throttled_device": throttled_dev,
        "throttle_at": THROTTLE_AT,
        "throttle_factor": THROTTLE,
        "observations": stats["observations"],
        "refit_epoch": stats["refit_epoch"],
        "total_makespan_s": stats["total_makespan_s"],
        "invariant_violations": violations,
    }


def straggler_rows(machine: str) -> dict:
    """Mid-DAG straggler lock-in vs live re-planning (DESIGN.md §11)."""
    base = MACHINES[machine]()
    target = max(base, key=lambda d: d.effective_speed).name
    truth = truth_from_profiles(
        base, lambda uid, name: STRAGGLER_THROTTLE if name == target
        else 1.0)
    g = transformer_block(**RUNTIME_BLOCK)

    def run(mode: str, replan: bool, ts: float):
        dom = TaskGraphDomain(MACHINES[machine](), bus="serialized",
                              dynamic=True)
        with CoExecutionRuntime(dom, executor=mode, truth=truth,
                                feedback=True, max_inflight=1,
                                time_scale=ts, replan=replan,
                                straggler_threshold=1.3) as rt:
            jobs = rt.run_stream([g], timeout=120)
            j = jobs[0]
            viol = list(verify_stream_invariants(jobs))
            viol += verify_graph_dependencies(j.final_spec, j.measured)
            return j.span, len(j.replans), viol

    out: dict = {"throttled_device": target,
                 "throttle_factor": STRAGGLER_THROTTLE,
                 "block": RUNTIME_BLOCK}
    locked, _, v_l = run("virtual", False, 1.0)
    spliced, n_rep, v_r = run("virtual", True, 1.0)
    out["virtual"] = {
        "locked_in_makespan_s": locked,
        "replanned_makespan_s": spliced,
        "replan_speedup": locked / spliced,
        "replans": n_rep,
        "invariant_violations": v_l + v_r,
    }
    # threaded: wall clock is noisy — report the median-speedup pair of
    # three back-to-back (locked, re-planned) runs
    ts = max(1.0, 0.25 / locked)
    pairs, viols, reps = [], [], 0
    for _ in range(_THREAD_REPEATS):
        l, _, va = run("threads", False, ts)
        r, n, vb = run("threads", True, ts)
        pairs.append((l, r))
        viols += va + vb
        reps += n
    l, r = sorted(pairs, key=lambda p: p[0] / p[1])[len(pairs) // 2]
    out["threads"] = {
        "locked_in_makespan_s": l,
        "replanned_makespan_s": r,
        "replan_speedup": l / r,
        "replans": reps,
        "time_scale": ts,
        "invariant_violations": viols,
    }
    return out


def main() -> None:
    report: dict = {"machines": {}}
    for machine in MACHINES:
        coexec, t_c = timed(coexec_rows, machine, repeats=1)
        naive, t_n = timed(naive_rows, machine, repeats=1)
        moe, t_m = timed(moe_rows, machine, repeats=1)
        ssm, t_ssm = timed(ssm_rows, machine, repeats=1)
        runtime, t_r = timed(runtime_rows, machine, repeats=1)
        straggler, t_s = timed(straggler_rows, machine, repeats=1)
        report["machines"][machine] = {"coexec": coexec,
                                       "list_vs_naive": naive,
                                       "moe": moe,
                                       "ssm": ssm,
                                       "runtime": runtime,
                                       "straggler": straggler}
        emit(f"graph_coexec_{machine}", t_c * 1e6,
             f"speedup={coexec['speedup_vs_best_single']:.3f}x "
             f"vs {coexec['best_single_device']}")
        emit(f"graph_moe_{machine}", t_m * 1e6,
             " ".join(f"{cfg}={row['speedup_vs_best_single']:.3f}x"
                      for cfg, row in moe.items()))
        emit(f"graph_ssm_{machine}", t_ssm * 1e6,
             " ".join(f"{label}={row['ssm_vs_best_single_x']:.3f}x"
                      for label, row in ssm.items()))
        emit(f"graph_list_vs_naive_{machine}", t_n * 1e6,
             "block="
             f"{naive['transformer_block']['list_vs_naive_speedup']:.3f}x "
             f"diamond={naive['diamond']['list_vs_naive_speedup']:.3f}x")
        emit(f"graph_runtime_{machine}", t_r * 1e6,
             f"obs={runtime['observations']} "
             f"refits={runtime['refit_epoch']} "
             f"viol={len(runtime['invariant_violations'])}")
        emit(f"graph_straggler_{machine}", t_s * 1e6,
             f"virtual={straggler['virtual']['replan_speedup']:.3f}x "
             f"threads={straggler['threads']['replan_speedup']:.3f}x "
             f"viol={len(straggler['virtual']['invariant_violations']) + len(straggler['threads']['invariant_violations'])}")

    report["acceptance"] = {
        "coexec_beats_best_single": all(
            m["coexec"]["speedup_vs_best_single"] > 1.0
            for m in report["machines"].values()),
        "list_no_worse_than_naive": all(
            row["list_vs_naive_speedup"] >= 1.0
            for m in report["machines"].values()
            for row in m["list_vs_naive"].values()),
        # dbrx-style experts (huge weight slabs, modest tokens/expert) can
        # be copy-bound: the solver rightly keeps them on one device
        # (speedup exactly 1.0).  Required: no MoE config ever regresses,
        # and every machine co-executes at least one config with real gain.
        "moe_coexec_never_loses": all(
            row["speedup_vs_best_single"] >= 1.0 - 1e-9
            for m in report["machines"].values()
            for row in m["moe"].values()),
        "moe_coexec_gains_somewhere": all(
            any(row["speedup_vs_best_single"] > 1.0
                for row in m["moe"].values())
            for m in report["machines"].values()),
        # the SSM scan spine is serial, so width (and hence gain) is
        # structurally limited: the contract is only that co-execution
        # never regresses below the best single device
        "ssm_coexec_never_loses": all(
            row["ssm_vs_best_single_x"] >= 1.0 - 1e-9
            for m in report["machines"].values()
            for row in m["ssm"].values()),
        "runtime_refits_on_per_task_obs": all(
            m["runtime"]["refit_epoch"] > 0
            for m in report["machines"].values()),
        "invariants_clean": all(
            not m["runtime"]["invariant_violations"]
            for m in report["machines"].values()),
        "replan_beats_locked_in_virtual": all(
            m["straggler"]["virtual"]["replan_speedup"]
            >= STRAGGLER_SPEEDUP_FLOOR
            for m in report["machines"].values()),
        "replan_beats_locked_in_threads": all(
            m["straggler"]["threads"]["replan_speedup"]
            >= STRAGGLER_SPEEDUP_FLOOR
            for m in report["machines"].values()),
        "replan_invariants_clean": all(
            not m["straggler"]["virtual"]["invariant_violations"]
            and not m["straggler"]["threads"]["invariant_violations"]
            for m in report["machines"].values()),
    }
    assert report["acceptance"]["coexec_beats_best_single"], \
        "DAG co-execution did not beat the best single device"
    assert report["acceptance"]["list_no_worse_than_naive"]
    assert report["acceptance"]["moe_coexec_never_loses"], \
        "MoE expert fan-out regressed vs the best single device"
    assert report["acceptance"]["moe_coexec_gains_somewhere"], \
        "no MoE config co-executed with real gain on some machine"
    assert report["acceptance"]["ssm_coexec_never_loses"], \
        "SSM scan-chain stack regressed vs the best single device"
    assert report["acceptance"]["runtime_refits_on_per_task_obs"]
    assert report["acceptance"]["invariants_clean"]
    assert report["acceptance"]["replan_beats_locked_in_virtual"], \
        "mid-graph re-planning under 1.10x vs locked-in (virtual)"
    assert report["acceptance"]["replan_beats_locked_in_threads"], \
        "mid-graph re-planning under 1.10x vs locked-in (threads)"
    assert report["acceptance"]["replan_invariants_clean"]

    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)
    emit("graph_report", 0.0, OUT_PATH)


if __name__ == "__main__":
    main()
