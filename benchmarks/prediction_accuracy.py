"""Paper Tables 4 & 5: per-device prediction error (%) and RMSE.

The predictor is trained by the profiling pass (simulated runners with
measurement noise), then evaluated on the six paper inputs against 'measured'
runs with independent noise — reproducing the paper's protocol on the
simulated testbed.
"""
from __future__ import annotations

import numpy as np

from repro.core import (DeviceProfile, HGemms, Profiler, fit_linear,
                        relative_error, rmse, simulated_runner)
import dataclasses

from .common import MACHINES, PAPER_INPUTS, emit, timed


def profile_machine(machine: str, *, noise: float = 0.02, seed: int = 0):
    """Run the paper's profiling pass: 30 squared matmuls per device."""
    truth = MACHINES[machine]()
    fitted = []
    for i, dev in enumerate(truth):
        sizes = (range(1000, 2001, 34) if dev.kind == "cpu"
                 else range(3000, 6001, 100))
        prof = Profiler(simulated_runner(dev, noise=noise, seed=seed + i),
                        repeats=5)
        prof.run(list(sizes)[:30])
        fitted.append(dataclasses.replace(dev, compute=prof.fit()))
    return truth, fitted


def run(machine: str, *, noise: float = 0.03, seed: int = 17):
    truth, fitted = profile_machine(machine, seed=seed)
    hg = HGemms(fitted)          # plans with the *fitted* models
    hg_truth = HGemms(truth)     # ground truth timings
    rng = np.random.default_rng(seed)
    errors: dict[str, list[float]] = {d.name: [] for d in truth}
    rows = []
    for name, (m, n, k) in PAPER_INPUTS.items():
        plan = hg.plan(m, n, k)
        row = {"input": name}
        for dev_t, dev_f, asg in zip(truth, fitted, plan.adapted.assignments):
            if asg.m == 0:
                continue
            pred_c = dev_f.compute(asg.ops)
            pred_y = dev_f.copy(asg.ops, n, k)
            meas_c = dev_t.compute(asg.ops) * (1 + noise * rng.standard_normal())
            meas_y = dev_t.copy(asg.ops, n, k) * (1 + 0.3 * noise * rng.standard_normal())
            e_glob = relative_error(pred_c + pred_y, meas_c + meas_y)
            e_c = relative_error(pred_c, meas_c)
            e_y = relative_error(pred_y, meas_y) if pred_y > 0 else 0.0
            row[dev_t.kind] = (e_glob, e_c, e_y)
            errors[dev_t.name].append(e_glob)
        rows.append(row)
    rmse_by_dev = {d.name: rmse(errors[d.name]) for d in truth
                   if errors[d.name]}
    return rows, rmse_by_dev


def main() -> None:
    for machine in ("mach1", "mach2"):
        (rows, rmses), dt = timed(run, machine)
        for row in rows:
            parts = []
            for kind in ("cpu", "gpu", "xpu"):
                if kind in row:
                    g, c, y = row[kind]
                    parts.append(f"{kind}={g:.1f}({c:.1f};{y:.1f})")
            emit(f"table4_pred_error_{machine}_{row['input']}",
                 dt * 1e6, " ".join(parts))
        for dev, r in rmses.items():
            emit(f"table5_rmse_{machine}_{dev}", dt * 1e6, f"rmse={r:.2f}%")


if __name__ == "__main__":
    main()
