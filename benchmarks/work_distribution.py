"""Paper Table 6: percentage of work distributed to CPU / GPU / XPU by the
optimizer, per input and machine."""
from __future__ import annotations

from .common import MACHINES, PAPER_INPUTS, emit, hgemms_for, timed


def run(machine: str):
    hg = hgemms_for(machine)
    out = []
    for name, (m, n, k) in PAPER_INPUTS.items():
        plan = hg.plan(m, n, k)
        ops = [a.ops for a in plan.adapted.assignments]
        total = sum(ops)
        out.append((name, [o / total * 100 for o in ops]))
    return out


def main() -> None:
    for machine in ("mach1", "mach2"):
        rows, dt = timed(run, machine)
        for name, shares in rows:
            cpu, gpu, xpu = shares
            emit(f"table6_distribution_{machine}_{name}", dt * 1e6,
                 f"cpu={cpu:.2f}% gpu={gpu:.2f}% xpu={xpu:.2f}%")


if __name__ == "__main__":
    main()
