"""Streaming co-execution runtime benchmark — sustained throughput and the
feedback-loop win under a mid-stream throttle, emitted as
``BENCH_streaming.json`` (a CI artifact alongside ``BENCH_timeline.json``).

The scenario (ISSUE 3 acceptance): a stream of ``N_JOBS`` >= 20 GEMM
workloads on ``paper_mach1`` with the XPU throttling ``THROTTLE``x at job
``THROTTLE_AT``.  Four configurations are compared in deterministic virtual
time (planning latency excluded, so the comparison is exact):

* ``static``   — plan once, never observe (the paper's per-application mode);
* ``feedback`` — the full plan→execute→observe→re-plan loop;
* each with plan-carry-over overlap on (carried link/device clocks) and
  off (global barrier between plans).

On a *uniform* stream the carry-over ratio is ~1: the solver balances every
plan, so the bottleneck device chains on itself in both modes.  The
``mixed`` section alternates the big GEMM with a thin one the degenerate
check assigns entirely to the host CPU — consecutive plans stress
*different* devices, and carried clocks hide the CPU job under the XPU
plan's tail (the overlap a barrier forbids).

A fifth, threaded section runs a shorter stream through the *real*
``StreamCore`` (persistent per-device workers, per-link ticket buses,
sleep-based ground-truth stages) and checks the measured timelines against
the per-link serialization / priority / copy-before-compute invariants
across plan boundaries.
"""
from __future__ import annotations

import json
import os

from repro.core import (CoExecutionRuntime, GemmDomain, GemmWorkload,
                        truth_from_profiles, verify_stream_invariants)

from .common import MACHINES, emit, timed

OUT_PATH = os.environ.get("BENCH_STREAMING_PATH", "BENCH_streaming.json")
MACHINE = "mach1"
SHAPE = (4096, 4096, 4096)
N_JOBS = 24
THROTTLE_AT = 8
THROTTLE = 3.0
THROTTLED_DEVICE = "2080ti-tensor"


def _truth():
    return truth_from_profiles(
        MACHINES[MACHINE](),
        lambda uid, name: THROTTLE
        if uid >= THROTTLE_AT and name == THROTTLED_DEVICE else 1.0)


def run_config(feedback: bool, carry: bool, *, executor: str = "virtual",
               n_jobs: int = N_JOBS, workloads=None) -> dict:
    domain = GemmDomain(MACHINES[MACHINE](), bus="serialized",
                        dynamic=feedback)
    with CoExecutionRuntime(domain, executor=executor, truth=_truth(),
                            feedback=feedback, carry_clocks=carry,
                            max_inflight=2) as rt:
        jobs = rt.run_stream(workloads or [GemmWorkload(*SHAPE)] * n_jobs)
        n_jobs = len(jobs)
        stats = rt.stats()
        violations = verify_stream_invariants(jobs)
    total = stats["total_makespan_s"]
    return {
        "feedback": feedback,
        "carry_clocks": carry,
        "executor": executor,
        "n_jobs": n_jobs,
        "total_makespan_s": total,
        "jobs_per_s": n_jobs / total if total else 0.0,
        "p50_job_latency_s": stats["p50_job_span_s"],
        "p95_job_latency_s": stats["p95_job_span_s"],
        "observations": stats["observations"],
        "refit_epoch": stats["refit_epoch"],
        "plan_cache": stats["plan_cache"],
        "invariant_violations": violations,
    }


def main() -> None:
    report: dict = {
        "scenario": {
            "machine": MACHINE, "shape": list(SHAPE), "n_jobs": N_JOBS,
            "throttle_at": THROTTLE_AT, "throttle_factor": THROTTLE,
            "throttled_device": THROTTLED_DEVICE,
        },
        "virtual": {},
    }
    for feedback in (False, True):
        for carry in (False, True):
            key = (("feedback" if feedback else "static")
                   + ("_carry" if carry else "_barrier"))
            row, dt = timed(run_config, feedback, carry, repeats=1)
            report["virtual"][key] = row
            emit(f"streaming_{key}", dt * 1e6,
                 f"total={row['total_makespan_s']*1e3:.2f}ms "
                 f"jobs_per_s={row['jobs_per_s']:.1f} "
                 f"viol={len(row['invariant_violations'])}")

    # mixed-shape stream: alternating big (XPU-tailed) and thin (all-CPU)
    # jobs — where plan-carry-over genuinely overlaps consecutive plans
    mixed = [GemmWorkload(*SHAPE) if i % 2 == 0
             else GemmWorkload(16, SHAPE[1], SHAPE[2])
             for i in range(N_JOBS)]
    report["mixed"] = {}
    for carry in (False, True):
        key = "carry" if carry else "barrier"
        row, dt = timed(run_config, False, carry, workloads=mixed, repeats=1)
        report["mixed"][key] = row
        emit(f"streaming_mixed_{key}", dt * 1e6,
             f"total={row['total_makespan_s']*1e3:.2f}ms "
             f"viol={len(row['invariant_violations'])}")

    v = report["virtual"]
    speedup = (v["static_carry"]["total_makespan_s"]
               / v["feedback_carry"]["total_makespan_s"])
    overlap_gain = (report["mixed"]["barrier"]["total_makespan_s"]
                    / report["mixed"]["carry"]["total_makespan_s"])
    report["feedback_speedup"] = speedup
    report["carry_over_speedup"] = overlap_gain
    # acceptance: the feedback loop beats the static plan, and every
    # measured timeline passed the cross-plan invariants
    report["acceptance"] = {
        "feedback_beats_static": v["feedback_carry"]["total_makespan_s"]
        < v["static_carry"]["total_makespan_s"],
        "carry_over_overlaps_mixed_stream": overlap_gain > 1.0,
        "invariants_clean": all(
            not row["invariant_violations"]
            for rows in (v, report["mixed"]) for row in rows.values()),
    }

    # real threaded runtime (persistent workers + ticket buses): shorter
    # stream, wall-clock sleeps — the invariants must hold on *measured*
    # intervals across plan boundaries
    threaded, dt = timed(run_config, True, True, executor="threads",
                         n_jobs=8, repeats=1)
    report["threaded"] = threaded
    report["acceptance"]["threaded_invariants_clean"] = \
        not threaded["invariant_violations"]
    emit("streaming_threaded", dt * 1e6,
         f"viol={len(threaded['invariant_violations'])} "
         f"obs={threaded['observations']}")

    assert report["acceptance"]["feedback_beats_static"], \
        "feedback loop did not beat the static plan"
    assert report["acceptance"]["invariants_clean"]
    assert report["acceptance"]["threaded_invariants_clean"]

    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)
    emit("streaming_report", 0.0,
         f"{OUT_PATH} feedback_speedup={speedup:.3f}x "
         f"carry_speedup={overlap_gain:.3f}x")


if __name__ == "__main__":
    main()
