"""Cluster-level scheduling benchmark — the §16 device-to-cluster story,
emitted as ``BENCH_cluster.json`` (a CI artifact alongside the graph and
scheduler benches).

One synthetic 2-host x 3-device stack (DESIGN.md §16): host ``h0`` holds a
40 TFLOP/s and a 30 TFLOP/s accelerator on one PCIe bus, host ``h1`` holds
a second 40 TFLOP/s part, and the hosts talk over a capped NIC that is an
order of magnitude slower than the intra-host links.  Three sections, each
hard-asserted and guarded by ``run.py --check``:

* **placement** — cluster-aware vs NIC-oblivious placement of a layered
  all-to-all DAG (every task of layer *l+1* reads every layer-*l* output,
  so any two-host placement pays real NIC crossings).  The baseline solves
  under ``topology.flatten()`` — same links and attach rows, hierarchy
  erased, i.e. exactly what the pre-§16 single-host planner saw — and its
  assignment is then priced under the *cluster* truth with
  ``graph_finish_times``.  Acceptance: the cluster-aware plan beats the
  flat plan's true cost by ≥ ``CLUSTER_AWARE_FLOOR``.
* **pareto** — the pluggable makespan/energy objective swept over
  ``PARETO_WEIGHTS`` (seconds-per-joule exchange rates) on powered device
  profiles (``idle_watts`` + ``joules_per_op``).  The free-assignment
  space is kept small enough that the solver enumerates it exhaustively,
  so each point is the true optimum of its score and the exchange
  argument guarantees monotonicity.  Acceptance: makespan non-decreasing
  and energy non-increasing along the sweep, the front is not degenerate
  (≥ 2 distinct energy levels), and the ``weight=0`` knob is
  bit-identical to ``objective=None`` (assign, order, makespan).
* **device_loss** — mid-stream device departure as a change-point
  (DESIGN.md §16): a job planned on all three devices meets a ground
  truth where ``h1.a`` runs ``DEAD_FACTOR`` x slow (a dying part).  The
  locked-in baseline rides the stale plan to completion; the rescue run
  calls ``CoExecutionRuntime.device_leave`` at 25% of the planned
  makespan — frontier freeze, pinned re-solve with the departed device
  banned, splice (reason ``"device-loss"``).  Acceptance: rescue beats
  locked-in by ≥ ``RESCUE_FLOOR``, the splice respects every DAG
  dependency, and no spliced task runs on the departed device.

All three sections are deterministic model quantities (virtual executor,
fixed profiles) — the ``*makespan_s`` / ``*speedup`` keys land in run.py's
regression guard buckets on purpose.
"""
from __future__ import annotations

import json
import math
import os

from repro.core import (BusTopology, Objective, TaskGraphDomain,
                        graph_finish_times, solve_list_schedule)
from repro.core.device_model import CopyModel, DeviceProfile, LinearTimeModel
from repro.core.graph import TaskGraph, TaskNode, verify_graph_dependencies
from repro.core.runtime import CoExecutionRuntime, truth_from_profiles

from .common import emit, timed

OUT_PATH = os.environ.get("BENCH_CLUSTER_PATH", "BENCH_cluster.json")

CLUSTER_AWARE_FLOOR = 1.10   # cluster-aware vs NIC-oblivious true cost
RESCUE_FLOOR = 1.10          # device-loss rescue vs locked-in plan
DEAD_FACTOR = 50.0           # how slow the dying device really runs
LOSS_AT_FRACTION = 0.25      # departure notice at 25% of planned makespan
PARETO_WEIGHTS = (0.0, 2e-5, 1e-4, 5e-4, 2e-3)   # seconds per joule

# the 2-host x 3-device stack (DESIGN.md §16): per-host PCIe/NVLink-class
# staging links, cross-host traffic through one capped NIC
STACK = (("h0", (("h0.a", 40.0), ("h0.b", 30.0))),
         ("h1", (("h1.a", 40.0),)))
# power table for the energy objective: h0 parts are fast but hungry,
# h1.a is the efficient part (so the knob has a real trade to make)
POWER = {"h0.a": (2.0, 4e-10), "h0.b": (1.5, 3e-10), "h1.a": (0.5, 0.8e-10)}


def _device(name: str, tflops: float, copy_bw: float, *,
            powered: bool = False) -> DeviceProfile:
    d = DeviceProfile(name, "gpu",
                      LinearTimeModel(2.0 / (tflops * 1e12), 1e-6),
                      CopyModel(copy_bw, dtype_size=2))
    if powered:
        idle_w, jpo = POWER[name]
        return d.with_power(idle_watts=idle_w, joules_per_op=jpo)
    return d


def _cluster(*, copy_bw: float = 15.75e9, nic_bw: float = 2e9,
             powered: bool = False
             ) -> tuple[list[DeviceProfile], BusTopology]:
    hosts = {hname: [_device(n, tf, copy_bw, powered=powered)
                     for n, tf in members]
             for hname, members in STACK}
    devs = [d for hname, _ in STACK for d in hosts[hname]]
    topo = BusTopology.cluster(hosts, nic_bandwidth_bytes_per_s=nic_bw,
                               nic_latency_s=1e-5)
    return devs, topo


def _layered(width: int, layers: int, ops: float, nbytes: float) -> TaskGraph:
    """All-to-all layered DAG: layer l+1 reads every layer-l output."""
    nodes, edges = [], []
    for l in range(layers):
        for w in range(width):
            nodes.append(TaskNode(f"l{l}.t{w}", ops, nbytes, nbytes))
            if l:
                edges.extend((f"l{l-1}.t{p}", f"l{l}.t{w}")
                             for p in range(width))
    return TaskGraph(tuple(nodes), tuple(edges))


def _chains(n_chains: int, n_stages: int, ops: float,
            nbytes: float) -> TaskGraph:
    nodes, edges = [], []
    for c in range(n_chains):
        for s in range(n_stages):
            nodes.append(TaskNode(f"c{c}.s{s}", ops, nbytes, nbytes))
            if s:
                edges.append((f"c{c}.s{s - 1}", f"c{c}.s{s}"))
    return TaskGraph(tuple(nodes), tuple(edges))


def _cross_host(topo: BusTopology, devs, edges, assign) -> int:
    host = [topo.host_index(d.name) for d in devs]
    return sum(1 for (u, v) in edges
               if host[assign[u]] != host[assign[v]])


# ---------------------------------------------------------------------------
# placement: cluster-aware vs NIC-oblivious flat
# ---------------------------------------------------------------------------


def placement_rows() -> dict:
    # NVLink-class staging (cheap intra-host moves) + a 1 GB/s NIC: the
    # flat planner happily spreads every layer across both hosts
    devs, topo = _cluster(copy_bw=100e9, nic_bw=1e9)
    g = _layered(width=4, layers=6, ops=1e10, nbytes=4e6)
    tasks, edges = g.task_specs(), g.edge_indices()
    aware = solve_list_schedule(devs, tasks, edges, bus=topo)
    flat = solve_list_schedule(devs, tasks, edges, bus=topo.flatten())
    # the flat plan's TRUE cost: its assignment priced under the cluster
    flat_truth = max(graph_finish_times(devs, tasks, edges, flat.assign,
                                        topology=topo, order=flat.order))
    return {
        "n_tasks": len(tasks),
        "n_edges": len(edges),
        "aware_makespan_s": aware.makespan,
        "flat_planned_makespan_s": flat.makespan,   # what flat believed
        "flat_truth_makespan_s": flat_truth,        # what it really costs
        "cluster_speedup": flat_truth / aware.makespan,
        "aware_cross_host_edges": _cross_host(topo, devs, edges,
                                              aware.assign),
        "flat_cross_host_edges": _cross_host(topo, devs, edges, flat.assign),
    }


# ---------------------------------------------------------------------------
# pareto: the makespan/energy objective knob
# ---------------------------------------------------------------------------


def pareto_rows() -> dict:
    devs, topo = _cluster(powered=True)
    g = _chains(n_chains=2, n_stages=4, ops=5e9, nbytes=1e5)
    tasks, edges = g.task_specs(), g.edge_indices()
    # 3^8 = 6561 assignments: below the raised exhaustive limit, so every
    # point is the true optimum of its score (monotonicity is then a
    # theorem, not a solver accident)
    solve = dict(bus=topo, exhaustive_limit=20000, max_evals=20001)
    points = []
    for w in PARETO_WEIGHTS:
        r = solve_list_schedule(devs, tasks, edges,
                                objective=Objective(energy_weight=w),
                                **solve)
        points.append({"energy_weight": w, "makespan_s": r.makespan,
                       "energy_j": r.energy_j,
                       "assign": list(r.assign)})
    base = solve_list_schedule(devs, tasks, edges, **solve)
    zero = solve_list_schedule(devs, tasks, edges,
                               objective=Objective(energy_weight=0.0),
                               **solve)
    return {
        "weights": list(PARETO_WEIGHTS),
        "points": points,
        "zero_weight_bit_identical": (
            list(base.assign) == list(zero.assign)
            and list(base.order) == list(zero.order)
            and base.makespan == zero.makespan),
        "energy_span_j": points[0]["energy_j"] - points[-1]["energy_j"],
    }


# ---------------------------------------------------------------------------
# device_loss: departure change-point vs locked-in plan
# ---------------------------------------------------------------------------


def device_loss_rows() -> dict:
    lost = "h1.a"
    base_devs, _ = _cluster()
    truth = truth_from_profiles(
        base_devs,
        lambda uid, name: DEAD_FACTOR if name == lost else 1.0)
    g = _chains(n_chains=6, n_stages=4, ops=5e9, nbytes=1e5)

    def run(rescue: bool):
        devs, topo = _cluster()
        dom = TaskGraphDomain(devs, bus=topo, dynamic=True)
        with CoExecutionRuntime(dom, executor="virtual", truth=truth,
                                feedback=False, max_inflight=1) as rt:
            job = rt.submit(g)
            job.wait(60)
            planned = job.plan.schedule.timeline.makespan
            if not rescue:
                return job.measured.makespan, planned, [], job
            at = LOSS_AT_FRACTION * planned
            recs = rt.device_leave(lost, at=at)
            return job.measured.makespan, planned, recs, job

    locked, planned, _, _ = run(rescue=False)
    rescued, _, recs, job = run(rescue=True)
    assert recs, "device_leave produced no rescue record"
    rec = recs[-1]
    violations = verify_graph_dependencies(rec.spec, job.measured)
    # no spliced (re-solved frontier) task may run on the departed device;
    # frozen tasks that started before the loss legitimately finish there
    spliced = set(rec.spliced)
    stray = sorted({e.task for e in job.measured.events
                    if e.task in spliced and e.device == lost})
    return {
        "lost_device": lost,
        "dead_factor": DEAD_FACTOR,
        "planned_makespan_s": planned,
        "loss_at_s": rec.at,
        "locked_in_makespan_s": locked,
        "rescued_makespan_s": rescued,
        "rescue_speedup": locked / rescued,
        "replan_reason": rec.reason,
        "frozen": len(rec.frozen),
        "spliced": len(rec.spliced),
        "invariant_violations": list(violations),
        "spliced_tasks_on_lost_device": stray,
    }


# ---------------------------------------------------------------------------


def main() -> None:
    report: dict = {
        "stack": {hname: [{"name": n, "tflops": tf,
                           "idle_watts": POWER[n][0],
                           "joules_per_op": POWER[n][1]}
                          for n, tf in members]
                  for hname, members in STACK},
    }
    placement, t = timed(placement_rows, repeats=1)
    report["placement"] = placement
    emit("cluster_placement", t * 1e6,
         f"x{placement['cluster_speedup']:.2f}_vs_flat")
    pareto, t = timed(pareto_rows, repeats=1)
    report["pareto"] = pareto
    emit("cluster_pareto", t * 1e6,
         f"span{pareto['energy_span_j']:.2f}J")
    loss, t = timed(device_loss_rows, repeats=1)
    report["device_loss"] = loss
    emit("cluster_device_loss", t * 1e6,
         f"x{loss['rescue_speedup']:.2f}_vs_locked_in")

    pts = pareto["points"]
    acceptance = {
        "cluster_aware_beats_flat": (
            placement["cluster_speedup"] >= CLUSTER_AWARE_FLOOR),
        "pareto_monotone": all(
            pts[i]["makespan_s"] <= pts[i + 1]["makespan_s"] + 1e-12
            and pts[i]["energy_j"] >= pts[i + 1]["energy_j"] - 1e-12
            for i in range(len(pts) - 1)),
        "pareto_settings": len(pts),
        "pareto_nondegenerate": pareto["energy_span_j"] > 1e-9,
        "zero_weight_bit_identical": pareto["zero_weight_bit_identical"],
        "rescue_beats_locked_in": (
            loss["rescue_speedup"] >= RESCUE_FLOOR),
        "rescue_reason_is_device_loss": loss["replan_reason"]
        == "device-loss",
        "rescue_respects_dependencies": not loss["invariant_violations"],
        "rescue_avoids_lost_device": not loss["spliced_tasks_on_lost_device"],
    }
    report["acceptance"] = acceptance
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)

    assert acceptance["cluster_aware_beats_flat"], (
        f"cluster-aware placement only "
        f"{placement['cluster_speedup']:.3f}x over flat "
        f"(floor {CLUSTER_AWARE_FLOOR})")
    assert acceptance["pareto_settings"] >= 3, "need >= 3 knob settings"
    assert acceptance["pareto_monotone"], (
        f"non-monotone makespan/energy front: {pts}")
    assert acceptance["pareto_nondegenerate"], (
        "energy knob is inert: every weight produced the same energy")
    assert acceptance["zero_weight_bit_identical"], (
        "Objective(0.0) diverged from objective=None")
    assert acceptance["rescue_beats_locked_in"], (
        f"device-loss rescue only {loss['rescue_speedup']:.3f}x over "
        f"locked-in (floor {RESCUE_FLOOR})")
    assert acceptance["rescue_reason_is_device_loss"], loss["replan_reason"]
    assert acceptance["rescue_respects_dependencies"], (
        loss["invariant_violations"])
    assert acceptance["rescue_avoids_lost_device"], (
        loss["spliced_tasks_on_lost_device"])


if __name__ == "__main__":
    main()
