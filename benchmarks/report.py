"""Render the §Dry-run and §Roofline tables for EXPERIMENTS.md from the
dry-run artifacts.  Usage: PYTHONPATH=src python -m benchmarks.report"""
from __future__ import annotations

import json
from pathlib import Path

from .roofline import DRYRUN_DIR, load_records, roofline_terms

ARCH_ORDER = ["stablelm-12b", "deepseek-67b", "minicpm3-4b", "qwen2-72b",
              "hymba-1_5b", "internvl2-26b", "llama4-maverick-400b-a17b",
              "dbrx-132b", "mamba2-2_7b", "musicgen-medium"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_bytes(x: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(x) < 1024:
            return f"{x:.1f}{unit}"
        x /= 1024
    return f"{x:.1f}PB"


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}µs"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def dryrun_table(recs: list[dict], mesh: str) -> str:
    lines = [
        "| arch | shape | compile | HLO GFLOP/dev | HBM GB/dev | "
        "AG GB/dev | AR GB/dev | RS/A2A/CP GB | peak mem/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    by = {(r["arch"], r["shape"]): r for r in recs if r["mesh"] == mesh}
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = by.get((arch, shape))
            if r is None:
                continue
            if r["status"] == "skip":
                lines.append(f"| {arch} | {shape} | skip | — | — | — | — | — "
                             f"| — |")
                continue
            c = r["collective_bytes_per_device"]
            rest = (c.get("reduce-scatter", 0) + c.get("all-to-all", 0)
                    + c.get("collective-permute", 0)) / 1e9
            lines.append(
                f"| {arch} | {shape} | {r['compile_s']:.0f}s "
                f"| {r['flops_per_device']/1e9:,.0f} "
                f"| {r['bytes_per_device']/1e9:,.1f} "
                f"| {c.get('all-gather',0)/1e9:.2f} "
                f"| {c.get('all-reduce',0)/1e9:.2f} "
                f"| {rest:.2f} "
                f"| {fmt_bytes(r['memory']['peak_bytes'])} |")
    return "\n".join(lines)


def roofline_table(recs: list[dict], mesh: str = "single") -> str:
    lines = [
        "| arch | shape | compute | memory (as-lowered / kernelized) | "
        "collective | dominant | MODEL/HLO | roofline frac (kern.) | "
        "what moves the bottleneck |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    by = {(r["arch"], r["shape"]): r for r in recs if r["mesh"] == mesh}
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = by.get((arch, shape))
            if r is None:
                continue
            if r["status"] == "skip":
                lines.append(f"| {arch} | {shape} | skip (full attention, "
                             f"524k) | | | | | | |")
                continue
            t = roofline_terms(r)
            hint = bottleneck_hint(t, r)
            lines.append(
                f"| {arch} | {shape} | {fmt_s(t['compute_s'])} "
                f"| {fmt_s(t['memory_s'])} / {fmt_s(t['memory_kernelized_s'])} "
                f"| {fmt_s(t['collective_s'])} "
                f"| **{t['dominant']}** | {t['useful_flops_ratio']:.2f} "
                f"| {t['roofline_fraction']:.2f} "
                f"({t['roofline_fraction_kernelized']:.2f}) | {hint} |")
    return "\n".join(lines)


def bottleneck_hint(t: dict, r: dict) -> str:
    if t["dominant"] == "compute":
        if t["useful_flops_ratio"] < 0.7:
            return ("compute-bound but only "
                    f"{t['useful_flops_ratio']:.0%} useful — reduce remat / "
                    "loss-scan recompute")
        return "near-roofline; bigger per-chip tiles / fp8 would move it"
    if t["dominant"] == "memory":
        return ("HBM-bound: fuse/flash the biggest elementwise chains, "
                "raise arithmetic intensity (batch more tokens per weight "
                "load)")
    c = r["collective_bytes_per_device"]
    worst = max(c, key=c.get)
    return f"collective-bound ({worst}): reshard to cut {worst} volume"


def main() -> None:
    recs = load_records()
    print("## §Dry-run — single pod (16×16 = 256 chips)\n")
    print(dryrun_table(recs, "single"))
    print("\n## §Dry-run — multi-pod (2×16×16 = 512 chips)\n")
    print(dryrun_table(recs, "multi"))
    print("\n## §Roofline — single pod\n")
    print(roofline_table(recs, "single"))
    ok = sum(1 for r in recs if r["status"] == "ok")
    sk = sum(1 for r in recs if r["status"] == "skip")
    er = sum(1 for r in recs if r["status"] not in ("ok", "skip"))
    print(f"\ncells: {ok} ok, {sk} skip (per-assignment long_500k rule), "
          f"{er} error")


if __name__ == "__main__":
    main()
