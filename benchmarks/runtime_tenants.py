"""Multi-tenant runtime benchmark — weighted-fair, SLO-aware admission with
priority preemption vs plain FIFO on one shared core, emitted as
``BENCH_runtime.json`` (a CI artifact alongside the other BENCH reports).

The scenario (DESIGN.md §13): two tenants share one virtual-time runtime on
``paper_mach1`` —

* ``batch``   — weight 1, batch tier: bursts of transformer-block DAGs
  (a backlog burst at t=0 and a second burst mid-stream);
* ``latency`` — weight 4, latency tier: small diamond DAGs arriving
  open-loop throughout the busy period.

The same arrival schedule runs under two admission configurations:

* ``fifo``         — submission order, no preemption (the pre-§13 queue);
* ``fair_preempt`` — SFQ weighted-fair order within strict tier priority,
  plus priority preemption (a latency arrival revokes the in-flight batch
  victim's not-yet-started tickets and splices its re-solved frontier).

Everything runs in deterministic virtual time, so the per-tier latency
percentiles are exact model quantities, not wall-clock noise.  Acceptance
(asserted): fair+preempt beats FIFO on latency-tier p99 by >= 1.2x, at
least one preemption splice actually happened, an infeasible-deadline job
is rejected at admission (and leaves no trace on the shared timeline), and
the cross-plan stream invariants hold in every configuration.
"""
from __future__ import annotations

import json
import os

from repro.core import (AdmissionRejected, CoExecutionRuntime, QoS,
                        TIER_LATENCY, TaskGraphDomain, diamond,
                        transformer_block, truth_from_profiles,
                        verify_stream_invariants)

from .common import MACHINES, emit, timed

OUT_PATH = os.environ.get("BENCH_RUNTIME_PATH", "BENCH_runtime.json")
MACHINE = "mach1"
N_BATCH = 10          # transformer blocks across two bursts
N_LATENCY = 8         # open-loop latency-tier arrivals
LATENCY_WEIGHT = 4.0
P99_TARGET = 1.2      # acceptance floor for the latency-tier p99 speedup


def _block():
    return transformer_block(d_model=2048, seq=4096, groups=4)


def _probe_block_makespan() -> float:
    """One block's solo makespan — the deterministic unit the arrival
    schedule is expressed in (model seconds, machine-independent)."""
    dom = TaskGraphDomain(MACHINES[MACHINE](), bus="serialized",
                          dynamic=True)
    with CoExecutionRuntime(dom, executor="virtual",
                            truth=truth_from_profiles(MACHINES[MACHINE]()),
                            max_inflight=1) as rt:
        return rt.run_stream([_block()])[0].measured.makespan


def _schedule(M: float):
    """The open-loop arrival schedule: (arrival, tenant, workload) tuples
    in arrival order — bursty batch traffic with latency-tier arrivals
    landing inside the busy period."""
    rows = []
    for i in range(N_BATCH):
        # burst 1: 6 jobs at t=0; burst 2: the rest at t = 4 blocks
        rows.append((0.0 if i < 6 else 4.0 * M, "batch", _block()))
    for i in range(N_LATENCY):
        rows.append(((0.5 + 0.9 * i) * M, "latency",
                     diamond(ops=2e9, width=3)))
    rows.sort(key=lambda r: r[0])
    return rows


def run_config(admission: str, preempt: bool, M: float) -> dict:
    machine = MACHINES[MACHINE]
    rt = CoExecutionRuntime(None, executor="virtual",
                            truth=truth_from_profiles(machine()),
                            feedback=True, max_inflight=2,
                            admission=admission, preempt=preempt)
    try:
        tenants = {
            "batch": rt.register("batch",
                                 TaskGraphDomain(machine(),
                                                 bus="serialized",
                                                 dynamic=True),
                                 QoS(weight=1.0)),
            "latency": rt.register("latency",
                                   TaskGraphDomain(machine(),
                                                   bus="serialized",
                                                   dynamic=True),
                                   QoS(weight=LATENCY_WEIGHT,
                                       tier=TIER_LATENCY)),
        }
        rt.pause_admission()
        for arrival, name, wl in _schedule(M):
            tenants[name].submit(wl, arrival=arrival)
        # one impossible SLO: predicted completion can never fit 1 us —
        # admission must bounce it before a single ticket is issued
        doomed = tenants["latency"].submit(diamond(ops=2e9, width=3),
                                           arrival=0.6 * M,
                                           deadline_s=1e-6)
        rt.resume_admission()
        rt.drain()
        jobs = list(rt.jobs)
        stats = rt.stats()
        violations = verify_stream_invariants(jobs)
    finally:
        rt.shutdown()
    done = [j for j in jobs if j.done and j.error is None]
    assert doomed.rejected and isinstance(doomed.error, AdmissionRejected)
    assert doomed.measured is None and doomed.planned is None
    assert len(done) == N_BATCH + N_LATENCY, \
        f"{len(done)} jobs finished, expected {N_BATCH + N_LATENCY}"
    preempt_splices = sum(1 for j in jobs for r in j.replans
                          if r.reason == "preempt")
    return {
        "admission": admission,
        "preempt": preempt,
        "total_makespan_s": stats["total_makespan_s"],
        "rejected": stats["rejected"],
        "preempt_splices": preempt_splices,
        "invariant_violations": violations,
        "tiers": {
            name: {
                "jobs_done": t["jobs_done"],
                "p50_latency_s": t["p50_latency_s"],
                "p95_latency_s": t["p95_latency_s"],
                "p99_latency_s": t["p99_latency_s"],
            } for name, t in stats["tenants"].items()
        },
    }


def main() -> None:
    M = _probe_block_makespan()
    report: dict = {
        "scenario": {
            "machine": MACHINE, "n_batch": N_BATCH,
            "n_latency": N_LATENCY, "latency_weight": LATENCY_WEIGHT,
            "block_makespan_s": M,
        },
    }
    for key, (admission, preempt) in (
            ("fifo", ("fifo", False)),
            ("fair_preempt", ("fair", True))):
        row, dt = timed(run_config, admission, preempt, M, repeats=1)
        report[key] = row
        lat = row["tiers"]["latency"]
        emit(f"runtime_tenants_{key}", dt * 1e6,
             f"lat_p99={lat['p99_latency_s']*1e3:.2f}ms "
             f"splices={row['preempt_splices']} "
             f"viol={len(row['invariant_violations'])}")

    fifo = report["fifo"]["tiers"]["latency"]
    fair = report["fair_preempt"]["tiers"]["latency"]
    report["latency_p50_speedup"] = (fifo["p50_latency_s"]
                                     / fair["p50_latency_s"])
    report["latency_p99_speedup"] = (fifo["p99_latency_s"]
                                     / fair["p99_latency_s"])
    report["acceptance"] = {
        "latency_p99_speedup_ge_1p2":
            report["latency_p99_speedup"] >= P99_TARGET,
        "preemption_exercised":
            report["fair_preempt"]["preempt_splices"] >= 1,
        "infeasible_deadline_rejected": all(
            report[k]["rejected"] == 1 for k in ("fifo", "fair_preempt")),
        "invariants_clean": all(
            not report[k]["invariant_violations"]
            for k in ("fifo", "fair_preempt")),
    }
    assert report["acceptance"]["latency_p99_speedup_ge_1p2"], \
        (f"fair+preempt latency p99 speedup "
         f"{report['latency_p99_speedup']:.3f}x < {P99_TARGET}x")
    assert report["acceptance"]["preemption_exercised"], \
        "no preemption splice happened in the fair_preempt run"
    assert report["acceptance"]["infeasible_deadline_rejected"]
    assert report["acceptance"]["invariants_clean"]

    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)
    emit("runtime_tenants_report", 0.0,
         f"{OUT_PATH} p99_speedup={report['latency_p99_speedup']:.3f}x "
         f"p50_speedup={report['latency_p50_speedup']:.3f}x")


if __name__ == "__main__":
    main()
