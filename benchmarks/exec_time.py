"""Paper Figures 3 & 4: absolute execution time per input (hgemms vs each
standalone device), plus a real-numerics small-scale co-execution run that
validates C == A@B through the full POAS pipeline."""
from __future__ import annotations

import numpy as np

from .common import PAPER_INPUTS, emit, hgemms_for, timed


def run(machine: str):
    hg = hgemms_for(machine)
    rows = []
    for name, (m, n, k) in PAPER_INPUTS.items():
        plan = hg.plan(m, n, k)
        rows.append((name, plan.schedule.timeline.makespan))
    return rows


def real_numerics(machine: str):
    """Small real matmul through the full pipeline (numerics check)."""
    hg = hgemms_for(machine)
    rng = np.random.default_rng(0)
    a = rng.standard_normal((512, 256)).astype(np.float32)
    b = rng.standard_normal((256, 384)).astype(np.float32)
    c, rep = hg.execute(a, b)
    err = float(np.max(np.abs(c - a @ b)))
    return err, rep.wall_seconds


def main() -> None:
    for machine in ("mach1", "mach2"):
        rows, dt = timed(run, machine)
        for name, t in rows:
            emit(f"fig34_exec_time_{machine}_{name}", dt * 1e6,
                 f"coexec_time_s={t:.3f}")
        err, wall = real_numerics(machine)
        emit(f"fig34_real_numerics_{machine}", wall * 1e6,
             f"max_abs_err={err:.2e}")


if __name__ == "__main__":
    main()
