"""Scheduler-throughput benchmark — the planner itself as the hot path,
emitted as ``BENCH_scheduler.json`` (a CI artifact alongside the graph
bench).

Two sections (DESIGN.md §12), on ``mach2`` (the 3-device heterogeneous
testbed — solver cost scales with graph size and device count, not with
which machine's timeline the plan describes):

* **throughput** — end-to-end EFT list-schedule placement (``refine=False``)
  at three DAG sizes (a 35-node transformer block, a ~300-node and a
  ~3000-node ``transformer_stack`` derived from the stablelm-12b config),
  against the pre-PR from-scratch baseline that re-simulated the whole
  placed prefix for every (task, device) candidate.  The baseline is
  fully re-measured up to ~400 nodes; at ~3000 nodes it is estimated by
  timing every ``SCRATCH_STRIDE``-th placement position with the real
  full-prefix pricing loop and scaling by the stride (per-position cost
  grows linearly with position, so a uniform stride is an unbiased
  sample) — flagged ``scratch_estimated``.  Acceptance: incremental
  placement ≥ 10x the from-scratch baseline at ≥ 300 nodes, and the
  incremental engine's finish times byte-identical to
  ``graph_finish_times`` at every size (where the baseline is fully
  measured, the placement vector must match exactly too; where sampled,
  every sampled position's argmin must match).
* **partial_resolve** — the PR-5 re-planning path: 90% of the order
  pinned with ``ext`` carrying the already-committed finish times, the
  remainder re-solved with ``seed_assign`` + descent refinement
  (``max_evals=80``) through a per-size ``SolveContextCache`` (the
  runtime holds one per job, so the warm path is what a rescue pays).
  Latencies are median/p95/best over >= 5 repeats after a cache-filling
  warmup (``resolve_ms``/``resolve_p95_ms``/``resolve_best_ms`` with
  refinement, ``resolve_eft_*`` EFT-only; ``*_best_ms`` — the floor over
  repeats — is what CI's latency guard gates, since ambient runner
  contention only ever adds time); the reported
  finish times must equal a from-scratch ``graph_finish_times`` replay.
  Quality contract (DESIGN.md §14, gated here): the refined makespan
  never exceeds the EFT seed's, and pruned descent stays within 2% of
  the full-sweep (``prune=False``) descent.  Latency gates: refined
  best-of-repeats <= 30 ms at ~3000 nodes, >= 8x the pre-§14 223 ms
  baseline — which ``common.timed`` measured as a min-of-repeats too,
  so floor-vs-floor is the like-for-like comparison (the median/p95
  columns are the distribution story this PR adds).  The
  EFT-only 10 ms target is reported, not gated: at this size the exact
  placement must re-simulate ~60-position suffixes for the ~90 winning
  host-stage flips the sweep adopts (DESIGN.md §12's staging semantics),
  which floors the honest bit-identical path near ~16 ms.

* **hierarchical** — template-tiled whole-model solves (DESIGN.md §15)
  at ~300 / ~3000 / ~30000 nodes: ``detect_templates`` +
  ``solve_hierarchical`` timed end to end per repeat (detection never
  amortized), template cache warm after the warmup call — the
  production steady state, since the cache is process-wide and shared
  across jobs/tenants.  Quality contract, hard-asserted per size: the
  reported finish times byte-match the engine's from-scratch simulation
  of the stitched assignment, the makespan never loses to the best
  all-one-device schedule, and where flat EFT is still tractable
  (≤ ``HIER_FLAT_MAX`` nodes) the tiled makespan stays within
  ``HIER_QUALITY_X`` of it.  Latency gates: 30k-node solve median
  ≤ 300 ms (acceptance boolean; hard fail at the 1.5x noise margin;
  run.py's 15% guard gates ``hier_best_ms``), and per-node cost at 30k
  within 2x of the 3040-node per-node cost (near-linearity).

``--profile`` dumps a cProfile of one warm refined re-solve at the
largest size (``bench_resolve.prof``) for future hot-path work.

Wall-clock keys (``plans_per_s``, ``*_ms``, ``incremental_vs_scratch_x``)
are named to stay outside the regression guard's speedup/makespan
patterns; the deterministic model quantities (``eft_makespan_s``,
``partial_makespan_s``) are guarded.
"""
from __future__ import annotations

import json
import math
import os
import time

from repro.core import (BusTopology, GraphSimContext, GraphSimState,
                        TemplatePlanCache, detect_templates,
                        graph_finish_times, solve_hierarchical,
                        solve_list_schedule, transformer_block,
                        transformer_stack)
from repro.core.optimize import _EPS, SolveContextCache

from .common import MACHINES, emit, timed, timed_quantiles

OUT_PATH = os.environ.get("BENCH_SCHEDULER_PATH", "BENCH_scheduler.json")
MACHINE = "mach2"
SIZES = (
    ("block35", dict(kind="block", d_model=4096, seq=16384, ff_mult=4,
                     groups=8)),
    ("stack304", dict(kind="stack", config="stablelm-12b", layers=4,
                      microbatches=4, groups=4)),
    ("stack3040", dict(kind="stack", config="stablelm-12b", layers=10,
                       microbatches=16, groups=4)),
)
HIER_SIZES = SIZES[1:] + (
    ("stack30k", dict(kind="stack", config="stablelm-12b", layers=100,
                      microbatches=16, groups=4)),
)
HIER_FLAT_MAX = 4000     # measure the flat reference up to this size
HIER_MS_GATE_30K = 300.0   # full tiled solve (detect + stitch), median
HIER_NOISE_X = 1.5         # same gross-regression backstop as the resolve
                           # gate; the precise guard is run.py's latency
                           # gate on hier_best_ms
HIER_QUALITY_X = 1.05      # tiled makespan within 5% of flat EFT where
                           # flat is still tractable (it currently *beats*
                           # flat: templates are descent-refined once and
                           # reused, flat EFT is greedy)
HIER_LINEARITY_X = 2.0     # per-node cost at 30k within 2x of 3040's
SCRATCH_FULL_MAX = 400   # fully re-measure the baseline up to this size
SCRATCH_STRIDE = 100     # sampled baseline positions beyond that
PIN_FRACTION = 0.9
RESOLVE_EVALS = 80
THROUGHPUT_FLOOR = 10.0  # required incremental-vs-scratch x at >=300 nodes
RESOLVE_MS_GATE_3000 = 30.0     # refined re-solve, best-of-repeats (§14)
RESOLVE_NOISE_X = 1.5           # hard-fail margin over the gate: a gross-
                                # regression backstop only — the precise
                                # 15% guard is run.py's latency gate, and a
                                # noisy shared runner (transient 1.3x wall-
                                # clock swings observed) must not fail the
                                # whole section on a clean change
RESOLVE_BASELINE_MS_3000 = 223.48  # pre-§14 refined latency (PR-7 snapshot)
RESOLVE_EFT_TARGET_MS = 10.0    # EFT-only aspiration — reported, not gated
PRUNE_QUALITY_X = 1.02          # pruned descent within 2% of full sweep


def _build(spec: dict):
    spec = dict(spec)
    kind = spec.pop("kind")
    if kind == "block":
        return transformer_block(**spec)
    return transformer_stack(spec.pop("config"), **spec)


def _scratch_price(devs, tasks, edges, topo, order, assign, pos, i):
    """One pre-PR candidate round: price task ``i`` on every device by
    re-simulating the whole placed prefix, return the EFT argmin."""
    prefix = order[: pos + 1]
    best_j, best_t = 0, math.inf
    for j in range(len(devs)):
        assign[i] = j
        t = graph_finish_times(devs, tasks, edges, assign, topology=topo,
                               order=prefix)[i]
        if t < best_t - _EPS:
            best_j, best_t = j, t
    return best_j


def _eft_scratch(devs, tasks, edges, topo, order):
    """The pre-PR placement loop: full prefix re-simulation per candidate."""
    assign = [-1] * len(tasks)
    for pos, i in enumerate(order):
        assign[i] = _scratch_price(devs, tasks, edges, topo, order, assign,
                                   pos, i)
    return assign


def _eft_scratch_sampled(devs, tasks, edges, topo, order, ref_assign,
                         stride):
    """Estimate the from-scratch baseline's runtime by timing every
    ``stride``-th position's full candidate round and scaling by the
    stride; each sampled argmin is asserted against the incremental
    placement.  Unsampled positions take the (equal, proven at the fully
    measured sizes) incremental assignment so the prefix stays exact."""
    assign = [-1] * len(tasks)
    t_sampled, checked = 0.0, 0
    for pos, i in enumerate(order):
        if pos % stride == 0:
            t0 = time.perf_counter()
            best_j = _scratch_price(devs, tasks, edges, topo, order, assign,
                                    pos, i)
            t_sampled += time.perf_counter() - t0
            assert best_j == ref_assign[i], \
                f"sampled scratch placement diverged at position {pos}"
            checked += 1
        assign[i] = ref_assign[i]
    return t_sampled * stride, checked


def _engine_exact(devs, tasks, edges, topo, order, assign) -> bool:
    """Incremental engine, advanced over the whole order in one go, must
    byte-match the canonical from-scratch simulation."""
    ctx = GraphSimContext(devs, tasks, edges, topo, list(order))
    st = GraphSimState(ctx, list(assign))
    st.advance(len(order))
    return st.finish == graph_finish_times(devs, tasks, edges, assign,
                                           topology=topo, order=order)


def throughput_rows() -> dict:
    devs = MACHINES[MACHINE]()
    topo = BusTopology.from_spec("serialized", devs)
    out = {}
    for name, spec in SIZES:
        g = _build(spec)
        tasks, edges = g.task_specs(), g.edge_indices()
        n = len(tasks)
        reps = 3 if n <= SCRATCH_FULL_MAX else 1
        res, t_inc = timed(solve_list_schedule, devs, tasks, edges,
                           repeats=reps, bus=topo, refine=False)
        order, assign = list(res.order), list(res.assign)
        estimated = n > SCRATCH_FULL_MAX
        if estimated:
            t_scr, checked = _eft_scratch_sampled(
                devs, tasks, edges, topo, order, assign, SCRATCH_STRIDE)
        else:
            ref_assign, t_scr = timed(_eft_scratch, devs, tasks, edges,
                                      topo, order, repeats=1)
            assert ref_assign == assign, \
                f"{name}: incremental placement differs from scratch EFT"
            checked = n
        exact = _engine_exact(devs, tasks, edges, topo, order, assign)
        assert exact, f"{name}: incremental finish times not byte-identical"
        out[name] = {
            "n_tasks": n,
            "solve_ms": t_inc * 1e3,
            "plans_per_s": 1.0 / t_inc,
            "scratch_ms": t_scr * 1e3,
            "scratch_plans_per_s": 1.0 / t_scr,
            "incremental_vs_scratch_x": t_scr / t_inc,
            "eft_makespan_s": res.makespan,
            "scratch_estimated": estimated,
            "scratch_positions_checked": checked,
            "engine_exact": exact,
        }
    return out


def resolve_rows(profile: bool = False) -> dict:
    devs = MACHINES[MACHINE]()
    topo = BusTopology.from_spec("serialized", devs)
    out = {}
    for name, spec in SIZES:
        g = _build(spec)
        tasks, edges = g.task_specs(), g.edge_indices()
        n = len(tasks)
        full = solve_list_schedule(devs, tasks, edges, bus=topo,
                                   refine=False)
        cut = int(PIN_FRACTION * n)
        frozen = list(full.order[:cut])
        pinned = {i: full.assign[i] for i in frozen}
        ext = {i: (full.task_finish[i], full.task_finish[i])
               for i in frozen}
        # one cache per graph, exactly how the runtime holds it per job —
        # the warmup call fills it, so the quantiles price a warm rescue
        cache = SolveContextCache()
        reps = 9

        def refined(prune=True):
            return solve_list_schedule(devs, tasks, edges, bus=topo,
                                       refine=True, pinned=pinned, ext=ext,
                                       seed_assign=list(full.assign),
                                       max_evals=RESOLVE_EVALS,
                                       prune=prune, cache=cache)

        res, ref_med, ref_p95, ref_best = timed_quantiles(refined,
                                                          repeats=reps)
        replay = graph_finish_times(devs, tasks, edges, res.assign,
                                    topology=topo, order=res.order, ext=ext)
        exact = replay == res.task_finish
        assert exact, f"{name}: partial re-solve finish times diverged"
        _, eft_med, eft_p95, eft_best = timed_quantiles(
            solve_list_schedule, devs, tasks, edges, repeats=reps, bus=topo,
            refine=False, pinned=pinned, ext=ext, cache=cache)
        # quality contract (§14): refined never worse than its EFT seed,
        # pruned descent within PRUNE_QUALITY_X of the full-sweep descent
        assert res.makespan <= full.makespan + _EPS, \
            f"{name}: refined makespan exceeds the EFT seed's"
        unpruned = refined(prune=False)
        quality_x = (res.makespan / unpruned.makespan
                     if unpruned.makespan > 0 else 1.0)
        assert quality_x <= PRUNE_QUALITY_X, \
            f"{name}: pruned descent {quality_x:.4f}x off the full sweep"
        if profile and name == SIZES[-1][0]:
            import cProfile
            import pstats
            prof = cProfile.Profile()
            prof.runcall(refined)
            prof.dump_stats("bench_resolve.prof")
            pstats.Stats(prof).sort_stats("cumulative").print_stats(25)
            emit("scheduler_resolve_profile", 0.0, "bench_resolve.prof")
        out[name] = {
            "n_tasks": n,
            "free_tasks": n - cut,
            "resolve_ms": ref_med * 1e3,
            "resolve_p95_ms": ref_p95 * 1e3,
            "resolve_best_ms": ref_best * 1e3,
            "resolve_eft_ms": eft_med * 1e3,
            "resolve_eft_p95_ms": eft_p95 * 1e3,
            "resolve_eft_best_ms": eft_best * 1e3,
            "resolve_repeats": reps,
            "refine_evals": res.iterations,
            "partial_makespan_s": res.makespan,
            "pruned_vs_unpruned_x": quality_x,
            "refined_le_seed": bool(res.makespan <= full.makespan + _EPS),
            "resolve_exact": exact,
        }
    return out


def hierarchical_rows() -> dict:
    """Template-tiled whole-model solves (DESIGN.md §15): detection +
    ``solve_hierarchical`` timed end to end per repeat (detection is NOT
    amortized — ``detect_templates`` is called fresh every time), with
    the template cache warm after the warmup call, which is the
    production shape: the cache is process-wide and shared across jobs
    and tenants, so a steady-state solve pays detection + stitch + the
    exact engine simulation, never the per-template representative
    solves."""
    devs = MACHINES[MACHINE]()
    topo = BusTopology.from_spec("serialized", devs)
    out = {}
    for name, spec in HIER_SIZES:
        g = _build(spec)
        tasks, edges = g.task_specs(), g.edge_indices()
        n = len(tasks)
        cache = TemplatePlanCache()

        def hier_once():
            part = detect_templates(g)
            return solve_hierarchical(devs, tasks, edges, partition=part,
                                      bus=topo, template_cache=cache)

        # >= 9 repeats, matching the §14 convention: the gated statistic
        # is the floor, and more repeats is what makes a floor stable on
        # a runner with ambient contention
        res, med, p95, best = timed_quantiles(hier_once, repeats=9)
        part = detect_templates(g)
        # ground truth: the engine's from-scratch simulation of the
        # stitched assignment must be byte-identical to what's reported
        replay = graph_finish_times(devs, tasks, edges, res.assign,
                                    topology=topo, order=res.order)
        exact = replay == res.task_finish and res.makespan == max(replay)
        assert exact, f"{name}: tiled finish times diverged from the engine"
        # the all-one-device floor (the §15 quality contract's hard half)
        floor = min(
            max(graph_finish_times(devs, tasks, edges, [j] * n,
                                   topology=topo))
            for j in range(len(devs)))
        assert res.makespan <= floor + _EPS, \
            f"{name}: tiled makespan lost to a single device"
        row = {
            "n_tasks": n,
            "instances": len(part.instances),
            "templates": part.n_templates,
            "hier_ms": med * 1e3,
            "hier_p95_ms": p95 * 1e3,
            "hier_best_ms": best * 1e3,
            "hier_makespan_s": res.makespan,
            "one_device_floor_s": floor,
            "hier_exact": exact,
            "hier_le_one_device": bool(res.makespan <= floor + _EPS),
        }
        if n <= HIER_FLAT_MAX:
            flat, t_flat = timed(solve_list_schedule, devs, tasks, edges,
                                 repeats=3, bus=topo, refine=False)
            quality_x = (res.makespan / flat.makespan
                         if flat.makespan > 0 else 1.0)
            assert quality_x <= HIER_QUALITY_X, \
                (f"{name}: tiled makespan {quality_x:.4f}x the flat "
                 f"EFT's (bound {HIER_QUALITY_X:.2f}x)")
            row.update({
                "flat_ms": t_flat * 1e3,
                "flat_makespan_s": flat.makespan,
                "hier_vs_flat_quality_x": quality_x,
                # wall-clock-derived: named outside the guard patterns
                "hier_solve_x_vs_flat": t_flat / med if med > 0 else 0.0,
            })
        out[name] = row
    return out


def main(profile: bool = False) -> None:
    report: dict = {"machine": MACHINE}
    thr, t_t = timed(throughput_rows, repeats=1)
    rsv, t_r = timed(resolve_rows, profile, repeats=1)
    hier, t_h = timed(hierarchical_rows, repeats=1)
    report["throughput"] = thr
    report["partial_resolve"] = rsv
    report["hierarchical"] = hier
    for name, row in thr.items():
        emit(f"scheduler_eft_{name}", row["solve_ms"] * 1e3,
             f"{row['plans_per_s']:.1f} plans/s "
             f"x{row['incremental_vs_scratch_x']:.1f} vs scratch"
             f"{' (est)' if row['scratch_estimated'] else ''}")
    for name, row in rsv.items():
        emit(f"scheduler_resolve_{name}", row["resolve_ms"] * 1e3,
             f"free={row['free_tasks']} p95={row['resolve_p95_ms']:.1f}ms "
             f"eft_only={row['resolve_eft_ms']:.1f}ms")
    for name, row in hier.items():
        emit(f"scheduler_hier_{name}", row["hier_ms"] * 1e3,
             f"n={row['n_tasks']} templates={row['templates']} "
             f"p95={row['hier_p95_ms']:.1f}ms")
    emit("scheduler_sections", (t_t + t_r + t_h) * 1e6,
         "throughput+resolve+hierarchical")

    big = [r for r in thr.values()
           if r["n_tasks"] >= 300 and not r["scratch_estimated"]]
    big_resolve = rsv[SIZES[-1][0]]
    report["acceptance"] = {
        "throughput_floor_x": THROUGHPUT_FLOOR,
        "incremental_10x_at_300_nodes": all(
            r["incremental_vs_scratch_x"] >= THROUGHPUT_FLOOR for r in big),
        "engine_bit_identical": all(r["engine_exact"]
                                    for r in thr.values()),
        "partial_resolve_exact": all(r["resolve_exact"]
                                     for r in rsv.values()),
        # §14 latency gate: refined re-solve at ~3000 nodes, gated on the
        # repeat floor — the PR-7 223ms baseline was common.timed's min-
        # of-repeats, and ambient runner contention only ever adds time
        "resolve_ms_gate_3000_nodes": RESOLVE_MS_GATE_3000,
        "resolve_under_gate_3000_nodes":
            big_resolve["resolve_best_ms"] <= RESOLVE_MS_GATE_3000,
        # wall-clock-derived: named outside the guard's speedup/makespan
        # key patterns (run.py gates resolve_best_ms, with latency tol)
        "resolve_x_vs_pr7_baseline":
            RESOLVE_BASELINE_MS_3000 / big_resolve["resolve_best_ms"],
        "refined_never_worse_than_seed": all(r["refined_le_seed"]
                                             for r in rsv.values()),
        "pruned_within_2pct_of_full_sweep": all(
            r["pruned_vs_unpruned_x"] <= PRUNE_QUALITY_X
            for r in rsv.values()),
        # EFT-only aspiration — reported honestly, not gated: the exact
        # staging-flip replays floor this path near ~16 ms at 3040 nodes
        "resolve_eft_target_ms_3000_nodes": RESOLVE_EFT_TARGET_MS,
        "resolve_eft_ms_3000_nodes": big_resolve["resolve_eft_ms"],
        "resolve_eft_best_ms_3000_nodes":
            big_resolve["resolve_eft_best_ms"],
        # §15 gates: the 30k-node whole-model solve, tiled quality bounds
        "hier_ms_gate_30k_nodes": HIER_MS_GATE_30K,
        "hier_under_gate_30k_nodes":
            hier["stack30k"]["hier_ms"] <= HIER_MS_GATE_30K,
        "hier_exact": all(r["hier_exact"] for r in hier.values()),
        "hier_le_one_device": all(r["hier_le_one_device"]
                                  for r in hier.values()),
        "hier_quality_bound_x": HIER_QUALITY_X,
        "hier_within_bound_of_flat": all(
            r["hier_vs_flat_quality_x"] <= HIER_QUALITY_X
            for r in hier.values() if "hier_vs_flat_quality_x" in r),
        # near-linearity: per-node tiled-solve cost at 30k stays within
        # HIER_LINEARITY_X of the 3040-node per-node cost
        "hier_linearity_bound_x": HIER_LINEARITY_X,
        "hier_near_linear_in_instances":
            (hier["stack30k"]["hier_ms"] / hier["stack30k"]["n_tasks"])
            <= HIER_LINEARITY_X * (hier["stack3040"]["hier_ms"]
                                   / hier["stack3040"]["n_tasks"]),
    }
    assert big, "no fully-measured size at >=300 nodes"
    assert report["acceptance"]["incremental_10x_at_300_nodes"], \
        "incremental EFT under 10x the from-scratch baseline at >=300 nodes"
    assert report["acceptance"]["engine_bit_identical"]
    assert report["acceptance"]["partial_resolve_exact"]
    # the hard failure allows the CI gate's wall-clock noise margin; the
    # committed snapshot's boolean above is the <= 30 ms acceptance record
    assert big_resolve["resolve_best_ms"] <= RESOLVE_MS_GATE_3000 * \
        RESOLVE_NOISE_X, \
        (f"refined re-solve floor {big_resolve['resolve_best_ms']:.1f}ms "
         f"over the {RESOLVE_MS_GATE_3000:.0f}ms gate "
         f"(+{RESOLVE_NOISE_X:.2f}x noise margin) at 3040 nodes")
    assert report["acceptance"]["hier_exact"]
    assert report["acceptance"]["hier_le_one_device"]
    assert report["acceptance"]["hier_within_bound_of_flat"]
    assert hier["stack30k"]["hier_ms"] <= HIER_MS_GATE_30K * HIER_NOISE_X, \
        (f"30k-node tiled solve median {hier['stack30k']['hier_ms']:.0f}ms "
         f"over the {HIER_MS_GATE_30K:.0f}ms gate "
         f"(+{HIER_NOISE_X:.2f}x noise margin)")

    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)
    emit("scheduler_report", 0.0, OUT_PATH)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--profile", action="store_true",
                    help="dump a cProfile of one warm refined re-solve at "
                         "the largest size to bench_resolve.prof")
    main(profile=ap.parse_args().profile)
