"""Scheduler-throughput benchmark — the planner itself as the hot path,
emitted as ``BENCH_scheduler.json`` (a CI artifact alongside the graph
bench).

Two sections (DESIGN.md §12), on ``mach2`` (the 3-device heterogeneous
testbed — solver cost scales with graph size and device count, not with
which machine's timeline the plan describes):

* **throughput** — end-to-end EFT list-schedule placement (``refine=False``)
  at three DAG sizes (a 35-node transformer block, a ~300-node and a
  ~3000-node ``transformer_stack`` derived from the stablelm-12b config),
  against the pre-PR from-scratch baseline that re-simulated the whole
  placed prefix for every (task, device) candidate.  The baseline is
  fully re-measured up to ~400 nodes; at ~3000 nodes it is estimated by
  timing every ``SCRATCH_STRIDE``-th placement position with the real
  full-prefix pricing loop and scaling by the stride (per-position cost
  grows linearly with position, so a uniform stride is an unbiased
  sample) — flagged ``scratch_estimated``.  Acceptance: incremental
  placement ≥ 10x the from-scratch baseline at ≥ 300 nodes, and the
  incremental engine's finish times byte-identical to
  ``graph_finish_times`` at every size (where the baseline is fully
  measured, the placement vector must match exactly too; where sampled,
  every sampled position's argmin must match).
* **partial_resolve** — the PR-5 re-planning path: 90% of the order
  pinned with ``ext`` carrying the already-committed finish times, the
  remainder re-solved with ``seed_assign`` + descent refinement
  (``max_evals=80``).  Reports latency per size (``resolve_ms`` with
  refinement, ``resolve_eft_ms`` for the EFT-only re-solve) and asserts
  the reported finish times equal a from-scratch ``graph_finish_times``
  replay.  Sub-10ms at ~3000 nodes is the design target (DESIGN.md §12),
  reported but not gated: descent refinement sweeps every free (task,
  device) move at least once, which dominates at that size.

Wall-clock keys (``plans_per_s``, ``*_ms``, ``incremental_vs_scratch_x``)
are named to stay outside the regression guard's speedup/makespan
patterns; the deterministic model quantities (``eft_makespan_s``,
``partial_makespan_s``) are guarded.
"""
from __future__ import annotations

import json
import math
import os
import time

from repro.core import (BusTopology, GraphSimContext, GraphSimState,
                        graph_finish_times, solve_list_schedule,
                        transformer_block, transformer_stack)
from repro.core.optimize import _EPS

from .common import MACHINES, emit, timed

OUT_PATH = os.environ.get("BENCH_SCHEDULER_PATH", "BENCH_scheduler.json")
MACHINE = "mach2"
SIZES = (
    ("block35", dict(kind="block", d_model=4096, seq=16384, ff_mult=4,
                     groups=8)),
    ("stack304", dict(kind="stack", config="stablelm-12b", layers=4,
                      microbatches=4, groups=4)),
    ("stack3040", dict(kind="stack", config="stablelm-12b", layers=10,
                       microbatches=16, groups=4)),
)
SCRATCH_FULL_MAX = 400   # fully re-measure the baseline up to this size
SCRATCH_STRIDE = 100     # sampled baseline positions beyond that
PIN_FRACTION = 0.9
RESOLVE_EVALS = 80
THROUGHPUT_FLOOR = 10.0  # required incremental-vs-scratch x at >=300 nodes


def _build(spec: dict):
    spec = dict(spec)
    kind = spec.pop("kind")
    if kind == "block":
        return transformer_block(**spec)
    return transformer_stack(spec.pop("config"), **spec)


def _scratch_price(devs, tasks, edges, topo, order, assign, pos, i):
    """One pre-PR candidate round: price task ``i`` on every device by
    re-simulating the whole placed prefix, return the EFT argmin."""
    prefix = order[: pos + 1]
    best_j, best_t = 0, math.inf
    for j in range(len(devs)):
        assign[i] = j
        t = graph_finish_times(devs, tasks, edges, assign, topology=topo,
                               order=prefix)[i]
        if t < best_t - _EPS:
            best_j, best_t = j, t
    return best_j


def _eft_scratch(devs, tasks, edges, topo, order):
    """The pre-PR placement loop: full prefix re-simulation per candidate."""
    assign = [-1] * len(tasks)
    for pos, i in enumerate(order):
        assign[i] = _scratch_price(devs, tasks, edges, topo, order, assign,
                                   pos, i)
    return assign


def _eft_scratch_sampled(devs, tasks, edges, topo, order, ref_assign,
                         stride):
    """Estimate the from-scratch baseline's runtime by timing every
    ``stride``-th position's full candidate round and scaling by the
    stride; each sampled argmin is asserted against the incremental
    placement.  Unsampled positions take the (equal, proven at the fully
    measured sizes) incremental assignment so the prefix stays exact."""
    assign = [-1] * len(tasks)
    t_sampled, checked = 0.0, 0
    for pos, i in enumerate(order):
        if pos % stride == 0:
            t0 = time.perf_counter()
            best_j = _scratch_price(devs, tasks, edges, topo, order, assign,
                                    pos, i)
            t_sampled += time.perf_counter() - t0
            assert best_j == ref_assign[i], \
                f"sampled scratch placement diverged at position {pos}"
            checked += 1
        assign[i] = ref_assign[i]
    return t_sampled * stride, checked


def _engine_exact(devs, tasks, edges, topo, order, assign) -> bool:
    """Incremental engine, advanced over the whole order in one go, must
    byte-match the canonical from-scratch simulation."""
    ctx = GraphSimContext(devs, tasks, edges, topo, list(order))
    st = GraphSimState(ctx, list(assign))
    st.advance(len(order))
    return st.finish == graph_finish_times(devs, tasks, edges, assign,
                                           topology=topo, order=order)


def throughput_rows() -> dict:
    devs = MACHINES[MACHINE]()
    topo = BusTopology.from_spec("serialized", devs)
    out = {}
    for name, spec in SIZES:
        g = _build(spec)
        tasks, edges = g.task_specs(), g.edge_indices()
        n = len(tasks)
        reps = 3 if n <= SCRATCH_FULL_MAX else 1
        res, t_inc = timed(solve_list_schedule, devs, tasks, edges,
                           repeats=reps, bus=topo, refine=False)
        order, assign = list(res.order), list(res.assign)
        estimated = n > SCRATCH_FULL_MAX
        if estimated:
            t_scr, checked = _eft_scratch_sampled(
                devs, tasks, edges, topo, order, assign, SCRATCH_STRIDE)
        else:
            ref_assign, t_scr = timed(_eft_scratch, devs, tasks, edges,
                                      topo, order, repeats=1)
            assert ref_assign == assign, \
                f"{name}: incremental placement differs from scratch EFT"
            checked = n
        exact = _engine_exact(devs, tasks, edges, topo, order, assign)
        assert exact, f"{name}: incremental finish times not byte-identical"
        out[name] = {
            "n_tasks": n,
            "solve_ms": t_inc * 1e3,
            "plans_per_s": 1.0 / t_inc,
            "scratch_ms": t_scr * 1e3,
            "scratch_plans_per_s": 1.0 / t_scr,
            "incremental_vs_scratch_x": t_scr / t_inc,
            "eft_makespan_s": res.makespan,
            "scratch_estimated": estimated,
            "scratch_positions_checked": checked,
            "engine_exact": exact,
        }
    return out


def resolve_rows() -> dict:
    devs = MACHINES[MACHINE]()
    topo = BusTopology.from_spec("serialized", devs)
    out = {}
    for name, spec in SIZES:
        g = _build(spec)
        tasks, edges = g.task_specs(), g.edge_indices()
        n = len(tasks)
        full = solve_list_schedule(devs, tasks, edges, bus=topo,
                                   refine=False)
        cut = int(PIN_FRACTION * n)
        frozen = list(full.order[:cut])
        pinned = {i: full.assign[i] for i in frozen}
        ext = {i: (full.task_finish[i], full.task_finish[i])
               for i in frozen}
        reps = 3 if n <= SCRATCH_FULL_MAX else 1
        res, t_ref = timed(solve_list_schedule, devs, tasks, edges,
                           repeats=reps, bus=topo, refine=True,
                           pinned=pinned, ext=ext,
                           seed_assign=list(full.assign),
                           max_evals=RESOLVE_EVALS)
        replay = graph_finish_times(devs, tasks, edges, res.assign,
                                    topology=topo, order=res.order, ext=ext)
        exact = replay == res.task_finish
        assert exact, f"{name}: partial re-solve finish times diverged"
        _, t_eft = timed(solve_list_schedule, devs, tasks, edges,
                         repeats=reps, bus=topo, refine=False,
                         pinned=pinned, ext=ext)
        out[name] = {
            "n_tasks": n,
            "free_tasks": n - cut,
            "resolve_ms": t_ref * 1e3,
            "resolve_eft_ms": t_eft * 1e3,
            "refine_evals": res.iterations,
            "partial_makespan_s": res.makespan,
            "resolve_exact": exact,
        }
    return out


def main() -> None:
    report: dict = {"machine": MACHINE}
    thr, t_t = timed(throughput_rows, repeats=1)
    rsv, t_r = timed(resolve_rows, repeats=1)
    report["throughput"] = thr
    report["partial_resolve"] = rsv
    for name, row in thr.items():
        emit(f"scheduler_eft_{name}", row["solve_ms"] * 1e3,
             f"{row['plans_per_s']:.1f} plans/s "
             f"x{row['incremental_vs_scratch_x']:.1f} vs scratch"
             f"{' (est)' if row['scratch_estimated'] else ''}")
    for name, row in rsv.items():
        emit(f"scheduler_resolve_{name}", row["resolve_ms"] * 1e3,
             f"free={row['free_tasks']} "
             f"eft_only={row['resolve_eft_ms']:.1f}ms")
    emit("scheduler_sections", (t_t + t_r) * 1e6, "throughput+resolve")

    big = [r for r in thr.values()
           if r["n_tasks"] >= 300 and not r["scratch_estimated"]]
    report["acceptance"] = {
        "throughput_floor_x": THROUGHPUT_FLOOR,
        "incremental_10x_at_300_nodes": all(
            r["incremental_vs_scratch_x"] >= THROUGHPUT_FLOOR for r in big),
        "engine_bit_identical": all(r["engine_exact"]
                                    for r in thr.values()),
        "partial_resolve_exact": all(r["resolve_exact"]
                                     for r in rsv.values()),
        "resolve_ms_target_3000_nodes": 10.0,   # reported, not gated
    }
    assert big, "no fully-measured size at >=300 nodes"
    assert report["acceptance"]["incremental_10x_at_300_nodes"], \
        "incremental EFT under 10x the from-scratch baseline at >=300 nodes"
    assert report["acceptance"]["engine_bit_identical"]
    assert report["acceptance"]["partial_resolve_exact"]

    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)
    emit("scheduler_report", 0.0, OUT_PATH)


if __name__ == "__main__":
    main()
