"""Roofline analysis — reads the dry-run JSON artifacts and derives the
three terms per (arch × shape × mesh):

    compute    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory     = HLO_bytes / (chips × HBM_bw)
    collective = Σ collective_bytes / (chips × links × link_bw)

All dry-run numbers are *per device*, so terms divide by per-chip rates.
Hardware constants: TPU v5e-class — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI with 4 links/chip on a 2D torus (DESIGN.md §6).
"""
from __future__ import annotations

import json
from pathlib import Path

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_LINK_BW = 50e9
LINKS_PER_CHIP = 4          # 2D torus: ±x, ±y
DCN_PER_CHIP = 6.25e9       # ~50 GB/s NIC per 8-chip host, cross-pod

import os

_ROOT = Path(__file__).resolve().parent.parent / "experiments"
# default to the shipping (optimized) artifacts; REPRO_DRYRUN_DIR overrides
# (e.g. experiments/dryrun_base for the paper-faithful baseline tables)
DRYRUN_DIR = Path(os.environ.get("REPRO_DRYRUN_DIR",
                                 _ROOT / "dryrun_opt"))
if not DRYRUN_DIR.exists():  # fall back to any populated artifact dir
    for cand in ("dryrun_opt", "dryrun", "dryrun_base"):
        if (_ROOT / cand).exists():
            DRYRUN_DIR = _ROOT / cand
            break


def load_records(dryrun_dir: Path = DRYRUN_DIR) -> list[dict]:
    recs = []
    for f in sorted(dryrun_dir.glob("*.json")):
        recs.append(json.loads(f.read_text()))
    return recs


def roofline_terms(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    flops = rec["flops_per_device"]
    nbytes = rec["bytes_per_device"]
    kbytes = rec.get("bytes_per_device_kernelized", nbytes)
    coll = rec["collective_bytes_per_device"]
    ici_bytes = sum(v for k, v in coll.items())
    compute_s = flops / PEAK_FLOPS
    memory_s = nbytes / HBM_BW
    memory_kernelized_s = kbytes / HBM_BW
    collective_s = ici_bytes / (ICI_LINK_BW * LINKS_PER_CHIP)
    if rec["mesh"] == "multi":
        # cross-pod share of all-reduce rides the DCN; approximate the pod
        # axis fraction as 1/log2 share of the all-reduce steps
        collective_s += coll.get("all-reduce", 0) * 0.1 / DCN_PER_CHIP
    dominant = max(
        (("compute", compute_s), ("memory", memory_s),
         ("collective", collective_s)), key=lambda kv: kv[1])[0]
    total_hlo_flops = flops * rec["chips"]
    useful = rec["model_flops_global"] / total_hlo_flops \
        if total_hlo_flops else 0.0
    bound = max(compute_s, memory_s, collective_s)
    kbound = max(compute_s, memory_kernelized_s, collective_s)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": compute_s, "memory_s": memory_s,
        "memory_kernelized_s": memory_kernelized_s,
        "collective_s": collective_s, "dominant": dominant,
        "useful_flops_ratio": useful,
        "roofline_fraction": compute_s / bound if bound else 0.0,
        "roofline_fraction_kernelized": compute_s / kbound if kbound else 0.0,
        "step_lower_bound_s": bound,
    }


def main() -> None:
    from .common import emit
    recs = load_records()
    if not recs:
        print("roofline,0,no dry-run artifacts yet — run "
              "`python -m repro.launch.dryrun`")
        return
    for rec in recs:
        t = roofline_terms(rec)
        tag = f"{rec['arch']}_{rec['shape']}_{rec['mesh']}"
        if t is None:
            emit(f"roofline_{tag}", 0.0,
                 rec.get("reason", rec.get("status")))
            continue
        emit(
            f"roofline_{tag}", t["step_lower_bound_s"] * 1e6,
            f"compute={t['compute_s']:.4f}s memory={t['memory_s']:.4f}s "
            f"collective={t['collective_s']:.4f}s dominant={t['dominant']} "
            f"useful={t['useful_flops_ratio']:.2f} "
            f"roofline_frac={t['roofline_fraction']:.2f}")


if __name__ == "__main__":
    main()
