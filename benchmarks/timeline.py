"""Unified timeline engine benchmark — solver/simulator agreement and the
chunked-pipelining win, emitted as ``BENCH_timeline.json`` (a CI artifact).

Two sections per machine:

* **agreement** — |max(solver finish) - simulated makespan| / makespan for
  the paper inputs.  With the unified engine this gap is exactly zero; it
  used to be 10-20 % (the solver charged no-copy devices for bus queue time
  and let output copies overlap input copies).
* **pipelining** — simulated makespan of the 4096^3 GEMM, unpipelined vs
  chunked pipelined copies (C = 2/4/8), both re-solved so the split prices
  the chunk boundaries.
"""
from __future__ import annotations

import json
import os

from repro.core import simulate_timeline, solve_bisection, with_pipeline
from repro.core.optimize import _finish_times

from .common import MACHINES, PAPER_INPUTS, emit, timed

OUT_PATH = os.environ.get("BENCH_TIMELINE_PATH", "BENCH_timeline.json")
CHUNK_COUNTS = (2, 4, 8)
PIPELINE_SHAPE = (4096, 4096, 4096)


def agreement_rows(machine: str) -> list[dict]:
    rows = []
    for name, (m, n, k) in PAPER_INPUTS.items():
        devs = MACHINES[machine]()
        N = float(m) * n * k
        res = solve_bisection(devs, N, n=n, k=k, bus="serialized")
        tl = simulate_timeline(devs, res.ops, n, k)
        fin = _finish_times(devs, res.ops, n, k, "serialized")
        gap = abs(max(fin) - tl.makespan) / tl.makespan if tl.makespan else 0.0
        rows.append({"input": name, "m": m, "n": n, "k": k,
                     "solver_makespan_s": max(fin),
                     "simulated_makespan_s": tl.makespan,
                     "relative_gap": gap})
    return rows


def pipelining_rows(machine: str) -> dict:
    m, n, k = PIPELINE_SHAPE
    N = float(m) * n * k
    devs = MACHINES[machine]()
    base = solve_bisection(devs, N, n=n, k=k, bus="serialized")
    t0 = simulate_timeline(devs, base.ops, n, k).makespan
    chunked = {}
    for C in CHUNK_COUNTS:
        dp = with_pipeline(MACHINES[machine](), C)
        r = solve_bisection(dp, N, n=n, k=k, bus="serialized")
        chunked[str(C)] = simulate_timeline(dp, r.ops, n, k).makespan
    best = min(chunked.values())
    return {"shape": list(PIPELINE_SHAPE),
            "unpipelined_makespan_s": t0,
            "pipelined_makespan_s": chunked,
            "best_speedup": t0 / best if best else 0.0}


def main() -> None:
    report: dict = {"machines": {}}
    for machine in MACHINES:
        agree, t_agree = timed(agreement_rows, machine, repeats=1)
        pipe, t_pipe = timed(pipelining_rows, machine, repeats=1)
        report["machines"][machine] = {"agreement": agree,
                                       "pipelining": pipe}
        worst = max(r["relative_gap"] for r in agree)
        emit(f"timeline_agreement_{machine}", t_agree * 1e6,
             f"worst_gap={worst:.3e}")
        emit(f"timeline_pipelining_{machine}", t_pipe * 1e6,
             f"speedup={pipe['best_speedup']:.3f}x")
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)
    emit("timeline_report", 0.0, OUT_PATH)


if __name__ == "__main__":
    main()
