"""Paper Table 7: speedup of hgemms co-execution vs standalone execution
(CPU-only / GPU-only / XPU-only), per input and machine."""
from __future__ import annotations

from .common import MACHINES, PAPER_INPUTS, emit, hgemms_for, timed


def run(machine: str):
    hg = hgemms_for(machine)
    rows = []
    for name, (m, n, k) in PAPER_INPUTS.items():
        plan = hg.plan(m, n, k)
        coexec = plan.schedule.timeline.makespan
        n_ops = float(m) * n * k
        standalone = {d.kind: d.total_time(n_ops, n, k) for d in hg.devices}
        rows.append((name, {kind: t / coexec
                            for kind, t in standalone.items()}, coexec))
    return rows


def main() -> None:
    for machine in ("mach1", "mach2"):
        rows, dt = timed(run, machine)
        for name, sp, coexec in rows:
            emit(f"table7_speedup_{machine}_{name}", dt * 1e6,
                 f"vs_cpu={sp['cpu']:.2f}x vs_gpu={sp['gpu']:.2f}x "
                 f"vs_xpu={sp['xpu']:.2f}x coexec_s={coexec:.3f}")


if __name__ == "__main__":
    main()
