"""Shared benchmark plumbing: the paper's six evaluation inputs (Table 3)
and the two simulated testbeds (Tables 1-2)."""
from __future__ import annotations

import statistics
import time

from repro.core import HGemms, paper_mach1, paper_mach2

# Table 3: (m, n, k) and TOps = m*n*k / 1e12
PAPER_INPUTS = {
    "i1": (30_000, 30_000, 30_000),   # 27.0 TOps
    "i2": (60_000, 20_000, 35_000),   # 42.0
    "i3": (130_000, 20_000, 20_000),  # 52.0
    "i4": (40_000, 80_000, 20_000),   # 64.0
    "i5": (40_000, 30_000, 60_000),   # 72.0
    "i6": (56_000, 40_000, 40_000),   # 89.6
}

MACHINES = {"mach1": paper_mach1, "mach2": paper_mach2}


def hgemms_for(machine: str, **kw) -> HGemms:
    return HGemms(MACHINES[machine](), **kw)


def timed(fn, *args, repeats: int = 3, **kw):
    best = None
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        dt = time.perf_counter() - t0
        best = dt if best is None else min(best, dt)
    return out, best


def timed_quantiles(fn, *args, repeats: int = 5, warmup: int = 1, **kw):
    """Latency distribution of ``fn``: (last result, median s, p95 s,
    best s).

    Single-shot wall clocks at the millisecond scale are noisy (allocator
    state, frequency scaling, noisy VM neighbors); re-plan latencies are
    therefore reported as median/p95 over ``repeats`` >= 5 runs after
    ``warmup`` discarded calls (which also charge one-time costs like
    context-cache fills to warmup, not to the quantiles).  ``best`` is
    the regression-detection number: ambient contention only ever ADDS
    time, so the floor over repeats isolates the code's own cost — one
    quiet repeat is enough to prove a change didn't slow the path down."""
    repeats = max(5, repeats)
    out = None
    for _ in range(warmup):
        out = fn(*args, **kw)
    samples = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        samples.append(time.perf_counter() - t0)
    samples.sort()
    med = statistics.median(samples)
    # nearest-rank p95 (no interpolation past observed samples)
    p95 = samples[min(len(samples) - 1, int(0.95 * len(samples)))]
    return out, med, p95, samples[0]


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.3f},{derived}")
