"""PlanCache benchmark: repeated ``HGemms.plan`` calls must hit the cache.

For each paper input, times the cold solve vs the cached call (acceptance:
>= 10x faster), then verifies a ``DynamicScheduler.observe`` re-fit
invalidates the cache and forces a re-solve under the new models.
"""
from __future__ import annotations

import time

from .common import PAPER_INPUTS, emit, hgemms_for


def run(machine: str) -> None:
    hg = hgemms_for(machine)
    for name, (m, n, k) in PAPER_INPUTS.items():
        t0 = time.perf_counter()
        hg.plan(m, n, k)
        t_cold = time.perf_counter() - t0
        best_hit = None
        for _ in range(5):
            t0 = time.perf_counter()
            hg.plan(m, n, k)
            dt = time.perf_counter() - t0
            best_hit = dt if best_hit is None else min(best_hit, dt)
        speedup = t_cold / best_hit if best_hit else float("inf")
        emit(f"plan_cache_{machine}_{name}", best_hit * 1e6,
             f"cold_us={t_cold*1e6:.1f};speedup={speedup:.0f}x;"
             f"hit_10x={'PASS' if speedup >= 10 else 'FAIL'}")


def invalidation(machine: str) -> None:
    hg = hgemms_for(machine, dynamic=True)
    m, n, k = PAPER_INPUTS["i1"]
    p1 = hg.plan(m, n, k)
    hg.plan(m, n, k)
    hits_before = hg.plan_cache.hits
    # device 1 slows 3x -> model re-fit -> cache flush
    hg.dyn.observe(1, 1e12, hg.devices[1].compute(1e12) * 3.0)
    t0 = time.perf_counter()
    p2 = hg.plan(m, n, k)
    t_resolve = time.perf_counter() - t0
    ok = (len(hg.plan_cache) == 1 and p2.adapted is not p1.adapted
          and hg.plan_cache.invalidations >= 1
          and hg.plan_cache.hits == hits_before)
    emit(f"plan_cache_invalidation_{machine}", t_resolve * 1e6,
         f"resolved_after_refit={'PASS' if ok else 'FAIL'}")


def main() -> None:
    for machine in ("mach1", "mach2"):
        run(machine)
        invalidation(machine)


if __name__ == "__main__":
    main()
