"""Benchmark driver — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (see benchmarks/common.emit).
Sections:
  Table 4/5 — prediction accuracy + RMSE   (prediction_accuracy)
  Table 6   — work distribution            (work_distribution)
  Table 7   — co-execution speedups        (speedup)
  Fig 3/4   — execution times + numerics   (exec_time)
  §Roofline — dry-run roofline terms       (roofline)
  §Runtime  — plan-cache hit/invalidation  (plan_cache)
  §Timeline — solver/simulator agreement + pipelined-copy speedup
              (timeline; writes BENCH_timeline.json — uploaded in CI)
  §Stream   — feedback loop vs static plan, plan-carry-over overlap
              (streaming; writes BENCH_streaming.json — uploaded in CI)
  §Graph    — DAG co-execution vs best single device, list-schedule vs
              naive topo order (graph; writes BENCH_graph.json — uploaded
              in CI)

A failing section is reported as ``name,0,ERROR`` and the driver keeps
going, but the failure is collected and the process exits non-zero — CI
must not pass on broken benchmarks.
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (exec_time, graph, plan_cache, prediction_accuracy,
                   roofline, speedup, streaming, timeline, work_distribution)
    failures: list[str] = []
    for mod in (prediction_accuracy, work_distribution, speedup, exec_time,
                roofline, plan_cache, timeline, streaming, graph):
        name = mod.__name__.split(".")[-1]
        print(f"# --- {name} ---")
        try:
            mod.main()
        except Exception:  # noqa: BLE001 - report, continue, fail at exit
            print(f"{name},0,ERROR")
            traceback.print_exc()
            failures.append(name)
    if failures:
        print(f"# FAILED sections: {', '.join(failures)}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
