"""Benchmark driver — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (see benchmarks/common.emit).
Sections:
  Table 4/5 — prediction accuracy + RMSE   (prediction_accuracy)
  Table 6   — work distribution            (work_distribution)
  Table 7   — co-execution speedups        (speedup)
  Fig 3/4   — execution times + numerics   (exec_time)
  §Roofline — dry-run roofline terms       (roofline)
  §Runtime  — plan-cache hit/invalidation  (plan_cache)
  §Timeline — solver/simulator agreement + pipelined-copy speedup
              (timeline; writes BENCH_timeline.json — uploaded in CI)
  §Stream   — feedback loop vs static plan, plan-carry-over overlap
              (streaming; writes BENCH_streaming.json — uploaded in CI)
  §Graph    — DAG co-execution vs best single device, list-schedule vs
              naive topo order, mid-graph straggler re-planning (graph;
              writes BENCH_graph.json — uploaded in CI)
  §Sched    — incremental-engine placement throughput vs the from-scratch
              EFT baseline, partial re-solve latency, template-tiled
              hierarchical solves up to 30k nodes (scheduler; writes
              BENCH_scheduler.json — uploaded in CI)
  §Cluster  — cluster-aware vs NIC-oblivious placement on a 2-host stack,
              makespan/energy Pareto sweep, device-loss rescue vs locked-in
              plan (cluster; writes BENCH_cluster.json — uploaded in CI)
  §Tenants  — weighted-fair + preemptive admission vs FIFO on one shared
              core, per-tier latency percentiles (runtime_tenants; writes
              BENCH_runtime.json — uploaded in CI)

A failing section is reported as ``name,0,ERROR`` and the driver keeps
going, but the failure is collected and the process exits non-zero — CI
must not pass on broken benchmarks.

Regression guard: before the sections run, the committed ``BENCH_*.json``
baselines are snapshotted; afterwards every freshly-emitted makespan
(lower is better) and speedup (higher is better) is compared against its
baseline value with a relative tolerance (``BENCH_REGRESSION_TOL``, default
10%).  Metrics under a ``thread*`` path are wall-clock — inherently noisy
on shared CI runners — and are skipped; everything else in these reports is
a deterministic model quantity, so a drift beyond tolerance is a real
performance regression and fails the job.

Two wall-clock exceptions ARE guarded: the 3040-node partial re-solve
latency (``partial_resolve/stack3040/resolve_best_ms``, DESIGN.md §14),
the quantity the straggler-rescue path blocks on, and the 30k-node
template-tiled hierarchical solve (``hierarchical/stack30k/hier_best_ms``,
DESIGN.md §15), the whole-model admission path.  Each gated leaf is the
BEST of the section's repeats, not the median: shared
runners suffer ambient noisy-neighbor contention that only ever adds
time, so one quiet repeat is enough to prove the code path didn't
regress, while medians swing 1.3x run-to-run.  It gets its own tolerance
(``BENCH_LATENCY_TOL``, default 15%) so CI fails when a change regresses
re-plan latency, alongside the makespan/speedup guards.  The median
(``resolve_ms``) stays in the report as the honest latency story.
"""
from __future__ import annotations

import json
import os
import sys
import traceback

BENCH_FILES = ("BENCH_timeline.json", "BENCH_streaming.json",
               "BENCH_graph.json", "BENCH_scheduler.json",
               "BENCH_cluster.json", "BENCH_runtime.json")
TOLERANCE = float(os.environ.get("BENCH_REGRESSION_TOL", "0.10"))
LATENCY_TOL = float(os.environ.get("BENCH_LATENCY_TOL", "0.15"))
# wall-clock latency leaves that ARE gated (path suffix -> direction):
# the ~3000-node refined re-solve best-of-repeats, DESIGN.md §14's
# headline path (best, not median — noise only adds time, so the floor
# is the stable regression signal on a shared runner), and the 30k-node
# template-tiled hierarchical solve, DESIGN.md §15's headline path
LATENCY_GATED = ("/partial_resolve/stack3040/resolve_best_ms",
                 "/hierarchical/stack30k/hier_best_ms")


def _metrics(obj, path: str = "") -> dict[str, tuple[str, float]]:
    """Flatten a benchmark report to {path: (direction, value)} over the
    comparable numeric leaves: ``*speedup*`` keys (higher is better) and
    ``*makespan_s`` keys (lower is better).  Paths under ``thread*``
    segments are wall-clock and excluded."""
    out: dict[str, tuple[str, float]] = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            sub = f"{path}/{k}"
            if isinstance(v, (dict, list)):
                out.update(_metrics(v, sub))
            elif isinstance(v, (int, float)) and not isinstance(v, bool):
                if any(seg.startswith("thread") for seg in sub.split("/")):
                    continue
                if "speedup" in k:
                    out[sub] = ("higher", float(v))
                elif k.endswith("makespan_s"):
                    out[sub] = ("lower", float(v))
                elif sub.endswith(LATENCY_GATED):
                    out[sub] = ("latency", float(v))
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            if isinstance(v, (dict, list)):
                out.update(_metrics(v, f"{path}/{i}"))
    return out


def load_baselines() -> dict[str, dict[str, tuple[str, float]]]:
    """Snapshot the committed BENCH_*.json metrics BEFORE the sections
    overwrite them in place."""
    out: dict[str, dict[str, tuple[str, float]]] = {}
    for fname in BENCH_FILES:
        try:
            with open(fname) as f:
                out[fname] = _metrics(json.load(f))
        except (OSError, ValueError):
            continue   # no baseline yet (fresh checkout artifact dir)
    return out


def check_regressions(baselines: dict[str, dict[str, tuple[str, float]]],
                      tolerance: float = TOLERANCE) -> list[str]:
    """Compare freshly-emitted reports against the snapshotted baselines.
    Returns human-readable regression lines (empty = pass).  Keys only in
    the FRESH report are ignored — new sections extend the baseline, they
    don't regress it.  Keys only in the BASELINE are a failure, listed by
    name: a silently-vanished metric means a section stopped emitting a
    quantity the guard was protecting (a rename or a dropped section),
    and skipping it would turn the guard off without anyone noticing."""
    problems: list[str] = []
    for fname, base in baselines.items():
        try:
            with open(fname) as f:
                new = _metrics(json.load(f))
        except (OSError, ValueError):
            continue   # the section failed; already reported as ERROR
        missing = sorted(p for p in base if p not in new)
        if missing:
            shown = ", ".join(missing[:8])
            more = f" (+{len(missing) - 8} more)" if len(missing) > 8 else ""
            problems.append(
                f"{fname}: {len(missing)} baseline metric(s) missing from "
                f"the fresh report: {shown}{more} — a renamed or dropped "
                f"section must update the committed baseline")
        for path, (direction, bval) in base.items():
            if path not in new or bval <= 0.0:
                continue
            nval = new[path][1]
            if direction == "higher" and nval < bval * (1.0 - tolerance):
                problems.append(
                    f"{fname}{path}: speedup {nval:.4g} fell below "
                    f"baseline {bval:.4g} (tolerance {tolerance:.0%})")
            elif direction == "lower" and nval > bval * (1.0 + tolerance):
                problems.append(
                    f"{fname}{path}: makespan {nval:.4g} rose above "
                    f"baseline {bval:.4g} (tolerance {tolerance:.0%})")
            elif direction == "latency" and \
                    nval > bval * (1.0 + LATENCY_TOL):
                problems.append(
                    f"{fname}{path}: re-plan latency {nval:.4g}ms rose "
                    f"above baseline {bval:.4g}ms "
                    f"(tolerance {LATENCY_TOL:.0%})")
    return problems


def _snapshot(path: str) -> None:
    """Dump the current BENCH_*.json metrics (CI runs this on the fresh
    checkout, BEFORE the benchmark steps overwrite the committed files)."""
    snap = {fname: {k: list(v) for k, v in metrics.items()}
            for fname, metrics in load_baselines().items()}
    with open(path, "w") as f:
        json.dump(snap, f, indent=2)
    print(f"# snapshotted baselines for {len(snap)} report(s) -> {path}")


def _check(path: str) -> None:
    """Compare the freshly-emitted reports against a --snapshot file; exit
    non-zero on regression (the CI guard step)."""
    with open(path) as f:
        snap = json.load(f)
    baselines = {fname: {k: (d, float(v)) for k, (d, v) in metrics.items()}
                 for fname, metrics in snap.items()}
    regressions = check_regressions(baselines)
    for line in regressions:
        print(f"# REGRESSION: {line}", file=sys.stderr)
    if regressions:
        sys.exit(1)
    total = sum(len(m) for m in baselines.values())
    print(f"# benchmark regression guard: {total} metric(s) within "
          f"{TOLERANCE:.0%} of baseline")


def main() -> None:
    if len(sys.argv) == 3 and sys.argv[1] == "--snapshot":
        _snapshot(sys.argv[2])
        return
    if len(sys.argv) == 3 and sys.argv[1] == "--check":
        _check(sys.argv[2])
        return
    from . import (cluster, exec_time, graph, plan_cache,
                   prediction_accuracy, roofline, runtime_tenants,
                   scheduler, speedup, streaming, timeline,
                   work_distribution)
    baselines = load_baselines()
    failures: list[str] = []
    for mod in (prediction_accuracy, work_distribution, speedup, exec_time,
                roofline, plan_cache, timeline, streaming, graph, scheduler,
                cluster, runtime_tenants):
        name = mod.__name__.split(".")[-1]
        print(f"# --- {name} ---")
        try:
            mod.main()
        except Exception:  # noqa: BLE001 - report, continue, fail at exit
            print(f"{name},0,ERROR")
            traceback.print_exc()
            failures.append(name)
    regressions = check_regressions(baselines)
    for line in regressions:
        print(f"# REGRESSION: {line}", file=sys.stderr)
    if regressions:
        failures.append("benchmark-regression-guard")
    if failures:
        print(f"# FAILED sections: {', '.join(failures)}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
