"""Benchmark driver — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (see benchmarks/common.emit).
Sections:
  Table 4/5 — prediction accuracy + RMSE   (prediction_accuracy)
  Table 6   — work distribution            (work_distribution)
  Table 7   — co-execution speedups        (speedup)
  Fig 3/4   — execution times + numerics   (exec_time)
  §Roofline — dry-run roofline terms       (roofline)
  §Runtime  — plan-cache hit/invalidation  (plan_cache)
  §Timeline — solver/simulator agreement + pipelined-copy speedup
              (timeline; writes BENCH_timeline.json — uploaded in CI)
  §Stream   — feedback loop vs static plan, plan-carry-over overlap
              (streaming; writes BENCH_streaming.json — uploaded in CI)
"""
from __future__ import annotations

import traceback


def main() -> None:
    from . import (exec_time, plan_cache, prediction_accuracy, roofline,
                   speedup, streaming, timeline, work_distribution)
    for mod in (prediction_accuracy, work_distribution, speedup, exec_time,
                roofline, plan_cache, timeline, streaming):
        name = mod.__name__.split(".")[-1]
        print(f"# --- {name} ---")
        try:
            mod.main()
        except Exception:  # noqa: BLE001 - report and continue
            print(f"{name},0,ERROR")
            traceback.print_exc()


if __name__ == "__main__":
    main()
