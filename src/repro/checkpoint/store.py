"""Sharded, atomic, resumable checkpointing.

Layout:  <dir>/step_<N>/
            manifest.json         — tree structure, shapes, dtypes, step
            arrays/<idx>.npy      — one file per leaf (host-gathered)
         <dir>/LATEST             — atomically updated pointer

Writes go to ``step_<N>.tmp`` then ``os.replace`` — a crash mid-save never
corrupts the previous checkpoint (fault tolerance requirement).  Restore
reshards to the *current* mesh: arrays are loaded on host then device_put
with the target sharding, so a 256-chip checkpoint restores onto 512 chips
(elastic scaling) and vice versa.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from pathlib import Path
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return keys, leaves, treedef


def save(directory: str | os.PathLike, step: int, tree: Any, *,
         keep: int = 3) -> Path:
    base = Path(directory)
    base.mkdir(parents=True, exist_ok=True)
    final = base / f"step_{step:08d}"
    tmp = base / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    (tmp / "arrays").mkdir(parents=True)

    keys, leaves, _ = _flatten(tree)
    manifest = {"step": step, "leaves": []}
    for i, (key, leaf) in enumerate(zip(keys, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        np.save(tmp / "arrays" / f"{i}.npy", arr)
        manifest["leaves"].append(
            {"key": key, "index": i, "shape": list(arr.shape),
             "dtype": str(arr.dtype)})
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)

    # atomic LATEST pointer
    fd, tmppath = tempfile.mkstemp(dir=base)
    with os.fdopen(fd, "w") as f:
        f.write(final.name)
    os.replace(tmppath, base / "LATEST")

    _garbage_collect(base, keep)
    return final


def _garbage_collect(base: Path, keep: int) -> None:
    ckpts = sorted(p for p in base.iterdir()
                   if p.is_dir() and p.name.startswith("step_")
                   and not p.name.endswith(".tmp"))
    for p in ckpts[:-keep] if keep > 0 else []:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(directory: str | os.PathLike) -> int | None:
    base = Path(directory)
    ptr = base / "LATEST"
    if not ptr.exists():
        return None
    name = ptr.read_text().strip()
    if not (base / name / "manifest.json").exists():
        # stale pointer (crash between replace calls): fall back to scan
        ckpts = sorted(p for p in base.iterdir()
                       if p.is_dir() and (p / "manifest.json").exists())
        if not ckpts:
            return None
        name = ckpts[-1].name
    return int(name.split("_")[1])


def restore(directory: str | os.PathLike, tree_like: Any, *,
            step: int | None = None, shardings: Any = None) -> tuple[Any, int]:
    """Restore into the structure of ``tree_like``; reshard onto
    ``shardings`` (same pytree) if given."""
    base = Path(directory)
    if step is None:
        step = latest_step(base)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {base}")
    ckpt = base / f"step_{step:08d}"
    manifest = json.loads((ckpt / "manifest.json").read_text())

    keys, leaves, treedef = _flatten(tree_like)
    by_key = {m["key"]: m for m in manifest["leaves"]}
    out = []
    shard_flat = (jax.tree_util.tree_leaves(shardings)
                  if shardings is not None else [None] * len(leaves))
    for key, ref_leaf, shard in zip(keys, leaves, shard_flat):
        if key not in by_key:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        m = by_key[key]
        arr = np.load(ckpt / "arrays" / f"{m['index']}.npy")
        if tuple(arr.shape) != tuple(ref_leaf.shape):
            raise ValueError(
                f"{key}: checkpoint shape {arr.shape} != {ref_leaf.shape}")
        target_dtype = ref_leaf.dtype
        if shard is not None:
            out.append(jax.device_put(arr.astype(target_dtype), shard))
        else:
            out.append(jax.numpy.asarray(arr, dtype=target_dtype))
    return jax.tree_util.tree_unflatten(treedef, out), step
