"""Model stack: configs, layers, MoE, SSM, and the Model assembly."""
from .config import ArchConfig, reduced
from .transformer import Model

__all__ = ["ArchConfig", "Model", "reduced"]
