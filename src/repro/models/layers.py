"""Model layers — pure-functional JAX (params are plain dict pytrees).

Every layer has an ``init_*(key, cfg) -> params`` and an apply function.
Stacked (per-layer-leading-dim) params are produced by the transformer via
vmapped init, and consumed through ``jax.lax.scan``.

Attention comes in three executable forms:
* ``flash_attention`` — chunked online-softmax over KV blocks (the pure-JAX
  oracle form; memory-bounded for 32k prefill). The Pallas TPU kernel in
  ``repro.kernels.flash_attention`` implements the same contract for real
  hardware; this module is what the CPU dry-run lowers.
* ``decode_attention`` — one query step against a (possibly windowed) cache.
* MLA variants (latent-compressed KV, absorbed-matmul decode).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from .config import ArchConfig

Params = dict
F32 = jnp.float32


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


def _init(key, shape, scale, dtype):
    return (scale * jax.random.normal(key, shape, dtype=F32)).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float) -> jax.Array:
    h = x.astype(F32)
    var = jnp.mean(h * h, axis=-1, keepdims=True)
    h = h * jax.lax.rsqrt(var + eps)
    return (h * p["scale"].astype(F32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding (llama-style half rotation)
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=F32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D) with D even; positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                       # (D/2,)
    angles = positions[..., None].astype(F32) * freqs        # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                      # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked flash attention (online softmax over KV blocks)
# ---------------------------------------------------------------------------


NEG_INF = -1e30


def _no_window(window) -> bool:
    """True iff window is statically known to mean 'full attention'."""
    return window is None or (isinstance(window, (int, float)) and window <= 0)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    kv_chunk: int = 1024, scale: float | None = None,
                    q_offset: int = 0) -> jax.Array:
    """Memory-bounded attention.

    q: (B, Sq, H, Dk)   k: (B, Skv, KH, Dk)   v: (B, Skv, KH, Dv)
    H must be a multiple of KH (GQA).  Never materializes (Sq, Skv) scores —
    scans over KV chunks with a running (max, denom, acc) triple.
    ``window`` > 0 restricts each query to the last ``window`` keys.
    ``q_offset`` is the absolute position of q[0] (for chunked prefill).
    """
    B, Sq, H, Dk = q.shape
    _, Skv, KH, _ = k.shape
    Dv = v.shape[-1]
    G = H // KH
    if scale is None:
        scale = 1.0 / math.sqrt(Dk)
    nchunks = -(-Skv // kv_chunk)
    pad = nchunks * kv_chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, nchunks, kv_chunk, KH, Dk)
    vc = v.reshape(B, nchunks, kv_chunk, KH, Dv)

    qg = q.reshape(B, Sq, KH, G, Dk)
    q_pos = q_offset + jnp.arange(Sq)

    def body(carry, inputs):
        m, l, acc = carry
        ci, k_i, v_i = inputs
        k_pos = ci * kv_chunk + jnp.arange(kv_chunk)
        # scores: (B, KH, G, Sq, C) — operands stay in model dtype (bf16);
        # the MXU accumulates in f32 via preferred_element_type, so no
        # explicit f32 upcast copies of Q/K hit HBM (§Perf iteration 1)
        s = jnp.einsum("bqhgd,bchd->bhgqc", qg, k_i,
                       preferred_element_type=F32) * scale
        mask = jnp.ones((Sq, kv_chunk), dtype=bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        mask &= k_pos[None, :] < Skv  # padding
        if not _no_window(window):
            # traced window: 0 means "full attention" (branchless for scans
            # over layers with heterogeneous windows, e.g. hymba)
            w_eff = jnp.where(jnp.asarray(window) > 0, window,
                              Skv + Sq + q_offset + 1)
            mask &= k_pos[None, :] > q_pos[:, None] - w_eff
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        # P is cast down to the V dtype for the PV matmul (what TPU flash
        # kernels do); accumulation stays f32
        pv = jnp.einsum("bhgqc,bchd->bhgqd", p.astype(v_i.dtype), v_i,
                        preferred_element_type=F32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KH, G, Sq), NEG_INF, dtype=F32)
    l0 = jnp.zeros((B, KH, G, Sq), dtype=F32)
    a0 = jnp.zeros((B, KH, G, Sq, Dv), dtype=F32)
    # named_scope tags the lowered while-loop: on a TPU deployment this loop
    # IS the Pallas flash kernel (scores/probs/carries stay in VMEM), so the
    # roofline accounting separates its HBM traffic (see launch/hlo_costs).
    with jax.named_scope("flash_attention"):
        (m, l, acc), _ = jax.lax.scan(
            body, (m0, l0, a0),
            (jnp.arange(nchunks), jnp.moveaxis(kc, 1, 0),
             jnp.moveaxis(vc, 1, 0)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.moveaxis(out, 3, 1).reshape(B, Sq, H, Dv)  # (B,KH,G,Sq,Dv)->(B,Sq,KH*G,Dv)
    return out.astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     length: jax.Array, *, window: int = 0,
                     scale: float | None = None) -> jax.Array:
    """One decode step.  q: (B, 1, H, Dk); caches: (B, S, KH, D*).

    ``length`` = number of valid cache entries (the new token's K/V must
    already be written).  Masked full-cache attention — O(S) per step.
    """
    B, _, H, Dk = q.shape
    _, S, KH, _ = k_cache.shape
    G = H // KH
    if scale is None:
        scale = 1.0 / math.sqrt(Dk)
    qg = q.reshape(B, KH, G, Dk)
    # Match q's sharding to the cache (KH or head_dim over "model") so the
    # score contraction stays shard-local with a tiny psum of (B,KH,G,S)
    # scores — otherwise XLA all-gathers + upcasts the whole KV cache per
    # decode step (§Perf iteration 3).
    from ..distributed.context import constrain, current_mesh
    mesh = current_mesh()
    if mesh is not None and "model" in mesh.axis_names:
        n = mesh.shape["model"]
        if KH % n == 0 and KH >= n:
            qg = constrain(qg, None, "model", None, None)
        elif Dk % n == 0:
            qg = constrain(qg, None, None, None, "model")
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache,
                   preferred_element_type=F32) * scale
    pos = jnp.arange(S)
    mask = pos < length
    if not _no_window(window):
        w_eff = jnp.where(jnp.asarray(window) > 0, window, S + 1)
        mask = mask & (pos >= length - w_eff)
    s = jnp.where(mask[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=F32)
    return out.reshape(B, 1, H, -1).astype(q.dtype)


# ---------------------------------------------------------------------------
# Standard GQA attention block (covers MHA as KH == H)
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ArchConfig) -> Params:
    dt = _dtype(cfg)
    d, H, KH, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    sc = 0.02
    out_sc = 0.02 / math.sqrt(2 * cfg.num_layers)
    p = {
        "wq": _init(ks[0], (d, H, hd), sc, dt),
        "wk": _init(ks[1], (d, KH, hd), sc, dt),
        "wv": _init(ks[2], (d, KH, hd), sc, dt),
        "wo": _init(ks[3], (H, hd, d), out_sc, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dtype=dt)
        p["bk"] = jnp.zeros((KH, hd), dtype=dt)
        p["bv"] = jnp.zeros((KH, hd), dtype=dt)
    return p


def attention_qkv(p: Params, x: jax.Array, positions: jax.Array,
                  cfg: ArchConfig):
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    k = jnp.einsum("bsd,dhe->bshe", x, p["wk"])
    v = jnp.einsum("bsd,dhe->bshe", x, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_block(p: Params, x: jax.Array, cfg: ArchConfig, *,
                    window: int = 0, kv_chunk: int | None = None,
                    return_kv: bool = False):
    """Full-sequence (training / prefill) attention."""
    B, S, _ = x.shape
    kv_chunk = kv_chunk or cfg.attn_kv_chunk or S
    positions = jnp.arange(S)[None, :]
    q, k, v = attention_qkv(p, x, positions, cfg)
    o = flash_attention(q, k, v, causal=True, window=window, kv_chunk=kv_chunk)
    out = jnp.einsum("bshe,hed->bsd", o, p["wo"])
    if return_kv:
        return out, {"k": k, "v": v}
    return out


def attention_decode(p: Params, x: jax.Array, cache: dict, cfg: ArchConfig, *,
                     window: int = 0) -> tuple[jax.Array, dict]:
    """x: (B, 1, d).  cache: {"k": (B,S,KH,hd), "v": ..., } + global "pos"."""
    pos = cache["pos"]
    positions = jnp.full((x.shape[0], 1), pos)
    q, k, v = attention_qkv(p, x, positions, cfg)
    kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, pos, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, pos, axis=1)
    o = decode_attention(q, kc, vc, pos + 1, window=window)
    out = jnp.einsum("bshe,hed->bsd", o, p["wo"])
    return out, {"k": kc, "v": vc}


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (MiniCPM3 / DeepSeek-V2 style)
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ArchConfig) -> Params:
    dt = _dtype(cfg)
    d, H = cfg.d_model, cfg.num_heads
    nope, rope, vdim = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    ks = jax.random.split(key, 6)
    sc = 0.02
    out_sc = 0.02 / math.sqrt(2 * cfg.num_layers)
    return {
        "wq_a": _init(ks[0], (d, qr), sc, dt),                    # down
        "wq_b": _init(ks[1], (qr, H, nope + rope), sc, dt),       # up
        "wkv_a": _init(ks[2], (d, kvr + rope), sc, dt),           # latent + shared rope key
        "wk_b": _init(ks[3], (kvr, H, nope), sc, dt),
        "wv_b": _init(ks[4], (kvr, H, vdim), sc, dt),
        "wo": _init(ks[5], (H, vdim, d), out_sc, dt),
        "q_norm": init_rmsnorm(qr, dt),
        "kv_norm": init_rmsnorm(kvr, dt),
    }


def _mla_q(p, x, positions, cfg: ArchConfig):
    nope, rope = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    cq = rmsnorm(p["q_norm"], jnp.einsum("bsd,dr->bsr", x, p["wq_a"]),
                 cfg.norm_eps)
    q = jnp.einsum("bsr,rhe->bshe", cq, p["wq_b"])
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(p, x, positions, cfg: ArchConfig):
    kvr, rope = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    kv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    ckv = rmsnorm(p["kv_norm"], kv[..., :kvr], cfg.norm_eps)
    k_rope = kv[..., None, kvr:]                               # (B,S,1,rope)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)
    return ckv, k_rope[..., 0, :]


def mla_block(p: Params, x: jax.Array, cfg: ArchConfig, *,
              kv_chunk: int | None = None, return_kv: bool = False):
    """Prefill/training MLA: expand latent to per-head K/V, flash over chunks.

    K per head = [W_kb·c ; k_rope(shared)]; V per head = W_vb·c.
    """
    B, S, _ = x.shape
    H = cfg.num_heads
    nope, rope, vdim = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kv_chunk = kv_chunk or cfg.attn_kv_chunk or S
    positions = jnp.arange(S)[None, :]
    q_nope, q_rope = _mla_q(p, x, positions, cfg)
    ckv, k_rope = _mla_latent(p, x, positions, cfg)
    # expanded keys/values (B,S,H,nope+rope) / (B,S,H,vdim)
    k_nope = jnp.einsum("bsr,rhe->bshe", ckv, p["wk_b"])
    v = jnp.einsum("bsr,rhe->bshe", ckv, p["wv_b"])
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, rope))],
        axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    o = flash_attention(q, k, v, causal=True, kv_chunk=kv_chunk,
                        scale=1.0 / math.sqrt(nope + rope))
    out = jnp.einsum("bshe,hed->bsd", o, p["wo"])
    if return_kv:
        return out, {"ckv": ckv, "krope": k_rope}
    return out


def mla_decode(p: Params, x: jax.Array, cache: dict, cfg: ArchConfig
               ) -> tuple[jax.Array, dict]:
    """Absorbed-matmul MLA decode: score against the *latent* cache.

    score = (q_nope·W_kb)·c + q_rope·k_rope ;  out = (attn·c)·W_vb — the
    cache stores only (kv_lora + rope) per position (the MLA memory win).
    """
    B = x.shape[0]
    pos = cache["pos"]
    kvr, rope = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    positions = jnp.full((B, 1), pos)
    q_nope, q_rope = _mla_q(p, x, positions, cfg)       # (B,1,H,nope/rope)
    ckv_t, k_rope_t = _mla_latent(p, x, positions, cfg)  # (B,1,kvr), (B,1,rope)
    ckv = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv_t, pos, axis=1)
    kr = jax.lax.dynamic_update_slice_in_dim(cache["krope"], k_rope_t, pos, axis=1)
    # absorb: q_lat (B,1,H,kvr)
    q_lat = jnp.einsum("bqhe,rhe->bqhr", q_nope, p["wk_b"])
    # keep the latent contraction shard-local (cache kvr dim is sharded
    # over "model"); same reasoning as decode_attention (§Perf iteration 3)
    from ..distributed.context import constrain, current_mesh
    mesh = current_mesh()
    if mesh is not None and "model" in mesh.axis_names:
        n = mesh.shape["model"]
        if kvr % n == 0:
            q_lat = constrain(q_lat, None, None, None, "model")
    s = (jnp.einsum("bqhr,bsr->bhqs", q_lat, ckv,
                    preferred_element_type=F32)
         + jnp.einsum("bqhe,bse->bhqs", q_rope, kr,
                      preferred_element_type=F32))
    s = s * (1.0 / math.sqrt(cfg.qk_nope_head_dim + rope))
    S = ckv.shape[1]
    mask = jnp.arange(S) < pos + 1
    s = jnp.where(mask[None, None, None, :], s, NEG_INF)
    pattn = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhqs,bsr->bqhr", pattn, ckv.astype(F32))  # (B,1,H,kvr)
    o = jnp.einsum("bqhr,rhe->bqhe", o_lat.astype(x.dtype), p["wv_b"])
    out = jnp.einsum("bshe,hed->bsd", o, p["wo"])
    return out, {"ckv": ckv, "krope": kr}


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ArchConfig, d_ff: int | None = None) -> Params:
    dt = _dtype(cfg)
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    out_sc = 0.02 / math.sqrt(2 * cfg.num_layers)
    return {
        "wi": _init(ks[0], (d, ff), 0.02, dt),
        "wg": _init(ks[1], (d, ff), 0.02, dt),
        "wo": _init(ks[2], (ff, d), out_sc, dt),
    }


def mlp_block(p: Params, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["wg"]))
    h = h * jnp.einsum("bsd,df->bsf", x, p["wi"])
    return jnp.einsum("bsf,fd->bsd", h, p["wo"])
