"""Mamba-2 / SSD (state-space duality) layer — chunked train/prefill scan and
O(1)-per-token recurrent decode.  Pure JAX (einsum + associative_scan); the
chunk-local quadratic part is MXU-friendly by construction (Q×Q matmuls).

Follows "Transformers are SSDs" (arXiv:2405.21060) §6 chunked algorithm:
  y = SSD(x, A, B, C) with per-head scalar decay A, grouped B/C (G groups).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import Params, _init, _dtype, init_rmsnorm, rmsnorm

F32 = jnp.float32


def init_ssm(key, cfg: ArchConfig) -> Params:
    dt = _dtype(cfg)
    d, di = cfg.d_model, cfg.d_inner
    nh, ds, G = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_groups
    conv_dim = di + 2 * G * ds
    ks = jax.random.split(key, 6)
    out_sc = 0.02 / math.sqrt(2 * cfg.num_layers)
    return {
        # fused input projection: [z, x, B, C, dt]
        "w_in": _init(ks[0], (d, 2 * di + 2 * G * ds + nh), 0.02, dt),
        "conv_w": _init(ks[1], (cfg.ssm_conv, conv_dim), 0.2, dt),
        "conv_b": jnp.zeros((conv_dim,), dtype=dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(F32),
        "D": jnp.ones((nh,), dtype=F32),
        "dt_bias": jnp.zeros((nh,), dtype=F32),
        "norm": init_rmsnorm(di, dt),
        "w_out": _init(ks[2], (di, d), out_sc, dt),
    }


def _split_proj(p: Params, x: jax.Array, cfg: ArchConfig):
    di, ds, G, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_groups, cfg.ssm_heads
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["w_in"])
    z, xBC, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * G * ds], axis=-1)
    return z, xBC, dt


def _causal_conv(p: Params, xBC: jax.Array) -> jax.Array:
    """Depthwise causal conv over time; xBC: (B, S, conv_dim)."""
    w = p["conv_w"].astype(F32)                     # (K, conv_dim)
    K = w.shape[0]
    xp = jnp.pad(xBC.astype(F32), ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + xBC.shape[1], :] * w[i] for i in range(K))
    return jax.nn.silu(out + p["conv_b"].astype(F32)).astype(xBC.dtype)


def _heads(cfg: ArchConfig, xBC: jax.Array):
    di, ds, G = cfg.d_inner, cfg.ssm_state, cfg.ssm_groups
    xh, B, C = jnp.split(xBC, [di, di + G * ds], axis=-1)
    b, s = xh.shape[:2]
    xh = xh.reshape(b, s, cfg.ssm_heads, cfg.ssm_head_dim)
    B = B.reshape(b, s, G, ds)
    C = C.reshape(b, s, G, ds)
    return xh, B, C


def ssd_scan(xh, B, C, dt, A, *, chunk: int):
    """Chunked SSD.  xh: (b,S,nh,hp)  B,C: (b,S,G,ds)  dt: (b,S,nh)  A: (nh,).

    Heads are split evenly over the G groups.  Returns y: (b,S,nh,hp) and the
    final state (b,nh,hp,ds).
    """
    b, S, nh, hp = xh.shape
    G, ds = B.shape[2], B.shape[3]
    hg = nh // G
    Q = min(chunk, S)
    NC = -(-S // Q)
    pad = NC * Q - S
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    xc = xh.reshape(b, NC, Q, nh, hp).astype(F32)
    Bc = B.reshape(b, NC, Q, G, ds).astype(F32)
    Cc = C.reshape(b, NC, Q, G, ds).astype(F32)
    dtc = dt.reshape(b, NC, Q, nh).astype(F32)

    dA = dtc * A[None, None, None, :]                 # (b,NC,Q,nh) negative
    cum = jnp.cumsum(dA, axis=2)                      # within-chunk cumsum
    seg_end = cum[:, :, -1, :]                        # (b,NC,nh)

    # --- intra-chunk (quadratic within Q) ---
    # decay L[q, t] = exp(cum_q - cum_t) for q >= t
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (b,NC,Q,Q,nh)
    causal = jnp.tril(jnp.ones((Q, Q), dtype=bool))
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(diff), 0.0)
    CB = jnp.einsum("bnqgs,bntgs->bnqtg", Cc, Bc)          # (b,NC,Q,Q,G)
    CB = jnp.repeat(CB, hg, axis=-1)                       # (b,NC,Q,Q,nh)
    M = CB * L
    xdt = xc * dtc[..., None]                              # (b,NC,Q,nh,hp)
    y_intra = jnp.einsum("bnqth,bnthp->bnqhp", M, xdt)

    # --- chunk states ---
    decay_to_end = jnp.exp(seg_end[:, :, None, :] - cum)   # (b,NC,Q,nh)
    Bc_h = jnp.repeat(Bc, hg, axis=3) if G != nh else Bc   # (b,NC,Q,nh,ds)
    states = jnp.einsum("bnths,bnthp->bnhps",
                        Bc_h, xdt * decay_to_end[..., None])

    # --- inter-chunk recurrence: H_{c} = H_{c-1} * exp(seg_end_c) + S_c ---
    seg_decay = jnp.exp(seg_end)                           # (b,NC,nh)

    def combine(a, bb):
        d1, s1 = a
        d2, s2 = bb
        return d1 * d2, s1 * d2[..., None, None] + s2

    dec_scan, st_scan = jax.lax.associative_scan(
        combine, (seg_decay, states), axis=1)
    # H_prev for chunk c = state after chunk c-1
    H_prev = jnp.concatenate(
        [jnp.zeros_like(st_scan[:, :1]), st_scan[:, :-1]], axis=1)

    # --- inter-chunk output: y_t += C_t · (exp(cum_t) * H_prev) ---
    Cc_h = jnp.repeat(Cc, hg, axis=3) if G != nh else Cc   # (b,NC,Q,nh,ds)
    y_inter = jnp.einsum("bnths,bnhps->bnthp", Cc_h,
                         H_prev) * jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(b, NC * Q, nh, hp)[:, :S]
    final_state = st_scan[:, -1]                           # (b,nh,hp,ds)
    return y, final_state


def ssm_block(p: Params, x: jax.Array, cfg: ArchConfig, *,
              return_state: bool = False):
    """Training / prefill forward.  x: (B, S, d_model)."""
    z, xBC_raw, dt = _split_proj(p, x, cfg)
    xBC = _causal_conv(p, xBC_raw)
    xh, B, C = _heads(cfg, xBC)
    A = -jnp.exp(p["A_log"])
    dt_s = jax.nn.softplus(dt.astype(F32) + p["dt_bias"])
    y, state = ssd_scan(xh, B, C, dt_s, A, chunk=cfg.ssm_chunk)
    y = y + xh.astype(F32) * p["D"][None, None, :, None]
    y = y.reshape(x.shape[0], x.shape[1], cfg.d_inner).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    if return_state:
        K = cfg.ssm_conv
        conv_tail = xBC_raw[:, -(K - 1):, :]   # pre-activation window tail
        if x.shape[1] < K - 1:
            conv_tail = jnp.pad(
                xBC_raw, ((0, 0), (K - 1 - x.shape[1], 0), (0, 0)))
        return out, {"state": state, "conv": conv_tail}
    return out


def init_ssm_cache(cfg: ArchConfig, batch: int, dtype) -> dict:
    conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return {
        "state": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                            cfg.ssm_state), dtype=F32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype=dtype),
    }


def ssm_decode(p: Params, x: jax.Array, cache: dict, cfg: ArchConfig
               ) -> tuple[jax.Array, dict]:
    """One-token recurrent step.  x: (B, 1, d_model)."""
    b = x.shape[0]
    nh, hp, ds, G = (cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state,
                     cfg.ssm_groups)
    z, xBC, dt = _split_proj(p, x, cfg)
    # conv with rolling cache
    window = jnp.concatenate([cache["conv"], xBC], axis=1)   # (B, K, conv)
    w = p["conv_w"].astype(F32)
    conv_out = (window.astype(F32) * w[None]).sum(axis=1) + p["conv_b"].astype(F32)
    xBC_t = jax.nn.silu(conv_out)[:, None, :].astype(x.dtype)
    new_conv = window[:, 1:]

    xh, B, C = _heads(cfg, xBC_t)
    xh, B, C = xh[:, 0], B[:, 0], C[:, 0]                    # (B,nh,hp),(B,G,ds)
    hg = nh // G
    B_h = jnp.repeat(B, hg, axis=1).astype(F32)              # (B,nh,ds)
    C_h = jnp.repeat(C, hg, axis=1).astype(F32)
    A = -jnp.exp(p["A_log"])
    dt_s = jax.nn.softplus(dt[:, 0].astype(F32) + p["dt_bias"])  # (B,nh)
    dA = jnp.exp(dt_s * A[None])                             # (B,nh)
    upd = jnp.einsum("bhp,bhs->bhps", xh.astype(F32) * dt_s[..., None], B_h)
    state = cache["state"] * dA[..., None, None] + upd
    y = jnp.einsum("bhps,bhs->bhp", state, C_h)
    y = y + xh.astype(F32) * p["D"][None, :, None]
    y = y.reshape(b, 1, cfg.d_inner).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    return out, {"state": state, "conv": new_conv}
