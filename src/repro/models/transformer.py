"""Model assembly: embedding → scanned blocks → norm → chunked-xent loss,
plus the KV-cache decode path.  One ``Model`` class covers all 10 assigned
families (dense / MoE / SSM / hybrid / VLM / audio) — family differences are
config-driven.

Layers are *stacked* (leading ``num_layers`` dim on every leaf) and consumed
by ``jax.lax.scan`` so a 95-layer model lowers as one block body — essential
for the 80-compile dry-run.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from ..distributed.context import (batch_axes, constrain, constrain_batch,
                                   constrain_tokens, current_mesh)
from .config import ArchConfig
from .layers import (Params, _dtype, _init, attention_block, attention_decode,
                     init_attention, init_mla, init_mlp, init_rmsnorm,
                     mla_block, mla_decode, mlp_block, rmsnorm)
from .moe import capacity_for, init_moe, moe_block
from .ssm import init_ssm, init_ssm_cache, ssm_block, ssm_decode

F32 = jnp.float32


# ---------------------------------------------------------------------------
# Per-layer block
# ---------------------------------------------------------------------------


def init_block(key, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 6)
    dt = _dtype(cfg)
    p: Params = {"ln1": init_rmsnorm(cfg.d_model, dt)}
    if cfg.attention == "mla":
        p["attn"] = init_mla(ks[0], cfg)
    elif cfg.attention in ("gqa", "swa"):
        p["attn"] = init_attention(ks[0], cfg)
    if cfg.uses_ssm:
        p["ssm"] = init_ssm(ks[1], cfg)
        if cfg.family == "hybrid":
            p["ln_attn_out"] = init_rmsnorm(cfg.d_model, dt)
            p["ln_ssm_out"] = init_rmsnorm(cfg.d_model, dt)
    if cfg.uses_moe:
        p["ln2"] = init_rmsnorm(cfg.d_model, dt)
        p["moe"] = init_moe(ks[2], cfg)
    elif cfg.d_ff:
        p["ln2"] = init_rmsnorm(cfg.d_model, dt)
        p["mlp"] = init_mlp(ks[3], cfg)
    return p


def block_forward(p: Params, x: jax.Array, cfg: ArchConfig, *,
                  window) -> jax.Array:
    """window: 0/int for static, or a traced scalar (hybrid per-layer)."""
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.family == "hybrid":
        a = attention_block(p["attn"], h, cfg, window=window)
        s = ssm_block(p["ssm"], h, cfg)
        a = rmsnorm(p["ln_attn_out"], a, cfg.norm_eps)
        s = rmsnorm(p["ln_ssm_out"], s, cfg.norm_eps)
        x = x + 0.5 * (a + s)
    elif cfg.uses_ssm:
        x = x + ssm_block(p["ssm"], x=h, cfg=cfg)
    elif cfg.attention == "mla":
        x = x + mla_block(p["attn"], h, cfg)
    else:
        x = x + attention_block(p["attn"], h, cfg, window=window)
    x = constrain_tokens(x, seq_shard=cfg.seq_shard_activations)
    if cfg.uses_moe:
        h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
        x = x + moe_block(p["moe"], h2, cfg, mesh=current_mesh(),
                          batch_axes=batch_axes() or ("data",))
    elif cfg.d_ff:
        h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
        x = x + mlp_block(p["mlp"], h2)
    return constrain_tokens(x, seq_shard=cfg.seq_shard_activations)


def block_prefill(p: Params, x: jax.Array, cfg: ArchConfig, *,
                  window) -> tuple[jax.Array, dict]:
    """block_forward that also emits this layer's decode-cache entry."""
    entry: dict = {}
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.family == "hybrid":
        a, kv = attention_block(p["attn"], h, cfg, window=window,
                                return_kv=True)
        s, st = ssm_block(p["ssm"], h, cfg, return_state=True)
        a = rmsnorm(p["ln_attn_out"], a, cfg.norm_eps)
        s = rmsnorm(p["ln_ssm_out"], s, cfg.norm_eps)
        x = x + 0.5 * (a + s)
        entry.update(kv)
        entry.update(st)
    elif cfg.uses_ssm:
        s, st = ssm_block(p["ssm"], h, cfg, return_state=True)
        x = x + s
        entry.update(st)
    elif cfg.attention == "mla":
        a, kv = mla_block(p["attn"], h, cfg, return_kv=True)
        x = x + a
        entry.update(kv)
    else:
        a, kv = attention_block(p["attn"], h, cfg, window=window,
                                return_kv=True)
        x = x + a
        entry.update(kv)
    x = constrain_batch(x)
    if cfg.uses_moe:
        h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
        x = x + moe_block(p["moe"], h2, cfg, mesh=current_mesh(),
                          batch_axes=batch_axes() or ("data",))
    elif cfg.d_ff:
        h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
        x = x + mlp_block(p["mlp"], h2)
    return constrain_batch(x), entry


def block_decode(p: Params, x: jax.Array, cache: dict, cfg: ArchConfig, *,
                 window) -> tuple[jax.Array, dict]:
    new_cache = dict(cache)
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.family == "hybrid":
        a, ac = attention_decode(p["attn"], h, cache, cfg, window=window)
        s, sc = ssm_decode(p["ssm"], h, cache, cfg)
        a = rmsnorm(p["ln_attn_out"], a, cfg.norm_eps)
        s = rmsnorm(p["ln_ssm_out"], s, cfg.norm_eps)
        x = x + 0.5 * (a + s)
        new_cache.update(ac)
        new_cache.update(sc)
    elif cfg.uses_ssm:
        s, sc = ssm_decode(p["ssm"], h, cache, cfg)
        x = x + s
        new_cache.update(sc)
    elif cfg.attention == "mla":
        a, ac = mla_decode(p["attn"], h, cache, cfg)
        x = x + a
        new_cache.update(ac)
    else:
        a, ac = attention_decode(p["attn"], h, cache, cfg, window=window)
        x = x + a
        new_cache.update(ac)
    if cfg.uses_moe:
        h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
        x = x + moe_block(p["moe"], h2, cfg, mesh=current_mesh(),
                          batch_axes=batch_axes() or ("data",))
    elif cfg.d_ff:
        h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
        x = x + mlp_block(p["mlp"], h2)
    return x, new_cache


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


def _layer_windows(cfg: ArchConfig) -> jnp.ndarray:
    """Per-layer window sizes: 0 = full attention."""
    if cfg.attention != "swa" or not cfg.window:
        return jnp.zeros((cfg.num_layers,), dtype=jnp.int32)
    w = [0 if i in set(cfg.global_layers) else cfg.window
         for i in range(cfg.num_layers)]
    return jnp.asarray(w, dtype=jnp.int32)


def _sub_cfgs(cfg: ArchConfig) -> list[ArchConfig]:
    """Per-scan-step sub-layer configs (llama4: [dense, moe] per group)."""
    if cfg.uses_moe and cfg.moe_every > 1:
        dense = dataclasses.replace(cfg, num_experts=0, shared_expert_ff=0)
        return [dense] * (cfg.moe_every - 1) + [cfg]
    return [cfg]


def _n_groups(cfg: ArchConfig) -> int:
    g = len(_sub_cfgs(cfg))
    assert cfg.num_layers % g == 0, (cfg.num_layers, g)
    return cfg.num_layers // g


def init_group(key, cfg: ArchConfig) -> Params:
    subs = _sub_cfgs(cfg)
    if len(subs) == 1:
        return init_block(key, cfg)
    ks = jax.random.split(key, len(subs))
    return {f"s{i}": init_block(k, sc) for i, (k, sc) in enumerate(zip(ks, subs))}


def group_forward(p: Params, x: jax.Array, cfg: ArchConfig, *,
                  window) -> jax.Array:
    subs = _sub_cfgs(cfg)
    if len(subs) == 1:
        return block_forward(p, x, cfg, window=window)
    for i, sc in enumerate(subs):
        x = block_forward(p[f"s{i}"], x, sc, window=window)
    return x


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    # ---- init ------------------------------------------------------------

    def init(self, key) -> Params:
        cfg = self.cfg
        dt = _dtype(cfg)
        k_emb, k_head, k_layers, k_front = jax.random.split(key, 4)
        layer_keys = jax.random.split(k_layers, _n_groups(cfg))
        layers = jax.vmap(lambda k: init_group(k, cfg))(layer_keys)
        p: Params = {
            "embed": _init(k_emb, (cfg.vocab_size, cfg.d_model), 0.02, dt),
            "final_norm": init_rmsnorm(cfg.d_model, dt),
            "layers": layers,
        }
        if not cfg.tie_embeddings:
            p["lm_head"] = _init(k_head, (cfg.d_model, cfg.vocab_size),
                                 0.02, dt)
        if cfg.frontend != "none":
            p["adapter"] = _init(k_front, (cfg.d_model, cfg.d_model),
                                 0.02, dt)
        return p

    # ---- forward ----------------------------------------------------------

    def embed_inputs(self, params: Params, batch: dict) -> jax.Array:
        cfg = self.cfg
        if cfg.frontend != "none":
            x = batch["embeds"].astype(_dtype(cfg))
            x = jnp.einsum("bsd,de->bse", x, params["adapter"])
        else:
            x = jnp.take(params["embed"], batch["tokens"], axis=0)
        return constrain_batch(x)

    def hidden_states(self, params: Params, batch: dict) -> jax.Array:
        cfg = self.cfg
        x = self.embed_inputs(params, batch)
        swa = cfg.attention == "swa" and cfg.window > 0

        def body(carry, xs):
            if swa:
                lp, w = xs
            else:
                lp, w = xs, 0
            out = group_forward(lp, carry, cfg, window=w)
            return out, None

        if cfg.remat == "full":
            body = jax.checkpoint(body, prevent_cse=False)
        elif cfg.remat == "dots":
            body = jax.checkpoint(
                body, prevent_cse=False,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        xs = (params["layers"], _layer_windows(cfg)) if swa else params["layers"]
        x, _ = jax.lax.scan(body, x, xs)
        return rmsnorm(params["final_norm"], x, cfg.norm_eps)

    def unembed(self, params: Params) -> jax.Array:
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["lm_head"]

    def loss(self, params: Params, batch: dict) -> jax.Array:
        """Chunked softmax cross-entropy (never materializes (T, V) logits)."""
        cfg = self.cfg
        h = self.hidden_states(params, batch)
        B, S, d = h.shape
        labels = batch["labels"]
        w_head = self.unembed(params)
        hf = h.reshape(B * S, d)
        lf = labels.reshape(B * S)
        chunk = min(cfg.loss_chunk, B * S)
        n = -(-hf.shape[0] // chunk)
        pad = n * chunk - hf.shape[0]
        if pad:
            hf = jnp.pad(hf, ((0, pad), (0, 0)))
            lf = jnp.pad(lf, (0, pad), constant_values=-1)
        hc = hf.reshape(n, chunk, d)
        lc = lf.reshape(n, chunk)

        def chunk_loss(carry, xs):
            hx, lx = xs
            # native-dtype operands + f32 accumulation: avoids converting
            # the (d, V) head to f32 once per chunk (§Perf iteration 1)
            logits = jnp.einsum("cd,dv->cv", hx, w_head,
                                preferred_element_type=F32)
            lse = jax.nn.logsumexp(logits, axis=-1)
            # label logit via masked sum — partitions cleanly when the vocab
            # dim is sharded (take_along_axis would all-gather the logits)
            vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
            ll = jnp.sum(jnp.where(vocab_iota == lx[:, None], logits, 0.0),
                         axis=-1)
            valid = (lx >= 0).astype(F32)
            loss_sum, count = carry
            return (loss_sum + ((lse - ll) * valid).sum(),
                    count + valid.sum()), None

        body = jax.checkpoint(chunk_loss, prevent_cse=False)
        (loss_sum, count), _ = jax.lax.scan(
            body, (jnp.zeros((), F32), jnp.zeros((), F32)), (hc, lc))
        return loss_sum / jnp.maximum(count, 1.0)

    def logits(self, params: Params, batch: dict) -> jax.Array:
        """Full logits — small inputs only (tests/examples)."""
        h = self.hidden_states(params, batch)
        return jnp.einsum("bsd,dv->bsv", h.astype(F32),
                          self.unembed(params).astype(F32))

    def prefill(self, params: Params, batch: dict) -> tuple[jax.Array, dict]:
        """Process a prompt, returning (last-token logits (B,V), decode cache).

        The cache length equals the prompt length; callers wanting headroom
        pad via ``extend_cache``.  MLA caches the latent; SSM caches the
        final recurrent state + conv tail — so decode continues exactly.
        """
        cfg = self.cfg
        x = self.embed_inputs(params, batch)
        swa = cfg.attention == "swa" and cfg.window > 0
        subs = _sub_cfgs(cfg)
        g = len(subs)

        def body(carry, xs):
            if swa:
                lp, w = xs
            else:
                lp, w = xs, 0
            if g == 1:
                out, entry = block_prefill(lp, carry, cfg, window=w)
                return out, entry
            out = carry
            entries = []
            for i, sc in enumerate(subs):
                out, e = block_prefill(lp[f"s{i}"], out, sc, window=w)
                entries.append(e)
            entry = {kk: jnp.stack([e[kk] for e in entries])
                     for kk in entries[0]}
            return out, entry

        xs = (params["layers"], _layer_windows(cfg)) if swa else params["layers"]
        x, cache = jax.lax.scan(body, x, xs)
        if g > 1:
            cache = {kk: vv.reshape(vv.shape[0] * g, *vv.shape[2:])
                     for kk, vv in cache.items()}
        S = x.shape[1]
        h = rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", h.astype(F32),
                            self.unembed(params).astype(F32))[:, 0]
        cache["pos"] = jnp.asarray(S, jnp.int32)
        return logits, cache

    @staticmethod
    def extend_cache(cache: dict, extra: int) -> dict:
        """Pad sequence-indexed cache entries by ``extra`` positions."""
        out = {}
        for kk, vv in cache.items():
            if kk in ("k", "v", "ckv", "krope"):
                pad = [(0, 0)] * vv.ndim
                pad[2] = (0, extra)
                out[kk] = jnp.pad(vv, pad)
            else:
                out[kk] = vv
        return out

    # ---- decode ------------------------------------------------------------

    def init_cache(self, batch: int, max_len: int) -> dict:
        cfg = self.cfg
        dt = _dtype(cfg)
        L = cfg.num_layers
        cache: dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
        if cfg.attention in ("gqa", "swa"):
            kvh, hd = cfg.num_kv_heads, cfg.head_dim
            cache["k"] = jnp.zeros((L, batch, max_len, kvh, hd), dtype=dt)
            cache["v"] = jnp.zeros((L, batch, max_len, kvh, hd), dtype=dt)
        elif cfg.attention == "mla":
            cache["ckv"] = jnp.zeros((L, batch, max_len, cfg.kv_lora_rank),
                                     dtype=dt)
            cache["krope"] = jnp.zeros(
                (L, batch, max_len, cfg.qk_rope_head_dim), dtype=dt)
        if cfg.uses_ssm:
            sc = init_ssm_cache(cfg, batch, dt)
            cache["state"] = jnp.broadcast_to(
                sc["state"], (L, *sc["state"].shape)).astype(F32)
            cache["conv"] = jnp.broadcast_to(
                sc["conv"], (L, *sc["conv"].shape)).astype(dt)
        return cache

    def decode_step(self, params: Params, cache: dict, batch: dict
                    ) -> tuple[jax.Array, dict]:
        """One token for every sequence.  batch: {"tokens": (B,1)} or
        {"embeds": (B,1,d)}.  Returns (logits (B,V), new cache)."""
        cfg = self.cfg
        x = self.embed_inputs(params, batch)
        swa = cfg.attention == "swa" and cfg.window > 0
        pos = cache["pos"]
        subs = _sub_cfgs(cfg)
        g = len(subs)
        ng = _n_groups(cfg)
        layer_caches = {
            kk: vv.reshape(ng, g, *vv.shape[1:]) if g > 1 else vv
            for kk, vv in cache.items() if kk != "pos"}

        def body(carry, xs):
            if swa:
                lp, lc, w = xs
            else:
                (lp, lc), w = xs, 0
            if g == 1:
                lc = dict(lc, pos=pos)
                out, nc = block_decode(lp, carry, lc, cfg, window=w)
                nc.pop("pos", None)
                return out, nc
            out = carry
            ncs = []
            for i, sc in enumerate(subs):
                lci = {kk: vv[i] for kk, vv in lc.items()}
                lci["pos"] = pos
                out, nci = block_decode(lp[f"s{i}"], out, lci, sc, window=w)
                nci.pop("pos", None)
                ncs.append(nci)
            nc = {kk: jnp.stack([c[kk] for c in ncs]) for kk in ncs[0]}
            return out, nc

        xs = ((params["layers"], layer_caches, _layer_windows(cfg)) if swa
              else (params["layers"], layer_caches))
        x, new_caches = jax.lax.scan(body, x, xs)
        if g > 1:
            new_caches = {kk: vv.reshape(ng * g, *vv.shape[2:])
                          for kk, vv in new_caches.items()}
        h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", h.astype(F32),
                            self.unembed(params).astype(F32))[:, 0]
        new_caches["pos"] = pos + 1
        return logits, new_caches
