"""Mixture-of-Experts layer — expert-parallel, sort-based local dispatch.

Design (see DESIGN.md §8): the dense one-hot dispatch einsum used by
GShard-style implementations costs O(tokens · E · capacity · d) FLOPs, which
at our assigned shapes exceeds the useful expert FLOPs by >10×.  Instead we
run the MoE FFN inside ``shard_map``:

* experts are sharded over the ``model`` mesh axis (EP), their weight
  matrices additionally sharded over ``data`` (ZeRO-3 style) and
  all-gathered just-in-time inside the body;
* tokens stay sharded over ``data`` (replicated over ``model``), each model
  shard selects+sorts the tokens routed to *its* experts (local argsort →
  static-capacity scatter), runs the grouped FFN, scatters results back and
  ``psum``s partial outputs over ``model``.

This keeps dispatch cost O(tokens·k·d) (gathers), expert compute perfectly
EP-parallel, and avoids global scatter ops that partition poorly under SPMD.
The same code runs un-sharded (single device) by calling ``moe_local`` with
the full expert range.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import Params, _init, _dtype


def _shard_map(f, *, mesh, in_specs, out_specs):
    """jax >= 0.6 exposes ``jax.shard_map`` (check_vma); older releases ship
    ``jax.experimental.shard_map.shard_map`` (check_rep)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)

F32 = jnp.float32


def init_moe(key, cfg: ArchConfig) -> Params:
    dt = _dtype(cfg)
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    out_sc = 0.02 / math.sqrt(2 * cfg.num_layers)
    p = {
        "router": _init(ks[0], (d, E), 0.02, F32),  # router kept in f32
        "w_in": _init(ks[1], (E, d, ff), 0.02, dt),
        "w_gate": _init(ks[2], (E, d, ff), 0.02, dt),
        "w_out": _init(ks[3], (E, ff, d), out_sc, dt),
    }
    if cfg.shared_expert_ff:
        from .layers import init_mlp
        p["shared"] = init_mlp(ks[4], cfg, d_ff=cfg.shared_expert_ff)
    return p


def capacity_for(tokens: int, cfg: ArchConfig) -> int:
    c = int(math.ceil(tokens * cfg.experts_per_token
                      * cfg.moe_capacity_factor / cfg.num_experts))
    return max(8, -(-c // 8) * 8)  # round up to 8 (sublane grain)


def moe_local(p: Params, x: jax.Array, cfg: ArchConfig, *,
              e_off, num_local: int, capacity: int
              ) -> tuple[jax.Array, jax.Array]:
    """Per-shard MoE FFN.  x: (T, d) local tokens; experts [e_off, e_off+n).

    Returns (partial_out (T, d), aux_counts (E,)).  ``e_off`` may be traced
    (derived from ``jax.lax.axis_index`` inside shard_map).
    """
    T, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    C = capacity

    logits = x.astype(F32) @ p["router"]                      # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, k)                    # (T, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    eid = top_i.reshape(-1)                                   # (T*k,)
    wgt = top_w.reshape(-1)
    tok = jnp.repeat(jnp.arange(T), k)

    local = (eid >= e_off) & (eid < e_off + num_local)
    # dustbin index = num_local for non-local / overflow slots
    eid_l = jnp.where(local, eid - e_off, num_local)
    order = jnp.argsort(eid_l, stable=True)
    eid_s, tok_s, wgt_s = eid_l[order], tok[order], wgt[order]

    counts = jnp.bincount(eid_s, length=num_local + 1)        # (n+1,)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(eid_s.size) - starts[eid_s]
    keep = (pos < C) & (eid_s < num_local)
    slot_e = jnp.where(keep, eid_s, num_local)
    slot_c = jnp.where(keep, pos, 0)

    buf = jnp.zeros((num_local + 1, C, d), dtype=x.dtype)
    buf = buf.at[slot_e, slot_c].set(x[tok_s], mode="drop")
    xe = buf[:num_local]                                      # (n, C, d)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", xe, p["w_in"])
    y = jnp.einsum("ecf,efd->ecd", h, p["w_out"])             # (n, C, d)

    contrib = y[slot_e.clip(0, num_local - 1), slot_c]
    contrib = jnp.where(keep[:, None], contrib, 0.0)
    out = jnp.zeros((T, d), dtype=F32)
    out = out.at[tok_s].add(contrib.astype(F32) * wgt_s[:, None])

    # aux statistics for the load-balancing loss (global expert ids)
    full_counts = jnp.bincount(eid, length=E).astype(F32)
    return out.astype(x.dtype), full_counts


def moe_block(p: Params, x: jax.Array, cfg: ArchConfig, mesh=None,
              batch_axes: tuple = ("data",), model_axis: str = "model"
              ) -> jax.Array:
    """(B, S, d) -> (B, S, d).  Uses shard_map when a mesh is provided."""
    B, S, d = x.shape
    E = cfg.num_experts

    if mesh is None or model_axis not in mesh.axis_names:
        flat = x.reshape(B * S, d)
        out, _ = moe_local(p, flat, cfg, e_off=0, num_local=E,
                           capacity=capacity_for(B * S, cfg))
        out = out.reshape(B, S, d)
    else:
        from jax.sharding import PartitionSpec as P
        n_model = mesh.shape[model_axis]
        n_data = math.prod(mesh.shape[a] for a in batch_axes)
        num_local = max(E // n_model, 1)
        t_local = max((B + n_data - 1) // n_data * S, 1)
        cap = capacity_for(t_local, cfg)
        fsdp_axis = "data" if "data" in mesh.axis_names else None

        def body(router, w_in, w_gate, w_out, xb):
            if fsdp_axis is not None:
                w_in = jax.lax.all_gather(w_in, fsdp_axis, axis=1, tiled=True)
                w_gate = jax.lax.all_gather(w_gate, fsdp_axis, axis=1, tiled=True)
                w_out = jax.lax.all_gather(w_out, fsdp_axis, axis=2, tiled=True)
            pl = {"router": router, "w_in": w_in, "w_gate": w_gate,
                  "w_out": w_out}
            bl, sl = xb.shape[0], xb.shape[1]
            e_off = jax.lax.axis_index(model_axis) * num_local
            out, _ = moe_local(pl, xb.reshape(bl * sl, d), cfg,
                               e_off=e_off, num_local=num_local, capacity=cap)
            out = jax.lax.psum(out, model_axis)
            return out.reshape(bl, sl, d)

        wspec = P(model_axis, fsdp_axis, None)
        wospec = P(model_axis, None, fsdp_axis)
        xspec = P(batch_axes, None, None)
        out = _shard_map(
            body, mesh=mesh,
            in_specs=(P(None, None), wspec, wspec, wospec, xspec),
            out_specs=xspec,
        )(p["router"], p["w_in"], p["w_gate"], p["w_out"], x)

    if "shared" in p:
        from .layers import mlp_block
        out = out + mlp_block(p["shared"], x)
    return out
