"""Architecture configuration — one frozen dataclass drives every model."""
from __future__ import annotations

import dataclasses
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int                # query heads (0 for attention-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // num_heads

    # attention flavour
    attention: str = "gqa"        # gqa | mla | swa | none
    qkv_bias: bool = False
    window: int = 0               # sliding-window size (swa); 0 = full
    global_layers: Sequence[int] = ()  # swa archs: layers with full attention

    # MLA (DeepSeek/MiniCPM3 style multi-head latent attention)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    shared_expert_ff: int = 0     # llama4-style always-on shared expert
    moe_every: int = 1            # MoE on every Nth layer (llama4: 2), dense
                                  # SwiGLU (d_ff) on the rest

    # SSM (Mamba-2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_conv: int = 4
    ssm_groups: int = 1

    # modality frontend (stubbed per assignment: input_specs() provides
    # precomputed patch/frame embeddings)
    frontend: str = "none"        # none | vlm_stub | audio_stub

    # numerics / training
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    remat: str = "full"           # none | full | dots
    loss_chunk: int = 1024        # tokens per chunked-xent slab
    tie_embeddings: bool = False

    # distribution/perf knobs (§Perf hillclimb; defaults = paper-baseline)
    attn_kv_chunk: int = 1024     # flash KV block
    seq_shard_activations: bool = False  # Megatron-SP style: shard the
                                         # residual stream's seq dim over
                                         # "model" between blocks

    def __post_init__(self):
        if self.num_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ---- derived quantities ------------------------------------------------

    @property
    def is_attention_free(self) -> bool:
        return self.attention == "none"

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    @property
    def uses_ssm(self) -> bool:
        return self.ssm_state > 0

    @property
    def uses_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the 524k long-context decode shape."""
        return self.uses_ssm or (self.attention == "swa")

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for 6ND math."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        total = V * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if not self.is_attention_free:
            if self.attention == "mla":
                qr = self.q_lora_rank or d
                per_layer += d * qr + qr * self.num_heads * (
                    self.qk_nope_head_dim + self.qk_rope_head_dim)
                per_layer += d * (self.kv_lora_rank + self.qk_rope_head_dim)
                per_layer += self.kv_lora_rank * self.num_heads * (
                    self.qk_nope_head_dim + self.v_head_dim)
                per_layer += self.num_heads * self.v_head_dim * d
            else:
                hd = self.head_dim
                per_layer += d * self.num_heads * hd          # Wq
                per_layer += 2 * d * self.num_kv_heads * hd   # Wk, Wv
                per_layer += self.num_heads * hd * d          # Wo
        if self.uses_ssm:
            di, ds = self.d_inner, self.ssm_state
            per_layer += d * (2 * di + 2 * self.ssm_groups * ds + self.ssm_heads)
            per_layer += di * d
        moe_layers = (L // self.moe_every) if self.uses_moe else 0
        if self.uses_moe:
            moe_per_layer = d * self.num_experts               # router
            moe_per_layer += self.num_experts * 3 * d * self.d_ff
            if self.shared_expert_ff:
                moe_per_layer += 3 * d * self.shared_expert_ff
            dense_per_layer = 3 * d * self.d_ff                # interleaved
            total += moe_layers * moe_per_layer
            total += (L - moe_layers) * dense_per_layer
        elif self.d_ff:
            per_layer += 3 * d * self.d_ff                     # SwiGLU
        total += L * per_layer
        return total

    def active_param_count(self) -> int:
        """Activated params per token (MoE: only routed experts) for 6·N_active·D."""
        if not self.uses_moe:
            return self.param_count()
        d, L = self.d_model, self.num_layers
        moe_layers = L // self.moe_every
        inactive = (self.num_experts - self.experts_per_token) * 3 * d * self.d_ff
        return self.param_count() - moe_layers * inactive


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """A tiny same-family config for CPU smoke tests."""
    small = dict(
        num_layers=2,
        d_model=64,
        num_heads=4 if cfg.num_heads else 0,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads else 0,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        head_dim=16 if cfg.num_heads else 0,
        window=min(cfg.window, 32) if cfg.window else 0,
        global_layers=tuple(g for g in cfg.global_layers if g < 2),
        q_lora_rank=32 if cfg.q_lora_rank else 0,
        kv_lora_rank=16 if cfg.kv_lora_rank else 0,
        qk_nope_head_dim=16 if cfg.qk_nope_head_dim else 0,
        qk_rope_head_dim=8 if cfg.qk_rope_head_dim else 0,
        v_head_dim=16 if cfg.v_head_dim else 0,
        num_experts=min(cfg.num_experts, 4),
        experts_per_token=min(cfg.experts_per_token, 2),
        # capacity-dropping makes MoE outputs depend on *other* tokens in
        # the batch (not causally consistent); keep tiny-config capacity
        # non-binding so prefill/decode consistency tests are exact
        moe_capacity_factor=8.0,
        shared_expert_ff=64 if cfg.shared_expert_ff else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        ssm_chunk=8,
        loss_chunk=64,
        remat="none",
        dtype="float32",
    )
    small.update(overrides)
    return dataclasses.replace(cfg, name=cfg.name + "-tiny", **small)
