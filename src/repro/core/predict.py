"""POAS phase 1 — *Predict*.

Builds per-device performance models.  Three sources, all producing the same
``TimeModel`` interface (paper §3.1 stresses modularity of the predictor):

1. ``fit_linear`` — least-squares linear regression of measured time over the
   op count (the paper's approach, §4.1.1).
2. ``Profiler`` — the one-off profiling pass (paper §4.1.2): runs squared
   matmuls of growing size, measures, and regresses.  On this container it
   measures the real host CPU via jitted jnp matmuls; simulated device specs
   reproduce the paper's testbed.
3. ``roofline_model`` — XLA-cost-analysis-driven predictor for TPU device
   groups (our hardware adaptation; see DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import numpy as np

from .device_model import (CopyModel, DeviceProfile, LinearTimeModel,
                           RooflineTimeModel, NO_COPY)

# ---------------------------------------------------------------------------
# Regression
# ---------------------------------------------------------------------------


def fit_linear(ops: Sequence[float], seconds: Sequence[float],
               weights: Sequence[float] | None = None) -> LinearTimeModel:
    """Closed-form (weighted) least squares of t = a*ops + b, a>=0, b>=0."""
    x = np.asarray(ops, dtype=np.float64)
    y = np.asarray(seconds, dtype=np.float64)
    if weights is None:
        w = np.ones_like(x)
    else:
        w = np.asarray(weights, dtype=np.float64)
    sw = w.sum()
    mx, my = (w * x).sum() / sw, (w * y).sum() / sw
    vx = (w * (x - mx) ** 2).sum()
    if vx == 0.0:
        # Degenerate: single size — throughput-only model.  Clamp the slope
        # to the same positive floor as the main path: a zero-slope model
        # ("free compute at any size") would make every downstream solver
        # special-case it (solve_analytic holds zero-slope devices out of
        # the LP; the bisection would hand it the whole workload).
        a = max(float(my / mx) if mx else 0.0, 1e-18)
        return LinearTimeModel(a=a, b=0.0)
    a = float((w * (x - mx) * (y - my)).sum() / vx)
    a = max(a, 1e-18)
    b = max(float(my - a * mx), 0.0)
    return LinearTimeModel(a=a, b=b)


def relative_error(predicted: float, measured: float) -> float:
    """Paper §5.2: e = 100 * (v - v_pred) / v   (reported as |.| percent)."""
    if measured == 0.0:
        return 0.0
    return 100.0 * abs(measured - predicted) / measured


def rmse(errors_pct: Sequence[float]) -> float:
    e = np.asarray(errors_pct, dtype=np.float64)
    return float(np.sqrt(np.mean(e ** 2)))


# ---------------------------------------------------------------------------
# Profiling (paper §4.1.2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ProfileRecord:
    size: int           # squared matmul side
    ops: float          # size**3 MACs
    seconds: float


class Profiler:
    """Runs the paper's profiling pass: squared GEMMs, regress time over ops.

    ``runner(size) -> seconds`` abstracts the backend: real jitted matmul on
    the host, or a simulated device with synthetic noise.
    """

    def __init__(self, runner: Callable[[int], float], *, repeats: int = 5):
        self.runner = runner
        self.repeats = repeats
        self.records: list[ProfileRecord] = []

    def run(self, sizes: Sequence[int]) -> list[ProfileRecord]:
        self.records = []
        for s in sizes:
            ts = [self.runner(s) for _ in range(self.repeats)]
            self.records.append(
                ProfileRecord(size=s, ops=float(s) ** 3,
                              seconds=float(np.mean(ts))))
        return self.records

    def fit(self) -> LinearTimeModel:
        if not self.records:
            raise RuntimeError("run() the profiler before fit()")
        return fit_linear([r.ops for r in self.records],
                          [r.seconds for r in self.records])


def host_cpu_runner(dtype=np.float32) -> Callable[[int], float]:
    """Measure real jitted matmul wall time on the container CPU."""
    import jax
    import jax.numpy as jnp

    @jax.jit
    def mm(a, b):
        return a @ b

    def run(size: int) -> float:
        key = np.random.default_rng(size)
        a = jnp.asarray(key.standard_normal((size, size)), dtype=dtype)
        b = jnp.asarray(key.standard_normal((size, size)), dtype=dtype)
        mm(a, b).block_until_ready()  # warm the cache / compile
        t0 = time.perf_counter()
        mm(a, b).block_until_ready()
        return time.perf_counter() - t0

    return run


def simulated_runner(profile: DeviceProfile, *, noise: float = 0.02,
                     seed: int = 0) -> Callable[[int], float]:
    """Synthesize profiling measurements from a ground-truth device profile.

    Multiplicative Gaussian noise models run-to-run variance (the paper's
    frequency-drift observation, §5.2).
    """
    rng = np.random.default_rng(seed)

    def run(size: int) -> float:
        t = profile.compute(float(size) ** 3)
        return max(t * (1.0 + noise * rng.standard_normal()), 1e-12)

    return run


def measure_bandwidth_simulated(profile: DeviceProfile, *, nbytes: int = 1 << 28,
                                noise: float = 0.01, seed: int = 1) -> float:
    """Paper's memory-bandwidth micro-benchmark, simulated."""
    import math
    if math.isinf(profile.copy.bandwidth_bytes_per_s):
        return float("inf")
    rng = np.random.default_rng(seed)
    t = nbytes / profile.copy.bandwidth_bytes_per_s
    t *= 1.0 + noise * rng.standard_normal()
    return nbytes / max(t, 1e-12)


# ---------------------------------------------------------------------------
# Profile persistence (paper stores profiling results in a text file)
# ---------------------------------------------------------------------------


def save_profiles(path: str, devices: Sequence[DeviceProfile]) -> None:
    import json
    import math
    rows = []
    for d in devices:
        row = {"name": d.name, "kind": d.kind, "align_m": d.align_m,
               "align_k": d.align_k, "cache_bytes": d.cache_bytes,
               "pipeline_chunks": d.pipeline_chunks}
        if isinstance(d.compute, LinearTimeModel):
            row["model"] = {"type": "linear", "a": d.compute.a, "b": d.compute.b}
        else:
            row["model"] = {"type": "roofline",
                            "peak_ops_per_s": d.compute.peak_ops_per_s,
                            "hbm_bytes_per_s": d.compute.hbm_bytes_per_s,
                            "bytes_per_op": d.compute.bytes_per_op,
                            "overhead_s": d.compute.overhead_s}
        row["copy"] = {"bw": (None if math.isinf(d.copy.bandwidth_bytes_per_s)
                              else d.copy.bandwidth_bytes_per_s),
                       "dtype_size": d.copy.dtype_size,
                       "latency_s": d.copy.latency_s}
        rows.append(row)
    with open(path, "w") as f:
        json.dump(rows, f, indent=2)


def load_profiles(path: str) -> list[DeviceProfile]:
    import json
    import math
    with open(path) as f:
        rows = json.load(f)
    out = []
    for row in rows:
        m = row["model"]
        if m["type"] == "linear":
            compute = LinearTimeModel(a=m["a"], b=m["b"])
        else:
            compute = RooflineTimeModel(
                peak_ops_per_s=m["peak_ops_per_s"],
                hbm_bytes_per_s=m["hbm_bytes_per_s"],
                bytes_per_op=m["bytes_per_op"], overhead_s=m["overhead_s"])
        c = row["copy"]
        copy = (NO_COPY if c["bw"] is None else
                CopyModel(c["bw"], dtype_size=c["dtype_size"],
                          latency_s=c["latency_s"]))
        out.append(DeviceProfile(row["name"], row["kind"], compute, copy,
                                 align_m=row["align_m"], align_k=row["align_k"],
                                 cache_bytes=row["cache_bytes"],
                                 pipeline_chunks=row.get("pipeline_chunks", 1)))
    return out
