"""Persistent streaming co-execution runtime — plan → execute → observe →
re-plan as one loop (DESIGN.md §9).

The paper runs POAS once per application; its §3.4.2 dynamic mode, and any
deployment serving sustained traffic, need a *continuous* loop instead.
``CoExecutionRuntime`` keeps the whole pipeline alive across plans:

* an **admission queue** of POAS workloads for any registered ``Domain``;
* a planner thread running the four phases per job through the shared
  ``POAS``/``PlanCache`` (a cache hit skips the solve entirely);
* **plan-carry-over**: each plan's timeline is rebased onto the previous
  plan's carried link/device clocks (``core.bus.ClockState``), so plan
  k+1's input copies overlap plan k's tail instead of waiting for a global
  barrier;
* execution through the persistent ``StreamCore`` (long-lived per-device
  workers + per-link ticket buses, ``core.executor``) or through a
  deterministic **virtual-time** backend that prices the measured run on
  ground-truth device models;
* an **observation pump** converting each measured ``Timeline``'s compute
  events into ``DynamicScheduler.observe`` calls, so model re-fits,
  ``PlanCache`` invalidation, and re-planning happen automatically inside
  the loop — a device that starts throttling mid-stream sheds load within
  a few jobs without any caller wiring;
* **multi-tenant admission** (DESIGN.md §13): one runtime serves jobs from
  many registered ``Tenant``s (each its own domain, ``POAS``/``PlanCache``,
  observation pump, and ``QoS`` policy) through a single weighted-fair,
  deadline-aware admission queue onto ONE shared ``StreamCore`` and one
  carried-clock timeline — with SLO rejection at admission (an infeasible
  deadline never issues a ticket) and priority preemption of a batch-tier
  job's not-yet-started frontier when a latency-tier job arrives (built on
  the §11 ``reissue``/``rebase_partial`` splice machinery, unchanged).
"""
from __future__ import annotations

import dataclasses
import math
import threading
import time
from typing import Callable, Iterable, Mapping, Sequence

from .bus import (ClockState, GraphTimelineSpec, Timeline, _has_copy,
                  carry_clocks, graph_finish_times)
from .device_model import (DeviceProfile, LinearTimeModel, RooflineTimeModel)
from .domain import (Domain, PlanCache, QoS, TIER_BATCH, TIER_LATENCY,
                     Workload)
from .executor import DeviceTask, StreamCore
from .framework import POAS, POASPlan
from .optimize import SolveContextCache, solve_list_schedule
from .schedule import DynamicScheduler


# ---------------------------------------------------------------------------
# Observation pump — measured timelines feed the Predict phase
# ---------------------------------------------------------------------------


class ObservationPump:
    """Converts measured timelines into ``DynamicScheduler.observe`` calls.

    One pump is the single feedback path for every layer: the runtime feeds
    each job's measured compute events (``feed``), the serving dispatcher
    feeds per-bucket generation times, and the hetero train-step loop feeds
    per-pod step times (both via ``observe``).  ``time_scale`` converts
    measured wall seconds back to model seconds when execution is
    deliberately time-scaled (sleep-based testbeds).
    """

    def __init__(self, dyn: DynamicScheduler,
                 device_names: Sequence[str], *, time_scale: float = 1.0):
        self.dyn = dyn
        self.index = {name: i for i, name in enumerate(device_names)}
        self.time_scale = time_scale
        self.observations = 0

    def observe(self, device: str, ops: float, seconds: float) -> None:
        """One measured (ops, seconds) sample for a device, by name."""
        self.dyn.observe(self.index[device], float(ops),
                         float(seconds) / self.time_scale)
        self.observations += 1

    def feed(self, measured: Timeline,
             ops_by_device: Mapping[str, float]) -> int:
        """Pump every device's measured compute time (chunk durations
        summed) into the scheduler; returns the number of observations."""
        fed = 0
        for name, ops in ops_by_device.items():
            if name not in self.index or ops <= 0.0:
                continue
            seconds = sum(e.duration for e in measured.device_events(name)
                          if e.kind == "compute")
            if seconds > 0.0:
                self.observe(name, ops, seconds)
                fed += 1
        return fed

    def feed_tasks(self, measured: Timeline,
                   task_ops: Sequence[tuple[str, str, float]]) -> int:
        """Per-task observations for DAG jobs: each ``(task, device, ops)``
        row becomes its own ``observe`` call with that task's measured
        compute time — a single job yields many distinct (ops, seconds)
        samples per device, so the regression gets rank from one job
        instead of needing a stream of differently-sized jobs."""
        fed = 0
        for task, device, ops in task_ops:
            if device not in self.index or ops <= 0.0:
                continue
            seconds = sum(e.duration for e in measured.events
                          if e.task == task and e.device == device
                          and e.kind == "compute")
            if seconds > 0.0:
                self.observe(device, ops, seconds)
                fed += 1
        return fed


# ---------------------------------------------------------------------------
# Ground-truth helpers (testbeds: what the hardware *really* does)
# ---------------------------------------------------------------------------


def throttled(device: DeviceProfile, factor: float) -> DeviceProfile:
    """Ground-truth profile computing ``factor``× slower than ``device``
    (the paper's overheating scenario / a straggling pod)."""
    m = device.compute
    if isinstance(m, LinearTimeModel):
        slow = LinearTimeModel(a=m.a * factor, b=m.b * factor)
    elif isinstance(m, RooflineTimeModel):
        slow = RooflineTimeModel(peak_ops_per_s=m.peak_ops_per_s / factor,
                                 hbm_bytes_per_s=m.hbm_bytes_per_s / factor,
                                 bytes_per_op=m.bytes_per_op,
                                 overhead_s=m.overhead_s * factor)
    else:  # pragma: no cover - exotic model
        raise TypeError(f"cannot throttle {type(m).__name__}")
    return dataclasses.replace(device, compute=slow)


def copy_throttled(device: DeviceProfile, factor: float) -> DeviceProfile:
    """Ground-truth profile whose host<->device copies run ``factor``×
    slower than ``device`` (a degraded PCIe lane, a saturated NIC).  The
    engine prices copies from the device ``CopyModel`` capped by link
    bandwidth, so this slows measured copy events in both the virtual and
    the sleep-based threaded backends — the *link* straggler scenario."""
    c = device.copy
    if factor == 1.0 or math.isinf(c.bandwidth_bytes_per_s):
        return device
    slow = dataclasses.replace(
        c, bandwidth_bytes_per_s=c.bandwidth_bytes_per_s / factor,
        latency_s=c.latency_s * factor)
    return dataclasses.replace(device, copy=slow)


TruthFn = Callable[[int, DeviceProfile], DeviceProfile]
"""(job uid, planned device) -> the profile the hardware really runs at.

Must be anchored to FIXED ground-truth profiles: the planned device passed
in may already carry a re-fitted model, and deriving the truth from it
(e.g. ``throttled(planned, 2)``) compounds the slowdown on every re-fit —
the model chases its own tail to infinity.  Use ``truth_from_profiles``.
"""


def truth_from_profiles(base: Sequence[DeviceProfile],
                        slowdown: Callable[[int, str], float] | None = None,
                        copy_slowdown: Callable[[int, str], float] | None = None
                        ) -> TruthFn:
    """A ``TruthFn`` pinned to fixed ground-truth ``base`` profiles.

    ``slowdown(job_uid, device_name)`` returns the compute throttle factor
    in effect for that job (1.0 = nominal) — e.g. a device overheating 2x
    from job 8 onward is ``lambda uid, name: 2.0 if uid >= 8 and
    name == "xpu" else 1.0``.  ``copy_slowdown`` is the same contract for
    the device's host<->device copy bandwidth (the link-straggler
    scenario the copy-slack monitor catches).
    """
    by_name = {d.name: d for d in base}

    def fn(uid: int, planned: DeviceProfile) -> DeviceProfile:
        d = by_name.get(planned.name, planned)
        f = slowdown(uid, d.name) if slowdown is not None else 1.0
        out = throttled(d, f) if f != 1.0 else d
        cf = copy_slowdown(uid, d.name) if copy_slowdown is not None else 1.0
        return copy_throttled(out, cf)

    return fn


def model_sleep_tasks(truth: TruthFn | None = None, *,
                      time_scale: float = 1.0) -> "TaskFactory":
    """Task factory whose stages sleep their ground-truth model durations —
    the simulated-testbed execution backend for the threaded runtime.

    ``truth`` substitutes what the device *really* does for what the plan
    believes (e.g. a mid-stream throttle); it is evaluated at execution
    time keyed on the job uid, so throttles are deterministic regardless of
    thread timing.  ``time_scale`` shrinks the sleeps; pair it with the
    runtime's ``time_scale`` so the pump converts back to model seconds.
    """

    def factory(job: "StreamJob", plan: POASPlan) -> list[DeviceTask]:
        spec = plan.schedule.spec
        if spec is None:
            raise ValueError("model_sleep_tasks needs Schedule.spec "
                             "(every shipped domain provides it)")
        if isinstance(spec, GraphTimelineSpec):
            return _graph_sleep_tasks(job, spec, truth, time_scale)
        kinds = {(e.device, e.kind) for e in plan.schedule.timeline.events}
        tasks: list[DeviceTask] = []
        for d, c in zip(spec.devices, spec.ops):
            if c <= 0.0:
                continue

            def true_dev(d=d) -> DeviceProfile:
                return truth(job.uid, d) if truth is not None else d

            def sleep_in(d=d, c=c):
                time.sleep(true_dev(d).copy.in_time(c, spec.n, spec.k)
                           * time_scale)

            def sleep_compute(d=d, c=c):
                time.sleep(true_dev(d).compute(c) * time_scale)

            def sleep_out(d=d, c=c):
                time.sleep(true_dev(d).copy.out_time(c, spec.n, spec.k)
                           * time_scale)

            has_in = (d.name, "copy_in") in kinds
            has_out = (d.name, "copy_out") in kinds
            tasks.append(DeviceTask(device=d.name,
                                    copy_in=sleep_in if has_in else None,
                                    compute=sleep_compute,
                                    copy_out=sleep_out if has_out else None))
        return tasks

    return factory


def _graph_sleep_tasks(job: "StreamJob", spec: GraphTimelineSpec,
                       truth: TruthFn | None,
                       time_scale: float) -> list[DeviceTask]:
    """Sleep-stage ``DeviceTask``s for a task-graph plan: one stage group
    per DAG task (``task``/``deps`` set so the StreamCore blocks on
    upstream completion), durations re-priced per stage under the
    ground-truth profiles via the spec's own engine rebase."""
    truth_devs = [truth(job.uid, d) if truth is not None else d
                  for d in spec.devices]
    seconds = spec.stage_seconds(truth_devs)
    parents = spec.parents_of()
    tasks: list[DeviceTask] = []
    # planned order, NOT node order: each device's worker runs its stage
    # groups strictly in dispatch order, so a same-device dependency queued
    # out of topological order would deadlock the worker on its own queue
    for i in spec.order:
        t, a = spec.tasks[i], spec.assign[i]
        if a < 0:
            continue
        dev = spec.devices[a].name
        stage = seconds.get(t.name, {})

        def sleeper(s: float):
            return (lambda: time.sleep(s * time_scale))

        tasks.append(DeviceTask(
            device=dev,
            copy_in=sleeper(stage["copy_in"]) if stage.get("copy_in")
            else None,
            compute=sleeper(stage.get("compute", 0.0)),
            copy_out=sleeper(stage["copy_out"]) if stage.get("copy_out")
            else None,
            task=t.name, deps=parents.get(t.name, ())))
    return tasks


# ---------------------------------------------------------------------------
# Stream jobs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ReplanRecord:
    """One mid-graph re-plan splice on a live DAG job (DESIGN.md §11).

    ``frozen`` are the completed/running tasks kept in place, ``spliced``
    the not-yet-started tasks whose tickets were revoked and re-issued
    under ``spec`` (the re-solved full-graph spec, frozen assignments
    pinned); ``planned`` is the frontier's re-planned partial timeline —
    its per-link ticket order is what the executor spliced in, and what
    ``verify_stream_invariants`` checks the measured grant order against.
    """

    at: float                    # stream time (model seconds) of the splice
    straggler: str               # task (or preempting job id) that tripped it
    frozen: tuple[str, ...]
    spliced: tuple[str, ...]
    spec: GraphTimelineSpec
    planned: Timeline
    # what tripped the splice: "straggler" (compute slack), "copy-straggler"
    # (link slack), or "preempt" (a latency-tier arrival revoked this
    # batch-tier job's frontier)
    reason: str = "straggler"


class AdmissionRejected(RuntimeError):
    """The job's deadline was infeasible at admission: the engine-priced
    predicted completion on the carried clocks exceeded it, so the job was
    rejected *before* dispatch — no ticket was ever issued (DESIGN.md §13).
    """

    def __init__(self, uid: int, predicted: float, deadline: float):
        super().__init__(
            f"job {uid}: predicted completion {predicted:.6g}s exceeds "
            f"deadline {deadline:.6g}s — rejected at admission")
        self.uid = uid
        self.predicted = predicted
        self.deadline = deadline


@dataclasses.dataclass
class StreamJob:
    """One admitted workload's lifecycle through the loop."""

    uid: int
    workload: Workload
    plan: POASPlan | None = None
    planned: Timeline | None = None    # rebased onto carried clocks
    measured: Timeline | None = None
    error: BaseException | None = None
    epoch_at_plan: int = 0             # DynamicScheduler.epoch when planned
    replans: list[ReplanRecord] = dataclasses.field(default_factory=list)
    # multi-tenant lifecycle (DESIGN.md §13)
    tenant: "Tenant | None" = None
    arrival: float = 0.0               # stream-axis submit time
    deadline: float | None = None      # absolute stream-axis SLO deadline
    vstart: float = 0.0                # SFQ start tag (fair-admission order)
    vft: float = 0.0                   # SFQ finish tag (tenant's next floor)
    _done: threading.Event = dataclasses.field(
        default_factory=threading.Event)
    # mid-execution bookkeeping (threads: the straggler monitor runs on
    # device worker threads; virtual: the deterministic replay)
    _fed_tasks: set = dataclasses.field(default_factory=set)
    _planned_compute: dict = dataclasses.field(default_factory=dict)
    _planned_copy: dict = dataclasses.field(default_factory=dict)
    _handle: object = None
    _replan_attempts: int = 0
    _preempt_attempts: int = 0
    _admit_time: float = 0.0           # when the admission queue released it
    _base_clocks: ClockState | None = None   # virtual: clocks it priced from
    # tasks whose straggler trigger was evaluated and produced no splice
    # (the re-solve confirmed the lock-in): don't re-solve for them again
    _checked_tasks: set = dataclasses.field(default_factory=set)
    _replan_lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock)
    # every rescue re-solves this job's one DAG: reuse the priority order
    # and per-(device, task) duration tables across re-plans (§14) — only
    # clocks/pinned/ext change, and those are per-state, not per-context
    _solve_cache: SolveContextCache = dataclasses.field(
        default_factory=SolveContextCache)

    def wait(self, timeout: float | None = None) -> "StreamJob":
        if not self._done.wait(timeout):
            raise TimeoutError(f"job {self.uid} still running")
        if self.error is not None:
            raise self.error
        return self

    @property
    def done(self) -> bool:
        return self._done.is_set()

    @property
    def rejected(self) -> bool:
        """True when SLO admission control rejected the job (never ran)."""
        return isinstance(self.error, AdmissionRejected)

    @property
    def start(self) -> float:
        if self.measured is None:
            return 0.0
        return min((e.start for e in self.measured.events), default=0.0)

    @property
    def finish(self) -> float:
        return self.measured.makespan if self.measured else 0.0

    @property
    def span(self) -> float:
        """Measured latency of this job (first stage start → last end)."""
        return self.finish - self.start

    @property
    def latency(self) -> float:
        """Submit-to-completion latency on the stream axis (finish − the
        arrival time) — queueing delay included, unlike ``span``."""
        return max(0.0, self.finish - self.arrival)

    @property
    def final_spec(self):
        """The spec the job actually executed under: the last re-plan's
        spec when the job was spliced mid-graph, else the planned one."""
        if self.replans:
            return self.replans[-1].spec
        return self.plan.schedule.spec if self.plan is not None else None


TaskFactory = Callable[[StreamJob, POASPlan], Sequence[DeviceTask]]

def _ancestor_closed_freeze(spec: GraphTimelineSpec,
                            started: Sequence[str]
                            ) -> tuple[list[str], list[str]]:
    """(frozen, frontier) for a mid-graph re-plan: the started set closed
    over ancestors, and the migratable remainder, both in task order.

    A stage group counts as started the moment its device worker picks it
    up — possibly while a cross-device parent is still pending (the group
    blocks in its dependency wait).  That consumer's stages were built
    against the parent's original placement, so the parent must freeze in
    place too: without the closure the progress snapshot would not be
    ancestor-closed and ``frontier_subgraph`` would (rightly) reject it.
    """
    parents = spec.parents_of()
    frozen = set(started)
    stack = list(started)
    while stack:
        for u in parents.get(stack.pop(), ()):
            if u not in frozen:
                frozen.add(u)
                stack.append(u)
    frozen_l = [t.name for t in spec.tasks if t.name in frozen]
    frontier = [t.name for t, a in zip(spec.tasks, spec.assign)
                if a >= 0 and t.name not in frozen]
    return frozen_l, frontier


def _planned_copy_map(spec: GraphTimelineSpec,
                      devices: Sequence[DeviceProfile] | None = None
                      ) -> dict[tuple[str, str], float]:
    """Planned per-``(task, kind)`` copy seconds — what the copy-slack
    monitor compares measured link transfers against (the link-straggler
    counterpart of ``_planned_compute``)."""
    out: dict[tuple[str, str], float] = {}
    for task, stages in spec.stage_seconds(devices).items():
        for kind, s in stages.items():
            if kind != "compute" and s > 0.0:
                out[(task, kind)] = s
    return out


def _copy_refit(devices: Sequence[DeviceProfile], events,
                planned_stage: Mapping[str, Mapping[str, float]],
                until: float = math.inf) -> list[DeviceProfile]:
    """Fold measured copy slack into the re-solve's device profiles.

    Compute models re-fit through the ``ObservationPump``, but nothing
    observes the ``CopyModel`` — without this, a copy-straggler trip hands
    the re-solve the same nominal link speeds the lock-in was planned
    under, and it dutifully confirms the lock-in.  Scale each device's
    copy model by the worst measured/planned ratio its link showed by the
    detection time, so the re-solve prices the degraded lane honestly."""
    ratio = {d.name: 1.0 for d in devices}
    for e in events:
        if e.kind not in ("copy_in", "copy_out") or e.task is None:
            continue
        if e.end > until + 1e-12:
            continue
        ps = planned_stage.get(e.task, {}).get(e.kind, 0.0)
        if ps > 0.0 and e.duration > ps and e.device in ratio:
            ratio[e.device] = max(ratio[e.device], e.duration / ps)
    return [copy_throttled(d, ratio[d.name]) if ratio[d.name] > 1.0 else d
            for d in devices]


# Per-descent evaluation cap for the threaded mid-graph re-solve: it runs
# in-line on the straggling device's worker thread (freezing its queue), and
# on a serialized bus the other devices' first copies wait on the straggler's
# revoked grants — every engine evaluation directly delays the whole splice.
_REPLAN_MAX_EVALS = 80

# Predicted-gain gate: splice only when the re-solved frontier beats the
# locked-in plan (re-priced under the same re-fitted models, ext and clocks)
# by at least this factor — a marginal prediction is not worth the splice.
_REPLAN_MIN_GAIN = 1.05


# ---------------------------------------------------------------------------
# Multi-tenant admission (DESIGN.md §13)
# ---------------------------------------------------------------------------


class FairAdmission:
    """Start-time Fair Queueing (SFQ) over tenants — pure tag algebra, no
    clock reads, so the admission *order* is a deterministic function of
    the submit sequence (Goyal et al.'s SFQ, the classic weighted-fair
    discipline that needs no fluid-model reference clock).

    Each job is stamped at submit with a virtual start tag
    ``S = max(v, F_tenant)`` and finish tag ``F = S + cost / weight``
    (``F_tenant`` = the tenant's previous job's finish tag); jobs are
    admitted in increasing start-tag order and the system virtual time
    ``v`` advances to the start tag of each job entering service.  While
    two tenants stay backlogged, their admitted-work ratio tracks their
    weight ratio within one job of slack — the property
    ``tests/test_multi_tenant.py`` checks under hypothesis.
    """

    def __init__(self) -> None:
        self._vtime = 0.0
        self._last_finish: dict[str, float] = {}

    def stamp(self, tenant: str, weight: float,
              cost: float) -> tuple[float, float]:
        """Tag one submitted job; returns ``(vstart, vfinish)``."""
        if weight <= 0.0:
            raise ValueError("weight must be > 0")
        vstart = max(self._vtime, self._last_finish.get(tenant, 0.0))
        vfinish = vstart + max(0.0, float(cost)) / float(weight)
        self._last_finish[tenant] = vfinish
        return vstart, vfinish

    def on_admit(self, vstart: float) -> None:
        """A job with this start tag entered service: advance ``v``."""
        if vstart > self._vtime:
            self._vtime = vstart


class Tenant:
    """One registered workload source on a shared ``CoExecutionRuntime``.

    A tenant owns the *domain-specific* half of the loop — its ``Domain``,
    ``POAS`` + ``PlanCache``, ``DynamicScheduler`` and ``ObservationPump``
    — while the runtime owns the shared half: one ``StreamCore`` (or the
    virtual-time engine), one carried-clock timeline, one weighted-fair
    admission queue.  Per-tenant pumps mean one tenant's measurements
    re-fit only its own models and invalidate only its own cache.
    """

    def __init__(self, name: str, domain: Domain, qos: QoS,
                 runtime: "CoExecutionRuntime", *, cache: bool = True,
                 feedback: bool = True):
        self.name = name
        self.domain = domain
        self.qos = qos
        self.runtime = runtime
        self.poas = POAS(domain, cache=PlanCache() if cache else None)
        self.dyn: DynamicScheduler | None = getattr(domain, "dyn", None)
        self.pump: ObservationPump | None = None
        if feedback and self.dyn is not None:
            names = [d.name for d in domain.predict()]
            self.pump = ObservationPump(self.dyn, names,
                                        time_scale=runtime.time_scale)
        self.jobs: list[StreamJob] = []
        self.rejected = 0

    @property
    def plan_cache(self) -> PlanCache | None:
        return self.poas.cache

    def submit(self, workload: Workload, *,
               deadline_s: float | None = None,
               arrival: float | None = None) -> StreamJob:
        return self.runtime.submit(workload, tenant=self,
                                   deadline_s=deadline_s, arrival=arrival)

    def stats(self) -> dict:
        done = [j for j in self.jobs if j.done and j.error is None]
        lats = sorted(j.latency for j in done)
        p = lambda q: lats[max(0, math.ceil(q * len(lats)) - 1)] \
            if lats else 0.0
        return {
            "jobs_done": len(done),
            "rejected": self.rejected,
            "p50_latency_s": p(0.50),
            "p95_latency_s": p(0.95),
            "p99_latency_s": p(0.99),
            "observations": self.pump.observations if self.pump else 0,
            "refit_epoch": self.dyn.epoch if self.dyn else 0,
            "plan_cache": self.poas.cache.stats() if self.poas.cache else {},
        }


# ---------------------------------------------------------------------------
# The runtime
# ---------------------------------------------------------------------------


class CoExecutionRuntime:
    """Persistent plan→execute→observe→re-plan loop over one shared core.

    Single-tenant (the classic shape): construct with a ``domain`` and
    ``submit`` workloads.  Multi-tenant (DESIGN.md §13): ``register`` any
    number of tenants — each its own ``Domain``, ``POAS``/``PlanCache``
    and observation pump, all sharing ONE ``StreamCore`` (or virtual
    engine), one ``BusTopology`` link namespace and one carried-clock
    timeline.  Admission is weighted-fair (SFQ over ``QoS.weight`` within
    strict ``QoS.tier`` priority), deadline-aware (an infeasible SLO is
    rejected before a ticket is issued), and — with ``preempt`` on — a
    latency-tier arrival revokes batch-tier jobs' not-yet-started tickets
    and splices their re-solved frontiers behind it.

    Parameters
    ----------
    domain:
        any registered POAS ``Domain``; it becomes the ``"default"``
        tenant (weight 1, batch tier).  If it carries a
        ``DynamicScheduler`` (``domain.dyn``) and ``feedback`` is on,
        measured timelines are pumped back into it.  ``None`` starts an
        empty runtime — ``register`` tenants before submitting.
    executor:
        ``"threads"`` — the real ``StreamCore`` (long-lived per-device
        workers, per-link ticket buses surviving across plans); stage
        callables come from ``task_factory`` (default: ground-truth sleeps
        via ``model_sleep_tasks``).
        ``"virtual"`` — deterministic virtual time: the measured timeline is
        the engine's pricing of the plan under the ground-truth profiles
        (``truth``), chained on carried measured clocks.  Planning latency
        does not pollute the stream, so throughput comparisons are exact.
    carry_clocks:
        rebase each plan onto the previous plan's carried link/device
        clocks (overlapped back-to-back plans).  Off = a global barrier
        between plans.
    feedback:
        pump measured compute events into ``domain.dyn`` after each job
        (model re-fit → ``PlanCache`` invalidation → re-plan, automatically).
    max_inflight:
        how many jobs may be planned ahead of the oldest unfinished one.
        In virtual mode this sets the observation lag (a plan dispatched
        while k jobs are in flight cannot have seen their measurements).
    replan:
        mid-graph re-planning (DESIGN.md §11): while a DAG job executes,
        per-task measurements feed the pump *during* execution, and a task
        whose measured compute exceeds ``straggler_threshold`` × its
        planned time freezes the completed/running tasks, re-solves the
        not-yet-started frontier under the re-fitted models (assignments
        pinned, clocks carried), and splices the new assignment into the
        live run via the StreamCore's ticket revoke/re-issue.  In virtual
        mode the same protocol is replayed deterministically at the moment
        the first straggling compute would have finished.
    straggler_threshold:
        measured/planned per-task compute slack ratio that triggers a
        re-plan (needs ``replan=True`` and a dynamic domain).
    replan_min_frontier:
        minimum number of not-yet-started tasks worth re-solving for.
    max_replans_per_job:
        re-plan attempts allowed per job (1 = classic one-shot rescue).
    admission:
        ``"fair"`` — SFQ weighted-fair order within strict tier priority
        (with a single tenant this degenerates to FIFO exactly);
        ``"fifo"`` — raw submission order (the baseline the benchmark
        compares against).
    preempt:
        priority preemption: a ``TIER_LATENCY`` job's dispatch revokes
        every running batch-tier DAG job's not-yet-started tickets and
        splices the re-solved frontier behind it (§11 machinery, reason
        ``"preempt"``).
    """

    def __init__(self, domain: Domain | None = None, *,
                 executor: str = "threads",
                 task_factory: TaskFactory | None = None,
                 truth: TruthFn | None = None,
                 cache: bool = True,
                 feedback: bool = True,
                 carry_clocks: bool = True,
                 max_inflight: int = 2,
                 time_scale: float = 1.0,
                 replan: bool = False,
                 straggler_threshold: float = 1.5,
                 replan_min_frontier: int = 2,
                 max_replans_per_job: int = 1,
                 admission: str = "fair",
                 preempt: bool = False):
        if executor not in ("threads", "virtual"):
            raise ValueError(f"unknown executor {executor!r}")
        if admission not in ("fair", "fifo"):
            raise ValueError(f"unknown admission policy {admission!r}")
        self.carry = bool(carry_clocks)
        self.max_inflight = max(1, int(max_inflight))
        self.executor = executor
        self.truth = truth
        self.time_scale = time_scale
        self.feedback = bool(feedback)
        self.admission_policy = admission
        self.preempt = bool(preempt)
        self.replan = bool(replan)
        self.straggler_threshold = float(straggler_threshold)
        self.replan_min_frontier = max(1, int(replan_min_frontier))
        self.max_replans_per_job = max(0, int(max_replans_per_job))
        self.jobs: list[StreamJob] = []
        self.tenants: dict[str, Tenant] = {}
        self._default_cache = bool(cache)
        self._default: Tenant | None = None
        self._task_factory = task_factory or model_sleep_tasks(
            truth, time_scale=time_scale)
        self._core = StreamCore() if executor == "threads" else None
        if self._core is not None:
            # per-task measurements flow DURING execution, not only at job
            # completion — the straggler monitor and the observation pumps
            # both hang off the core's event hook
            self._core.on_event = self._on_stream_event
        self._plan_clocks = ClockState()
        self._meas_clocks = ClockState()
        self._virtual_events: list = []
        self._virtual_finishes: dict[int, float] = {}   # uid -> stream end
        self._vnow = 0.0                   # virtual admission clock
        self._dispatched = 0
        self._last_virtual: StreamJob | None = None
        self._preempt_pending: tuple | None = None
        self._pending_obs: list[StreamJob] = []   # virtual-mode obs lag
        self._pending: list[StreamJob] = []       # submitted, not admitted
        self._admission = FairAdmission()
        self._inflight = threading.Semaphore(self.max_inflight)
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._hold = False
        self._closed = False
        if domain is not None:
            self.register("default", domain, QoS())
        self._planner = threading.Thread(target=self._plan_loop,
                                         name="poas-planner", daemon=True)
        self._planner.start()

    # -- tenants ------------------------------------------------------------

    def register(self, name: str, domain: Domain,
                 qos: QoS | None = None, *,
                 cache: bool | None = None) -> Tenant:
        """Register one tenant (its own POAS/cache/pump) on the shared
        core.  The first registered tenant is the default ``submit``
        target and backs the legacy ``.domain/.poas/.dyn/.pump`` aliases."""
        with self._cv:
            if self._closed:
                raise RuntimeError("runtime is shut down")
            if name in self.tenants:
                raise ValueError(f"tenant {name!r} already registered")
            ten = Tenant(name, domain, qos or QoS(), self,
                         cache=self._default_cache if cache is None
                         else cache,
                         feedback=self.feedback)
            self.tenants[name] = ten
            if self._default is None:
                self._default = ten
            return ten

    # single-tenant aliases: the pre-§13 API (and the shipped tests) reach
    # the loop's domain half through the runtime object itself
    @property
    def domain(self) -> Domain | None:
        return self._default.domain if self._default else None

    @property
    def poas(self) -> POAS | None:
        return self._default.poas if self._default else None

    @property
    def dyn(self) -> DynamicScheduler | None:
        return self._default.dyn if self._default else None

    @property
    def pump(self) -> ObservationPump | None:
        return self._default.pump if self._default else None

    # -- admission ----------------------------------------------------------

    def submit(self, workload: Workload, *, tenant: Tenant | None = None,
               deadline_s: float | None = None,
               arrival: float | None = None) -> StreamJob:
        """Admit one workload; returns immediately with its ``StreamJob``.

        ``deadline_s`` (relative) overrides the tenant's ``QoS.deadline_s``
        for this job; the absolute deadline is ``arrival + deadline_s`` on
        the stream axis.  ``arrival`` places the submit on the virtual
        stream axis (model seconds) for open-loop experiments — virtual
        mode only; in threads mode the wall clock is the arrival.
        """
        now = self._core.now() / self.time_scale \
            if self._core is not None else 0.0
        with self._cv:
            if self._closed:
                raise RuntimeError("runtime is shut down")
            ten = tenant if tenant is not None else self._default
            if ten is None:
                raise ValueError("no tenant registered: construct with a "
                                 "domain or call register() first")
            job = StreamJob(uid=len(self.jobs), workload=workload,
                            tenant=ten)
            job.arrival = float(arrival) if arrival is not None else now
            dl = deadline_s if deadline_s is not None else ten.qos.deadline_s
            if dl is not None:
                job.deadline = job.arrival + float(dl)
            job.vstart, job.vft = self._admission.stamp(
                ten.name, ten.qos.weight, float(workload.total_ops()))
            self.jobs.append(job)
            ten.jobs.append(job)
            self._pending.append(job)
            self._cv.notify()
        return job

    def pause_admission(self) -> None:
        """Hold the admission queue (submissions still accepted): lets an
        open-loop experiment enqueue its whole arrival schedule before any
        job is planned, so the fair-admission order is deterministic."""
        with self._cv:
            self._hold = True

    def resume_admission(self) -> None:
        with self._cv:
            self._hold = False
            self._cv.notify_all()

    # -- elastic membership (DESIGN.md §16) ---------------------------------

    def device_leave(self, name: str, *,
                     at: float | None = None) -> list[ReplanRecord]:
        """Device departure as a first-class change-point.

        Two halves, generalizing the §11 straggler rescue:

        1. *Future admissions*: every tenant whose planning set contains
           ``name`` shrinks it (``Domain.set_devices`` hook — dynamic
           domains carry their re-fitted models for the survivors) and
           drops its ``PlanCache``, so the next plan solves on the
           smaller cluster.
        2. *In-flight jobs* (virtual mode): any job whose stream had not
           finished by ``at`` (default: the virtual admission clock) and
           whose not-yet-started frontier touches the departed device is
           frontier-frozen and re-solved with the device *banned* —
           assignments of started tasks pinned, clocks carried, spliced
           back into the stream with ``ReplanRecord(reason=
           "device-loss")``.  Banning (rather than deleting) keeps the
           job's spec device tuple and clock names index-aligned.

        Returns the splice records, one per rescued job.
        """
        with self._cv:
            if self._closed:
                raise RuntimeError("runtime is shut down")
            tenants = list(self.tenants.values())
        for ten in tenants:
            cur = list(ten.domain.predict())
            new = [d for d in cur if d.name != name]
            if len(new) == len(cur):
                continue
            if not new:
                raise ValueError(f"device {name!r} is the last device of "
                                 f"tenant {ten.name!r}; cannot leave")
            if hasattr(ten.domain, "set_devices"):
                ten.domain.set_devices(new)
            if ten.poas.cache is not None:
                ten.poas.cache.invalidate()
            if ten.pump is not None:
                ten.pump.index = {d.name: i for i, d in enumerate(new)}
        recs: list[ReplanRecord] = []
        if self.executor == "virtual":
            t = self._vnow if at is None else float(at)
            with self._lock:
                inflight = [j for j in self.jobs
                            if j.measured is not None and j.error is None
                            and j.measured.makespan > t + 1e-12]
            for job in inflight:
                rec = self._rescue_device_loss(job, name, t)
                if rec is not None:
                    recs.append(rec)
        return recs

    def device_join(self, device: DeviceProfile, *,
                    topology: "str | BusTopology | None" = None) -> None:
        """Device arrival: widen every tenant's planning set and drop its
        ``PlanCache`` — the next admission plans on the larger cluster.
        In-flight jobs are left alone (their specs never knew the
        joiner).  ``topology`` replaces the bus when the new device needs
        attach rows a custom topology lacks."""
        with self._cv:
            if self._closed:
                raise RuntimeError("runtime is shut down")
            tenants = list(self.tenants.values())
        for ten in tenants:
            if not hasattr(ten.domain, "set_devices"):
                continue
            cur = list(ten.domain.predict())
            if any(d.name == device.name for d in cur):
                continue
            ten.domain.set_devices(cur + [device], topology=topology)
            if ten.poas.cache is not None:
                ten.poas.cache.invalidate()
            if ten.pump is not None:
                ten.pump.index = {d.name: i
                                  for i, d in enumerate(cur + [device])}

    def run_stream(self, workloads: Sequence[Workload],
                   timeout: float | None = 120.0) -> list[StreamJob]:
        """Submit every workload, wait for all of them, return their jobs."""
        jobs = [self.submit(w) for w in workloads]
        for j in jobs:
            j.wait(timeout)
        return jobs

    def drain(self, timeout: float | None = 120.0) -> None:
        with self._lock:
            jobs = list(self.jobs)
        for j in jobs:
            j._done.wait(timeout)

    def shutdown(self) -> None:
        with self._cv:
            if self._closed:
                return
            self._closed = True
            self._hold = False
            self._cv.notify_all()
        self._planner.join(timeout=60)
        if self._core is not None:
            self._core.shutdown()

    def __enter__(self) -> "CoExecutionRuntime":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # -- introspection ------------------------------------------------------

    @property
    def plan_cache(self) -> PlanCache | None:
        return self.poas.cache if self.poas is not None else None

    def stream_timeline(self) -> Timeline:
        """Every job's measured events on one time axis — the cross-plan
        invariant surface."""
        if self._core is not None:
            return self._core.stream_timeline()
        with self._lock:
            events = list(self._virtual_events)
        return Timeline(sorted(events, key=lambda e: (e.start, e.end)))

    def total_makespan(self) -> float:
        return self.stream_timeline().makespan

    def stats(self) -> dict:
        with self._lock:
            done = [j for j in self.jobs if j.done and j.error is None]
        spans = sorted(j.span for j in done)
        # nearest-rank percentile: ceil(q*n)-1, NOT int(q*n) — the latter
        # returns the max for p50 of two samples
        p = lambda q: spans[max(0, math.ceil(q * len(spans)) - 1)] \
            if spans else 0.0
        cache = self.plan_cache
        return {
            "jobs_done": len(done),
            "total_makespan_s": self.total_makespan(),
            "p50_job_span_s": p(0.50),
            "p95_job_span_s": p(0.95),
            "observations": self.pump.observations if self.pump else 0,
            "refit_epoch": self.dyn.epoch if self.dyn else 0,
            "replans": sum(len(j.replans) for j in done),
            "rejected": sum(t.rejected for t in self.tenants.values()),
            "plan_cache": cache.stats() if cache else {},
            "tenants": {name: t.stats()
                        for name, t in self.tenants.items()},
        }

    # -- the loop -----------------------------------------------------------

    def _next_clocks(self, timeline: Timeline, clocks: ClockState) -> ClockState:
        if self.carry:
            return carry_clocks(timeline, clocks)
        return ClockState(floor=max(timeline.makespan, clocks.floor))

    def _order_key(self, job: StreamJob):
        if self.admission_policy == "fifo":
            return (job.uid,)
        # strict tier priority, then SFQ start tags, uid as the tiebreak
        return (job.tenant.qos.tier, job.vstart, job.uid)

    def _select_locked(self) -> StreamJob:
        """Pick the next pending job (holding ``_cv``): min order key among
        the *eligible* set.  In threads mode every pending job has already
        arrived (the wall clock is the arrival); in virtual mode the
        open-loop slot model decides eligibility — an admission slot frees
        when the (d − max_inflight + 1)-th finish lands, the admission
        clock is the later of that slot and the previous admission, and
        only jobs arrived by then compete (an empty eligible set idles the
        queue forward to the next arrival)."""
        if self._core is not None:
            job = min(self._pending, key=self._order_key)
            job._admit_time = self._core.now() / self.time_scale
            return job
        m = self.max_inflight
        slot = 0.0
        if self._dispatched >= m:
            slot = sorted(self._virtual_finishes.values())[
                self._dispatched - m]
        t_adm = max(self._vnow, slot)
        elig = [j for j in self._pending if j.arrival <= t_adm + 1e-12]
        if not elig:
            t_adm = max(t_adm, min(j.arrival for j in self._pending))
            elig = [j for j in self._pending
                    if j.arrival <= t_adm + 1e-12]
        job = min(elig, key=self._order_key)
        self._vnow = t_adm
        job._admit_time = t_adm
        return job

    def _next_job(self) -> StreamJob | None:
        with self._cv:
            while True:
                if self._pending and not self._hold:
                    job = self._select_locked()
                    self._pending.remove(job)
                    self._admission.on_admit(job.vstart)
                    self._dispatched += 1
                    return job
                if self._closed and not self._pending:
                    return None
                self._cv.wait(timeout=0.1)

    def _plan_loop(self) -> None:
        while True:
            job = self._next_job()
            if job is None:
                return
            self._inflight.acquire()
            try:
                self._plan_and_dispatch(job)
            except AdmissionRejected as exc:
                job.error = exc
                job.tenant.rejected += 1
                with self._lock:
                    # the admission slot the job reserved frees instantly:
                    # a rejected job never runs
                    self._virtual_finishes[job.uid] = job._admit_time
                job._done.set()
                self._inflight.release()
            except BaseException as exc:
                job.error = exc
                job._done.set()
                self._inflight.release()

    def _plan_and_dispatch(self, job: StreamJob) -> None:
        ten = job.tenant
        if self.executor == "virtual":
            # flush observations old enough that a real pipeline would have
            # seen them (jobs completed before this one was planned); under
            # fair admission uids are NOT dispatch order, so the lag counts
            # completed-but-unfed jobs, not uid distance
            lag = self.max_inflight - 1
            while len(self._pending_obs) > lag:
                self._feed(self._pending_obs.pop(0))
        if ten.dyn is not None:
            job.epoch_at_plan = ten.dyn.epoch
        plan = ten.poas.plan(job.workload)
        job.plan = plan
        spec = plan.schedule.spec
        if spec is not None:
            base = self._plan_clocks
            if self._core is None and job.arrival > base.floor:
                # open-loop virtual stream: nothing of this job can be
                # planned to run before it arrived (carried clocks above
                # the floor still overlap)
                base = base.with_floor(job.arrival)
            planned = spec.rebase(base)
            self._check_deadline(job, spec, base, planned)
            job.planned = planned
            self._plan_clocks = self._next_clocks(planned,
                                                  self._plan_clocks)
        else:
            job.planned = plan.schedule.timeline
            if job.deadline is not None \
                    and job.planned.makespan > job.deadline + 1e-9:
                raise AdmissionRejected(job.uid, job.planned.makespan,
                                        job.deadline)
        if self.executor == "virtual":
            self._execute_virtual(job)
        else:
            self._execute_threads(job)

    def _check_deadline(self, job: StreamJob, spec, base: ClockState,
                        planned: Timeline) -> None:
        """SLO admission control: reject BEFORE any plan clock advances or
        any ticket is issued when the engine-priced completion of this
        plan on the carried clocks exceeds the job's absolute deadline —
        a rejected job leaves no trace on the shared timeline."""
        if job.deadline is None:
            return
        predicted = planned.makespan
        if self._core is not None:
            # the carried plan clocks can lag the wall (planner idle):
            # floor the prediction at 'now' so it cannot promise the past
            now = self._core.now() / self.time_scale
            if now > base.floor:
                predicted = spec.rebase(base.with_floor(now)).makespan
        if predicted > job.deadline + 1e-9:
            raise AdmissionRejected(job.uid, predicted, job.deadline)

    # -- virtual-time execution --------------------------------------------

    def _execute_virtual(self, job: StreamJob) -> None:
        spec = job.plan.schedule.spec
        if spec is None:
            raise ValueError("virtual execution needs Schedule.spec")
        truth_devs = [self.truth(job.uid, d) if self.truth else d
                      for d in spec.devices]
        base = self._meas_clocks
        if job.arrival > base.floor:
            # open-loop stream axis: no stage of this job can start before
            # it arrived; carried clocks above the floor still overlap
            base = base.with_floor(job.arrival)
        if self.preempt and job.tenant.qos.tier == TIER_LATENCY:
            base = self._preempt_virtual_prepare(job, base)
        job._base_clocks = base
        job.measured = spec.rebase(base, devices=truth_devs)
        if self.replan and isinstance(spec, GraphTimelineSpec):
            replayed = self._replay_replan_virtual(job, spec, truth_devs,
                                                   base, job.measured)
            if replayed is not None:
                job.measured = replayed
        self._meas_clocks = self._next_clocks(job.measured, self._meas_clocks)
        with self._lock:
            self._virtual_events.extend(job.measured.events)
            self._virtual_finishes[job.uid] = job.measured.makespan
        if self._preempt_pending is not None:
            self._preempt_virtual_commit(job)
        self._last_virtual = job
        self._pending_obs.append(job)
        job._done.set()
        self._inflight.release()

    def _preempt_virtual_prepare(self, lat: StreamJob,
                                 base: ClockState) -> ClockState:
        """Virtual-time priority preemption, half 1 (DESIGN.md §13):
        retract the last dispatched batch-tier job's not-yet-started
        frontier — in virtual time a stage's ticket is sound to revoke
        exactly when it had not started by the preemptor's admission —
        and hand back the clocks the frozen prefix leaves behind, so the
        latency job prices as if its tickets went ahead of the revoked
        ones.  Half 2 (``_preempt_virtual_commit``) re-solves and
        re-prices the victim's frontier behind the latency job."""
        victim = self._last_virtual
        if victim is None or victim.measured is None \
                or victim.tenant is lat.tenant \
                or victim.tenant.qos.tier <= lat.tenant.qos.tier \
                or victim._preempt_attempts >= 1:
            return base
        spec = victim.final_spec
        if not isinstance(spec, GraphTimelineSpec):
            return base
        t_p = lat._admit_time
        if victim.measured.makespan <= t_p + 1e-12:
            return base   # victim already finished: nothing to revoke
        first_start = {t.name: min((e.start for e in victim.measured.events
                                    if e.task == t.name), default=math.inf)
                       for t in spec.tasks}
        started, frontier = _ancestor_closed_freeze(
            spec, [t.name for t in spec.tasks
                   if first_start[t.name] < t_p - 1e-12])
        if not frontier:
            return base
        victim._preempt_attempts += 1
        started_set = set(started)
        frozen_events = [e for e in victim.measured.events
                         if e.task in started_set]
        # retract by event IDENTITY: task names collide across jobs that
        # share a graph template, so name-keyed removal would strip other
        # jobs' events from the stream
        retracted = {id(e) for e in victim.measured.events
                     if e.task not in started_set}
        with self._lock:
            self._virtual_events = [e for e in self._virtual_events
                                    if id(e) not in retracted]
        clocks = carry_clocks(Timeline(frozen_events),
                              victim._base_clocks or ClockState())
        self._meas_clocks = clocks
        self._preempt_pending = (victim, spec, started, tuple(frontier),
                                 frozen_events, t_p)
        if lat.arrival > clocks.floor:
            clocks = clocks.with_floor(lat.arrival)
        return clocks

    def _preempt_virtual_commit(self, lat: StreamJob) -> None:
        """Half 2 of the virtual preemption splice: with the latency job
        priced, re-solve the victim's revoked frontier (frozen tasks
        pinned, §11 machinery unchanged) on the clocks the frozen prefix
        AND the latency job leave behind, re-price it under ground truth,
        and splice it back into the stream."""
        victim, spec, started, frontier, frozen_events, t_p = \
            self._preempt_pending
        self._preempt_pending = None
        index = {t.name: i for i, t in enumerate(spec.tasks)}
        clocks = carry_clocks(
            lat.measured,
            carry_clocks(Timeline(frozen_events),
                         victim._base_clocks or ClockState()))
        devices = victim.tenant.dyn.snapshot() \
            if victim.tenant.dyn is not None else list(spec.devices)
        ext = self._frozen_ext(spec, started, Timeline(frozen_events),
                               t_p, devices, 1.0)
        pinned = {index[n]: spec.assign[index[n]] for n in started}
        res = solve_list_schedule(devices, spec.tasks, spec.edges,
                                  bus=spec.topology, pinned=pinned,
                                  ext=ext, clocks=clocks,
                                  seed_assign=spec.assign,
                                  max_evals=_REPLAN_MAX_EVALS,
                                  cache=victim._solve_cache)
        new_spec = dataclasses.replace(spec, devices=tuple(devices),
                                       assign=tuple(res.assign),
                                       order=tuple(res.order))
        ext_names = {spec.tasks[i].name: v for i, v in ext.items()}
        planned_frontier = new_spec.rebase_partial(clocks, ext=ext_names)
        truth_devs = [self.truth(victim.uid, d) if self.truth else d
                      for d in new_spec.devices]
        truth_frontier = new_spec.rebase_partial(clocks, ext=ext_names,
                                                 devices=truth_devs)
        victim.replans.append(ReplanRecord(
            at=t_p, straggler=f"j{lat.uid}", frozen=tuple(started),
            spliced=frontier, spec=new_spec, planned=planned_frontier,
            reason="preempt"))
        victim.measured = Timeline(sorted(
            frozen_events + list(truth_frontier.events),
            key=lambda e: (e.start, e.end)))
        self._meas_clocks = self._next_clocks(truth_frontier,
                                              self._meas_clocks)
        with self._lock:
            self._virtual_events.extend(truth_frontier.events)
            self._virtual_finishes[victim.uid] = victim.measured.makespan

    def _replay_replan_virtual(self, job: StreamJob,
                               spec: GraphTimelineSpec,
                               truth_devs: Sequence[DeviceProfile],
                               base: ClockState,
                               measured: Timeline) -> Timeline | None:
        """Deterministic virtual-time replay of the mid-graph re-plan
        protocol: detect the straggler at the moment its measured compute
        would have finished, freeze everything that had started by then,
        feed the observations the monitor would have seen, re-solve the
        frontier under the re-fitted models, and re-price it under the
        ground truth from the frozen tasks' carried clocks.  Returns the
        spliced timeline, or None when nothing triggers (or the re-solve
        confirms the lock-in)."""
        planned_s = {t.name: spec.devices[a].compute(t.ops)
                     for t, a in zip(spec.tasks, spec.assign) if a >= 0}
        comp = {e.task: e for e in measured.events if e.kind == "compute"}
        # trip candidates: compute slack (§11) AND copy slack — a stage
        # whose measured link transfer blows past its planned occupancy is
        # the same lock-in evidence, from the other side of the bus
        planned_stage = spec.stage_seconds()
        cand: list[tuple[float, str, str]] = []
        for n, e in comp.items():
            if planned_s.get(n, 0.0) > 0.0 and e.duration > \
                    self.straggler_threshold * planned_s[n]:
                cand.append((e.end, n, "straggler"))
        for e in measured.events:
            if e.kind in ("copy_in", "copy_out") and e.task is not None:
                ps = planned_stage.get(e.task, {}).get(e.kind, 0.0)
                if ps > 0.0 and e.duration > \
                        self.straggler_threshold * ps:
                    cand.append((e.end, e.task, "copy-straggler"))
        if not cand or job._replan_attempts >= self.max_replans_per_job:
            return None
        # detection moment: the first straggling stage to finish — the
        # earliest point a measured-vs-planned monitor has the evidence
        t_r, trip, reason = min(cand)
        first_start = {t.name: min((e.start for e in measured.events
                                    if e.task == t.name), default=math.inf)
                       for t in spec.tasks}
        # ancestor-close the freeze: the engine does not gate a task's
        # EXTERNAL input copy on its parents, so a consumer's first event
        # can precede a pending parent's — same closure as the threaded
        # monitor
        started, pend = _ancestor_closed_freeze(
            spec, [t.name for t in spec.tasks
                   if first_start[t.name] < t_r - 1e-12])
        index = {t.name: i for i, t in enumerate(spec.tasks)}
        if len(pend) < self.replan_min_frontier:
            return None
        if hasattr(job.workload, "frontier_subgraph"):
            job.workload.frontier_subgraph(started)
        # observations the tenant's pump would have delivered by t_r
        pump = job.tenant.pump if job.tenant is not None else None
        if pump is not None:
            for name in started:
                e = comp.get(name)
                if e is not None and e.end <= t_r + 1e-12 \
                        and name not in job._fed_tasks \
                        and spec.tasks[index[name]].ops > 0.0:
                    job._fed_tasks.add(name)
                    pump.observe(e.device,
                                 spec.tasks[index[name]].ops,
                                 e.duration * pump.time_scale)
        started_set = set(started)
        frozen_events = [e for e in measured.events
                         if e.task in started_set]
        # frozen tickets stay ahead of re-issued ones on every link, so the
        # frontier re-prices from the clocks the frozen tail leaves behind
        clocks = carry_clocks(Timeline(frozen_events), base)
        dyn = job.tenant.dyn if job.tenant is not None else None
        devices = dyn.snapshot() if dyn is not None \
            else list(spec.devices)
        if reason == "copy-straggler":
            devices = _copy_refit(devices, measured.events, planned_stage,
                                  until=t_r)
        # frozen pricing: same derivation as the threaded monitor (virtual
        # frozen events are complete, so the measured branches always hit)
        ext = self._frozen_ext(spec, started, Timeline(frozen_events),
                               t_r, devices, 1.0)
        pinned = {index[n]: spec.assign[index[n]] for n in started}
        res = solve_list_schedule(devices, spec.tasks, spec.edges,
                                  bus=spec.topology, pinned=pinned,
                                  ext=ext, clocks=clocks,
                                  seed_assign=spec.assign,
                                  cache=job._solve_cache)
        job._replan_attempts += 1
        if not self._worth_splicing(res, devices, spec, ext, clocks):
            return None   # the re-solve confirms the lock-in
        new_spec = dataclasses.replace(spec, devices=tuple(devices),
                                       assign=tuple(res.assign),
                                       order=tuple(res.order))
        ext_names = {spec.tasks[i].name: v for i, v in ext.items()}
        planned_frontier = new_spec.rebase_partial(clocks, ext=ext_names)
        truth_frontier = new_spec.rebase_partial(clocks, ext=ext_names,
                                                 devices=truth_devs)
        job.replans.append(ReplanRecord(
            at=t_r, straggler=trip, frozen=tuple(started),
            spliced=tuple(pend), spec=new_spec, planned=planned_frontier,
            reason=reason))
        return Timeline(sorted(frozen_events + truth_frontier.events,
                               key=lambda e: (e.start, e.end)))

    def _rescue_device_loss(self, job: StreamJob, name: str,
                            at: float) -> ReplanRecord | None:
        """Frontier-freeze + pinned re-solve of one in-flight job after
        ``name`` departs at stream time ``at`` — the §11 splice with the
        departed device *banned* instead of a straggler re-fit.  Unlike
        the straggler path there is no ``_worth_splicing`` gate: staying
        locked in is not an option once the device is gone."""
        spec = job.final_spec
        if not isinstance(spec, GraphTimelineSpec):
            return None
        dev_names = [d.name for d in spec.devices]
        if name not in dev_names:
            return None
        bi = dev_names.index(name)
        measured = job.measured
        first_start = {t.name: min((e.start for e in measured.events
                                    if e.task == t.name), default=math.inf)
                       for t in spec.tasks}
        started, pend = _ancestor_closed_freeze(
            spec, [t.name for t in spec.tasks
                   if first_start[t.name] < at - 1e-12])
        if not pend:
            return None   # everything had started: nothing left to move
        index = {t.name: i for i, t in enumerate(spec.tasks)}
        if all(spec.assign[index[n]] != bi for n in pend):
            return None   # the frontier never touches the departed device
        started_set = set(started)
        frozen_events = [e for e in measured.events if e.task in started_set]
        # retract by event IDENTITY (task names collide across jobs that
        # share a graph template — same rule as the preemption splice)
        retracted = {id(e) for e in measured.events
                     if e.task not in started_set}
        with self._lock:
            self._virtual_events = [e for e in self._virtual_events
                                    if id(e) not in retracted]
        clocks = carry_clocks(Timeline(frozen_events),
                              job._base_clocks or ClockState())
        if at > clocks.floor:
            # nothing re-issued can start before the loss was detected
            clocks = clocks.with_floor(at)
        devices = list(spec.devices)
        ext = self._frozen_ext(spec, started, Timeline(frozen_events),
                               at, devices, 1.0)
        # Graceful-drain evacuation: a frozen output resident only on the
        # departed device (avail = inf, "never staged") would pin its
        # consumers to a device that no longer exists.  Model the
        # departure notice staging it to the host at the moment of loss
        # (spot-preemption drain) over the device's outbound path; the
        # engine then charges any cross-host consumer the NIC hop as
        # usual.  Drain copies are priced but not given link occupancy —
        # the same simplification as the NIC hop itself (DESIGN.md §16).
        drain_dev = devices[bi]
        lk = spec.topology.link_of(name, "copy_out") \
            if spec.topology is not None else None
        for i, (c_end, avail) in list(ext.items()):
            if spec.assign[i] == bi and math.isinf(avail):
                t = spec.tasks[i]
                bw = drain_dev.copy.bandwidth_bytes_per_s
                if lk is not None and lk.bandwidth_bytes_per_s is not None:
                    bw = min(bw, lk.bandwidth_bytes_per_s)
                dur = 0.0 if (t.out_bytes <= 0.0 or math.isinf(bw)) \
                    else t.out_bytes / bw + drain_dev.copy.latency_s
                ext[i] = (c_end, max(c_end, at) + dur)
        pinned = {index[n]: spec.assign[index[n]] for n in started}
        res = solve_list_schedule(devices, spec.tasks, spec.edges,
                                  bus=spec.topology, pinned=pinned,
                                  ext=ext, clocks=clocks,
                                  max_evals=_REPLAN_MAX_EVALS,
                                  banned=frozenset({bi}),
                                  cache=job._solve_cache)
        new_spec = dataclasses.replace(spec, assign=tuple(res.assign),
                                       order=tuple(res.order))
        ext_names = {spec.tasks[i].name: v for i, v in ext.items()}
        planned_frontier = new_spec.rebase_partial(clocks, ext=ext_names)
        truth_devs = [self.truth(job.uid, d) if self.truth else d
                      for d in new_spec.devices]
        truth_frontier = new_spec.rebase_partial(clocks, ext=ext_names,
                                                 devices=truth_devs)
        rec = ReplanRecord(at=at, straggler=name, frozen=tuple(started),
                           spliced=tuple(pend), spec=new_spec,
                           planned=planned_frontier, reason="device-loss")
        job.replans.append(rec)
        job.measured = Timeline(sorted(
            frozen_events + list(truth_frontier.events),
            key=lambda e: (e.start, e.end)))
        self._meas_clocks = self._next_clocks(
            truth_frontier, carry_clocks(Timeline(frozen_events),
                                         job._base_clocks or ClockState()))
        with self._lock:
            self._virtual_events.extend(truth_frontier.events)
            self._virtual_finishes[job.uid] = job.measured.makespan
        return rec

    # -- threaded execution -------------------------------------------------

    def _execute_threads(self, job: StreamJob) -> None:
        tasks = self._task_factory(job, job.plan)
        order = job.plan.schedule.timeline.link_ticket_order()
        spec = job.plan.schedule.spec
        if isinstance(spec, GraphTimelineSpec):
            # what the straggler monitors compare measured stages against
            job._planned_compute = {
                t.name: spec.devices[a].compute(t.ops)
                for t, a in zip(spec.tasks, spec.assign) if a >= 0}
            job._planned_copy = _planned_copy_map(spec)
        handle = self._core.dispatch(tasks, order, job=f"j{job.uid}")
        job._handle = handle
        handle.add_done_callback(lambda h: self._complete(job, h))
        if self.preempt and job.tenant.qos.tier == TIER_LATENCY:
            # AFTER the latency job's dispatch: its tickets sit at the bus
            # tails now, and each victim's reissue appends BEHIND them
            self._preempt_threaded(job)

    def _preempt_threaded(self, lat: StreamJob) -> None:
        """Threads-mode priority preemption: revoke every running
        batch-tier DAG job's not-yet-started tickets and splice its
        re-solved frontier behind the just-dispatched latency job (§11
        ``reissue``/``rebase_partial`` machinery, reason ``"preempt"``).
        No predicted-gain gate — the point is the ticket ordering, not
        the victim's makespan."""
        with self._lock:
            victims = [j for j in self.jobs
                       if j is not lat and not j.done
                       and j._handle is not None
                       and j.tenant.qos.tier > lat.tenant.qos.tier
                       and j._preempt_attempts < 1]
        for victim in victims:
            self._splice_victim_threaded(victim, lat)

    def _splice_victim_threaded(self, victim: StreamJob,
                                lat: StreamJob) -> None:
        with victim._replan_lock:
            handle = victim._handle
            core = self._core
            if handle is None or core is None or handle.done \
                    or victim._preempt_attempts >= 1:
                return
            spec = victim.final_spec
            if not isinstance(spec, GraphTimelineSpec):
                return
            pending = core.pending_tasks(handle.job)
            started, frontier = _ancestor_closed_freeze(
                spec, [t.name for t in spec.tasks
                       if t.name not in pending])
            pend = set(frontier)
            if not pend:
                return
            victim._preempt_attempts += 1
            ts = self.time_scale
            dyn = victim.tenant.dyn if victim.tenant is not None else None
            devices = dyn.snapshot() if dyn is not None \
                else list(spec.devices)
            now_model = core.now() / ts
            measured = handle.timeline()
            ext = self._frozen_ext(spec, started, measured, now_model,
                                   devices, ts)
            clocks = self._splice_clocks(spec, ext, core.stream_timeline(),
                                         ts)
            if lat.planned is not None:
                # the latency job's planned occupancy: the victim's
                # frontier must price around the tickets now ahead of it
                clocks = clocks.merge(carry_clocks(lat.planned))
            index = {t.name: i for i, t in enumerate(spec.tasks)}
            pinned = {index[n]: spec.assign[index[n]] for n in started}
            res = solve_list_schedule(devices, spec.tasks, spec.edges,
                                      bus=spec.topology, pinned=pinned,
                                      ext=ext, clocks=clocks,
                                      seed_assign=spec.assign,
                                      max_evals=_REPLAN_MAX_EVALS,
                                      cache=victim._solve_cache)
            new_spec = dataclasses.replace(spec, devices=tuple(devices),
                                           assign=tuple(res.assign),
                                           order=tuple(res.order))
            victim._planned_compute = {
                t.name: devices[a].compute(t.ops)
                for t, a in zip(new_spec.tasks, new_spec.assign) if a >= 0}
            victim._planned_copy = _planned_copy_map(new_spec, devices)
            ext_names = {spec.tasks[i].name: v for i, v in ext.items()}
            front_tl = new_spec.rebase_partial(clocks, ext=ext_names)
            sched = dataclasses.replace(victim.plan.schedule,
                                        spec=new_spec, timeline=front_tl)
            plan2 = dataclasses.replace(victim.plan, schedule=sched)
            repl = [t for t in self._task_factory(victim, plan2)
                    if t.task in pend]
            spliced = core.reissue(handle, repl,
                                   front_tl.link_ticket_order())
            victim.replans.append(ReplanRecord(
                at=now_model, straggler=f"j{lat.uid}",
                frozen=tuple(started), spliced=tuple(spliced),
                spec=new_spec, planned=front_tl, reason="preempt"))

    # -- mid-graph re-planning (threads; DESIGN.md §11) ---------------------

    def _on_stream_event(self, jid: str, ev) -> None:
        """StreamCore event hook (runs on device worker threads): feed
        per-task compute measurements into the owning tenant's pump the
        moment they land, and trip the straggler monitor on
        planned-vs-measured slack — compute slack (§11) or copy slack
        (the link-straggler extension: a transfer blowing past its
        planned link occupancy is the same lock-in evidence)."""
        if ev.task is None:
            return
        try:
            uid = int(jid.lstrip("j"))
        except ValueError:
            return
        with self._lock:
            job = self.jobs[uid] if 0 <= uid < len(self.jobs) else None
        if job is None or job.plan is None:
            return
        spec = job.final_spec
        if not isinstance(spec, GraphTimelineSpec):
            return
        pump = job.tenant.pump if job.tenant is not None else None
        if ev.kind == "compute":
            ops = next((float(t.ops) for t in spec.tasks
                        if t.name == ev.task), 0.0)
            if pump is not None and ops > 0.0 and ev.duration > 0.0 \
                    and ev.task not in job._fed_tasks:
                job._fed_tasks.add(ev.task)
                pump.observe(ev.device, ops, ev.duration)
        if not self.replan:
            return
        measured_s = ev.duration / self.time_scale
        if ev.kind == "compute":
            planned_s = job._planned_compute.get(ev.task, 0.0)
            reason = "straggler"
        else:
            planned_s = job._planned_copy.get((ev.task, ev.kind), 0.0)
            reason = "copy-straggler"
        if planned_s <= 0.0 or measured_s <= \
                self.straggler_threshold * planned_s:
            return
        if (ev.task, ev.kind) in job._checked_tasks:
            return   # this stage's slack was already re-solved: lock-in held
        self._replan_threaded(job, ev, reason)

    def _frozen_ext(self, spec: GraphTimelineSpec, started: Sequence[str],
                    measured: Timeline, now_model: float,
                    devices: Sequence[DeviceProfile],
                    time_scale: float) -> dict[int, tuple[float, float]]:
        """(compute_end, avail) per frozen task, in model seconds: measured
        values where the stage already landed, refitted-model estimates for
        the still-running remainder; ``avail = inf`` marks an output that
        never reaches the host (so the re-solve cannot move its consumers
        off-device)."""
        index = {t.name: i for i, t in enumerate(spec.tasks)}
        stage_planned = spec.stage_seconds(devices)
        ext: dict[int, tuple[float, float]] = {}
        for name in started:
            i = index[name]
            a = spec.assign[i]
            if a < 0:
                continue
            t = spec.tasks[i]
            evs = measured.task_events(name)
            comp_ends = [e.end for e in evs if e.kind == "compute"]
            out_ends = [e.end for e in evs if e.kind == "copy_out"]
            if comp_ends:
                c_end = max(comp_ends) / time_scale
            else:   # running: charge the refitted model from now
                c_end = now_model + devices[a].compute(t.ops)
            if out_ends:
                avail = max(out_ends) / time_scale
            elif not _has_copy(devices[a]) or t.out_bytes <= 0.0:
                avail = c_end   # host-resident the moment compute ends
            elif stage_planned.get(name, {}).get("copy_out"):
                # staging planned but not yet measured: estimate
                avail = c_end + stage_planned[name]["copy_out"]
            else:
                avail = math.inf   # never staged: not host-readable
            ext[i] = (c_end, avail)
        return ext

    def _replan_threaded(self, job: StreamJob, ev,
                         reason: str = "straggler") -> None:
        with job._replan_lock:
            if job._replan_attempts >= self.max_replans_per_job:
                return
            handle = job._handle
            core = self._core
            if handle is None or core is None or handle.done:
                return
            spec = job.final_spec
            pending = core.pending_tasks(handle.job)
            started, frontier = _ancestor_closed_freeze(
                spec, [t.name for t in spec.tasks if t.name not in pending])
            pend = set(frontier)
            if len(pend) < self.replan_min_frontier:
                return
            if hasattr(job.workload, "frontier_subgraph"):
                # sanity: the closed snapshot is ancestor-closed by
                # construction; a raise here means the progress view is
                # corrupt
                job.workload.frontier_subgraph(started)
            ts = self.time_scale
            dyn = job.tenant.dyn if job.tenant is not None else None
            devices = dyn.snapshot() if dyn is not None \
                else list(spec.devices)
            now_model = core.now() / ts
            measured = handle.timeline()
            if reason == "copy-straggler":
                # measured wall durations -> model seconds before comparing
                scaled = [dataclasses.replace(e, start=e.start / ts,
                                              end=e.end / ts)
                          for e in measured.events]
                devices = _copy_refit(devices, scaled,
                                      spec.stage_seconds())
            ext = self._frozen_ext(spec, started, measured, now_model,
                                   devices, ts)
            clocks = self._splice_clocks(spec, ext, core.stream_timeline(),
                                         ts)
            index = {t.name: i for i, t in enumerate(spec.tasks)}
            pinned = {index[n]: spec.assign[index[n]] for n in started}
            # the re-solve runs ON the straggler's worker thread — that is
            # deliberate (it freezes the straggler's queue so its successors
            # stay migratable) but means solver latency stalls the splice:
            # cap the descent hard
            res = solve_list_schedule(devices, spec.tasks, spec.edges,
                                      bus=spec.topology, pinned=pinned,
                                      ext=ext, clocks=clocks,
                                      seed_assign=spec.assign,
                                      max_evals=_REPLAN_MAX_EVALS,
                                      cache=job._solve_cache)
            new_spec = dataclasses.replace(spec, devices=tuple(devices),
                                           assign=tuple(res.assign),
                                           order=tuple(res.order))
            if not self._worth_splicing(res, devices, spec, ext, clocks):
                # the re-solve confirms (or barely beats) the lock-in:
                # nothing to splice, and a no-op trigger (e.g.
                # sleep-overhead noise on a tiny task) must NOT burn the
                # job's re-plan budget.  The monitor baseline refreshes
                # from the re-fitted models under the assignment that
                # KEEPS executing — the original one, not the rejected
                # re-solve's.
                job._planned_compute = {
                    t.name: devices[a].compute(t.ops)
                    for t, a in zip(spec.tasks, spec.assign) if a >= 0}
                job._planned_copy = _planned_copy_map(spec, devices)
                job._checked_tasks.add((ev.task, ev.kind))
                return
            job._replan_attempts += 1
            job._planned_compute = {
                t.name: devices[a].compute(t.ops)
                for t, a in zip(new_spec.tasks, new_spec.assign) if a >= 0}
            job._planned_copy = _planned_copy_map(new_spec, devices)
            ext_names = {spec.tasks[i].name: v for i, v in ext.items()}
            frontier = new_spec.rebase_partial(clocks, ext=ext_names)
            sched = dataclasses.replace(job.plan.schedule, spec=new_spec,
                                        timeline=frontier)
            plan2 = dataclasses.replace(job.plan, schedule=sched)
            repl = [t for t in self._task_factory(job, plan2)
                    if t.task in pend]
            spliced = core.reissue(handle, repl,
                                   frontier.link_ticket_order())
            job.replans.append(ReplanRecord(
                at=now_model, straggler=ev.task, frozen=tuple(started),
                spliced=tuple(spliced), spec=new_spec, planned=frontier,
                reason=reason))

    def _worth_splicing(self, res, devices: Sequence[DeviceProfile],
                        spec: GraphTimelineSpec,
                        ext: Mapping[int, tuple[float, float]],
                        clocks: ClockState) -> bool:
        """Splice only for a real predicted gain: the re-solved makespan
        must beat the locked-in assignment re-priced under the SAME
        re-fitted models, frozen ext times, and carried clocks — and under
        its OWN planned order (that is what keeps executing if the splice
        is rejected)."""
        if tuple(res.assign) == tuple(spec.assign):
            return False
        seed_mk = max(graph_finish_times(devices, spec.tasks, spec.edges,
                                         spec.assign, topology=spec.topology,
                                         order=spec.order, clocks=clocks,
                                         ext=ext))
        return res.makespan * _REPLAN_MIN_GAIN < seed_mk

    def _splice_clocks(self, spec: GraphTimelineSpec,
                       ext: Mapping[int, tuple[float, float]],
                       stream: Timeline, time_scale: float) -> ClockState:
        """Where each link/device clock stands for the frontier re-pricing:
        the measured stream so far, floored by the frozen tasks' estimated
        tails (their pending copy_outs stay ahead of re-issued tickets on
        each link; a running compute holds its device)."""
        base = carry_clocks(stream)
        links = {k: v / time_scale for k, v in base.links.items()}
        devs = {k: v / time_scale for k, v in base.devices.items()}
        for i, (c_end, avail) in ext.items():
            a = spec.assign[i]
            if a < 0:
                continue
            dname = spec.devices[a].name
            devs[dname] = max(devs.get(dname, 0.0), c_end)
            if math.isfinite(avail) and avail > c_end:
                lk = spec.topology.link_of(dname, "out")
                if lk is not None:
                    links[lk.name] = max(links.get(lk.name, 0.0), avail)
        return ClockState(links=links, devices=devs)

    def _complete(self, job: StreamJob, handle) -> None:
        # Runs as a JobHandle done-callback on a device worker thread: it
        # must ALWAYS complete the job and free the in-flight slot, or one
        # bad observation (pump -> observe -> refit listeners) would wedge
        # the planner and every later job on that device.
        try:
            job.measured = handle.timeline()
            if handle.errors:
                job.error = handle.errors[0]
            else:
                self._feed(job)
        except BaseException as exc:
            if job.error is None:
                job.error = exc
        finally:
            job._done.set()
            self._inflight.release()

    def _feed(self, job: StreamJob) -> None:
        pump = job.tenant.pump if job.tenant is not None else None
        if pump is None or job.measured is None:
            return
        spec = job.final_spec
        if spec is None:
            return
        if isinstance(spec, GraphTimelineSpec):
            # DAG jobs observe per task (many sizes per device per job);
            # tasks already fed during execution (the straggler monitor's
            # early feed) are skipped, not observed twice
            rows = [r for r in spec.task_ops()
                    if r[0] not in job._fed_tasks]
            pump.feed_tasks(job.measured, rows)
        else:
            pump.feed(job.measured, spec.ops_by_device())


# ---------------------------------------------------------------------------
# Cross-plan invariant checks (tests + BENCH_streaming acceptance)
# ---------------------------------------------------------------------------


def _planned_link_order(j: StreamJob) -> dict[str, list[tuple]]:
    """The per-link grant order the job was *actually* issued under: the
    original plan's order for tickets never re-issued, then — for each
    mid-graph re-plan, in splice order — the frontier's re-planned order
    for the tasks that replan owns (the last splice of a task wins, exactly
    as the live buses saw it)."""
    planned = j.plan.schedule.timeline.link_ticket_order()
    if not j.replans:
        return planned
    owner: dict[str, int] = {}
    for idx, r in enumerate(j.replans):
        for name in r.spliced:
            owner[name] = idx
    out = {link: [t for t in seq
                  if not (len(t) == 3 and t[0] in owner)]
           for link, seq in planned.items()}
    for idx, r in enumerate(j.replans):
        for link, seq in r.planned.link_ticket_order().items():
            out.setdefault(link, []).extend(
                t for t in seq if owner.get(t[0]) == idx)
    return out


def verify_stream_invariants(jobs: Sequence[StreamJob], *,
                             eps: float = 1e-9) -> list[str]:
    """The Fig. 2 invariants, across plan boundaries.  Returns violations
    (empty = pass):

    * per link, ALL jobs' transfers serialize (no two copy events overlap,
      even from different plans);
    * per job and device, compute chunk j starts only after input chunk j
      landed, and output chunk j only after compute chunk j;
    * per job and link, the measured grant order equals the planned
      priority/ticket order — for a mid-graph re-planned job, the splice of
      the original order (frozen tasks) with each re-plan's frontier order.
    """
    problems: list[str] = []
    done = [j for j in jobs if j.measured is not None and j.error is None]

    # per-link serialization across the whole stream
    by_link: dict[str, list] = {}
    for j in done:
        for e in j.measured.events:
            if e.kind != "compute" and e.link is not None:
                by_link.setdefault(e.link, []).append(e)
    for link, evs in by_link.items():
        evs.sort(key=lambda e: (e.start, e.end))
        for a, b in zip(evs, evs[1:]):
            if b.start < a.end - eps:
                problems.append(
                    f"link {link}: {b.device}/{b.kind} starts {a.end - b.start:.3g}s "
                    f"before {a.device}/{a.kind} ends")

    for j in done:
        # copy-before-compute-before-copy-out, chunk-wise; task-graph
        # timelines group per (device, task) — a device runs many tasks
        for name, task in {(e.device, e.task) for e in j.measured.events}:
            evs = [e for e in j.measured.device_events(name)
                   if e.task == task]
            ins = sorted((e for e in evs if e.kind == "copy_in"),
                         key=lambda e: e.chunk)
            comps = sorted((e for e in evs if e.kind == "compute"),
                           key=lambda e: e.chunk)
            outs = sorted((e for e in evs if e.kind == "copy_out"),
                          key=lambda e: e.chunk)
            if task is not None:
                # DAG tasks: every input copy (external + edge reads) must
                # land before the single compute starts
                for i_ev in ins:
                    if comps and comps[0].start < i_ev.end - eps:
                        problems.append(
                            f"job {j.uid} {name}/{task}: compute before "
                            f"input copy {i_ev.chunk} landed")
                # EVERY output event must start after compute ends — the
                # old zip(comps[-1:], outs) paired only the first output
                # with the last compute, silently skipping the rest
                if comps:
                    c_end = comps[-1].end
                    for o_ev in outs:
                        if o_ev.start < c_end - eps:
                            problems.append(f"job {j.uid} {name}/{task}: "
                                            "copy_out before compute ended")
                continue
            for i_ev, c_ev in zip(ins, comps):
                if c_ev.start < i_ev.end - eps:
                    problems.append(f"job {j.uid} {name}: compute chunk "
                                    f"{c_ev.chunk} before its input landed")
            for c_ev, o_ev in zip(comps, outs):
                if o_ev.start < c_ev.end - eps:
                    problems.append(f"job {j.uid} {name}: copy_out chunk "
                                    f"{o_ev.chunk} before its compute ended")
        # planned per-link grant order is replayed (splice-aware)
        if j.plan is None:
            continue
        planned = _planned_link_order(j)
        measured = j.measured.link_ticket_order()
        for link, want in planned.items():
            got = measured.get(link, [])
            got_set = set(got)   # hoisted: one set, not one per element
            want = [t for t in want if t in got_set]   # subset task lists
            if got != want:
                problems.append(f"job {j.uid} link {link}: grant order "
                                f"{got} != planned {want}")
    return problems
