"""Persistent streaming co-execution runtime — plan → execute → observe →
re-plan as one loop (DESIGN.md §9).

The paper runs POAS once per application; its §3.4.2 dynamic mode, and any
deployment serving sustained traffic, need a *continuous* loop instead.
``CoExecutionRuntime`` keeps the whole pipeline alive across plans:

* an **admission queue** of POAS workloads for any registered ``Domain``;
* a planner thread running the four phases per job through the shared
  ``POAS``/``PlanCache`` (a cache hit skips the solve entirely);
* **plan-carry-over**: each plan's timeline is rebased onto the previous
  plan's carried link/device clocks (``core.bus.ClockState``), so plan
  k+1's input copies overlap plan k's tail instead of waiting for a global
  barrier;
* execution through the persistent ``StreamCore`` (long-lived per-device
  workers + per-link ticket buses, ``core.executor``) or through a
  deterministic **virtual-time** backend that prices the measured run on
  ground-truth device models;
* an **observation pump** converting each measured ``Timeline``'s compute
  events into ``DynamicScheduler.observe`` calls, so model re-fits,
  ``PlanCache`` invalidation, and re-planning happen automatically inside
  the loop — a device that starts throttling mid-stream sheds load within
  a few jobs without any caller wiring.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Callable, Mapping, Sequence

from .bus import ClockState, GraphTimelineSpec, Timeline, carry_clocks
from .device_model import (DeviceProfile, LinearTimeModel, RooflineTimeModel)
from .domain import Domain, PlanCache, Workload
from .executor import DeviceTask, StreamCore
from .framework import POAS, POASPlan
from .schedule import DynamicScheduler


# ---------------------------------------------------------------------------
# Observation pump — measured timelines feed the Predict phase
# ---------------------------------------------------------------------------


class ObservationPump:
    """Converts measured timelines into ``DynamicScheduler.observe`` calls.

    One pump is the single feedback path for every layer: the runtime feeds
    each job's measured compute events (``feed``), the serving dispatcher
    feeds per-bucket generation times, and the hetero train-step loop feeds
    per-pod step times (both via ``observe``).  ``time_scale`` converts
    measured wall seconds back to model seconds when execution is
    deliberately time-scaled (sleep-based testbeds).
    """

    def __init__(self, dyn: DynamicScheduler,
                 device_names: Sequence[str], *, time_scale: float = 1.0):
        self.dyn = dyn
        self.index = {name: i for i, name in enumerate(device_names)}
        self.time_scale = time_scale
        self.observations = 0

    def observe(self, device: str, ops: float, seconds: float) -> None:
        """One measured (ops, seconds) sample for a device, by name."""
        self.dyn.observe(self.index[device], float(ops),
                         float(seconds) / self.time_scale)
        self.observations += 1

    def feed(self, measured: Timeline,
             ops_by_device: Mapping[str, float]) -> int:
        """Pump every device's measured compute time (chunk durations
        summed) into the scheduler; returns the number of observations."""
        fed = 0
        for name, ops in ops_by_device.items():
            if name not in self.index or ops <= 0.0:
                continue
            seconds = sum(e.duration for e in measured.device_events(name)
                          if e.kind == "compute")
            if seconds > 0.0:
                self.observe(name, ops, seconds)
                fed += 1
        return fed

    def feed_tasks(self, measured: Timeline,
                   task_ops: Sequence[tuple[str, str, float]]) -> int:
        """Per-task observations for DAG jobs: each ``(task, device, ops)``
        row becomes its own ``observe`` call with that task's measured
        compute time — a single job yields many distinct (ops, seconds)
        samples per device, so the regression gets rank from one job
        instead of needing a stream of differently-sized jobs."""
        fed = 0
        for task, device, ops in task_ops:
            if device not in self.index or ops <= 0.0:
                continue
            seconds = sum(e.duration for e in measured.events
                          if e.task == task and e.device == device
                          and e.kind == "compute")
            if seconds > 0.0:
                self.observe(device, ops, seconds)
                fed += 1
        return fed


# ---------------------------------------------------------------------------
# Ground-truth helpers (testbeds: what the hardware *really* does)
# ---------------------------------------------------------------------------


def throttled(device: DeviceProfile, factor: float) -> DeviceProfile:
    """Ground-truth profile computing ``factor``× slower than ``device``
    (the paper's overheating scenario / a straggling pod)."""
    m = device.compute
    if isinstance(m, LinearTimeModel):
        slow = LinearTimeModel(a=m.a * factor, b=m.b * factor)
    elif isinstance(m, RooflineTimeModel):
        slow = RooflineTimeModel(peak_ops_per_s=m.peak_ops_per_s / factor,
                                 hbm_bytes_per_s=m.hbm_bytes_per_s / factor,
                                 bytes_per_op=m.bytes_per_op,
                                 overhead_s=m.overhead_s * factor)
    else:  # pragma: no cover - exotic model
        raise TypeError(f"cannot throttle {type(m).__name__}")
    return dataclasses.replace(device, compute=slow)


TruthFn = Callable[[int, DeviceProfile], DeviceProfile]
"""(job uid, planned device) -> the profile the hardware really runs at.

Must be anchored to FIXED ground-truth profiles: the planned device passed
in may already carry a re-fitted model, and deriving the truth from it
(e.g. ``throttled(planned, 2)``) compounds the slowdown on every re-fit —
the model chases its own tail to infinity.  Use ``truth_from_profiles``.
"""


def truth_from_profiles(base: Sequence[DeviceProfile],
                        slowdown: Callable[[int, str], float] | None = None
                        ) -> TruthFn:
    """A ``TruthFn`` pinned to fixed ground-truth ``base`` profiles.

    ``slowdown(job_uid, device_name)`` returns the throttle factor in
    effect for that job (1.0 = nominal) — e.g. a device overheating 2x
    from job 8 onward is ``lambda uid, name: 2.0 if uid >= 8 and
    name == "xpu" else 1.0``.
    """
    by_name = {d.name: d for d in base}

    def fn(uid: int, planned: DeviceProfile) -> DeviceProfile:
        d = by_name.get(planned.name, planned)
        f = slowdown(uid, d.name) if slowdown is not None else 1.0
        return throttled(d, f) if f != 1.0 else d

    return fn


def model_sleep_tasks(truth: TruthFn | None = None, *,
                      time_scale: float = 1.0) -> "TaskFactory":
    """Task factory whose stages sleep their ground-truth model durations —
    the simulated-testbed execution backend for the threaded runtime.

    ``truth`` substitutes what the device *really* does for what the plan
    believes (e.g. a mid-stream throttle); it is evaluated at execution
    time keyed on the job uid, so throttles are deterministic regardless of
    thread timing.  ``time_scale`` shrinks the sleeps; pair it with the
    runtime's ``time_scale`` so the pump converts back to model seconds.
    """

    def factory(job: "StreamJob", plan: POASPlan) -> list[DeviceTask]:
        spec = plan.schedule.spec
        if spec is None:
            raise ValueError("model_sleep_tasks needs Schedule.spec "
                             "(every shipped domain provides it)")
        if isinstance(spec, GraphTimelineSpec):
            return _graph_sleep_tasks(job, spec, truth, time_scale)
        kinds = {(e.device, e.kind) for e in plan.schedule.timeline.events}
        tasks: list[DeviceTask] = []
        for d, c in zip(spec.devices, spec.ops):
            if c <= 0.0:
                continue

            def true_dev(d=d) -> DeviceProfile:
                return truth(job.uid, d) if truth is not None else d

            def sleep_in(d=d, c=c):
                time.sleep(true_dev(d).copy.in_time(c, spec.n, spec.k)
                           * time_scale)

            def sleep_compute(d=d, c=c):
                time.sleep(true_dev(d).compute(c) * time_scale)

            def sleep_out(d=d, c=c):
                time.sleep(true_dev(d).copy.out_time(c, spec.n, spec.k)
                           * time_scale)

            has_in = (d.name, "copy_in") in kinds
            has_out = (d.name, "copy_out") in kinds
            tasks.append(DeviceTask(device=d.name,
                                    copy_in=sleep_in if has_in else None,
                                    compute=sleep_compute,
                                    copy_out=sleep_out if has_out else None))
        return tasks

    return factory


def _graph_sleep_tasks(job: "StreamJob", spec: GraphTimelineSpec,
                       truth: TruthFn | None,
                       time_scale: float) -> list[DeviceTask]:
    """Sleep-stage ``DeviceTask``s for a task-graph plan: one stage group
    per DAG task (``task``/``deps`` set so the StreamCore blocks on
    upstream completion), durations re-priced per stage under the
    ground-truth profiles via the spec's own engine rebase."""
    truth_devs = [truth(job.uid, d) if truth is not None else d
                  for d in spec.devices]
    seconds = spec.stage_seconds(truth_devs)
    parents = spec.parents_of()
    tasks: list[DeviceTask] = []
    # planned order, NOT node order: each device's worker runs its stage
    # groups strictly in dispatch order, so a same-device dependency queued
    # out of topological order would deadlock the worker on its own queue
    for i in spec.order:
        t, a = spec.tasks[i], spec.assign[i]
        if a < 0:
            continue
        dev = spec.devices[a].name
        stage = seconds.get(t.name, {})

        def sleeper(s: float):
            return (lambda: time.sleep(s * time_scale))

        tasks.append(DeviceTask(
            device=dev,
            copy_in=sleeper(stage["copy_in"]) if stage.get("copy_in")
            else None,
            compute=sleeper(stage.get("compute", 0.0)),
            copy_out=sleeper(stage["copy_out"]) if stage.get("copy_out")
            else None,
            task=t.name, deps=parents.get(t.name, ())))
    return tasks


# ---------------------------------------------------------------------------
# Stream jobs
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StreamJob:
    """One admitted workload's lifecycle through the loop."""

    uid: int
    workload: Workload
    plan: POASPlan | None = None
    planned: Timeline | None = None    # rebased onto carried clocks
    measured: Timeline | None = None
    error: BaseException | None = None
    epoch_at_plan: int = 0             # DynamicScheduler.epoch when planned
    _done: threading.Event = dataclasses.field(
        default_factory=threading.Event)

    def wait(self, timeout: float | None = None) -> "StreamJob":
        if not self._done.wait(timeout):
            raise TimeoutError(f"job {self.uid} still running")
        if self.error is not None:
            raise self.error
        return self

    @property
    def done(self) -> bool:
        return self._done.is_set()

    @property
    def start(self) -> float:
        if self.measured is None:
            return 0.0
        return min((e.start for e in self.measured.events), default=0.0)

    @property
    def finish(self) -> float:
        return self.measured.makespan if self.measured else 0.0

    @property
    def span(self) -> float:
        """Measured latency of this job (first stage start → last end)."""
        return self.finish - self.start


TaskFactory = Callable[[StreamJob, POASPlan], Sequence[DeviceTask]]


# ---------------------------------------------------------------------------
# The runtime
# ---------------------------------------------------------------------------


class CoExecutionRuntime:
    """Persistent plan→execute→observe→re-plan loop over one bound domain.

    Parameters
    ----------
    domain:
        any registered POAS ``Domain``.  If it carries a ``DynamicScheduler``
        (``domain.dyn``) and ``feedback`` is on, measured timelines are
        pumped back into it.
    executor:
        ``"threads"`` — the real ``StreamCore`` (long-lived per-device
        workers, per-link ticket buses surviving across plans); stage
        callables come from ``task_factory`` (default: ground-truth sleeps
        via ``model_sleep_tasks``).
        ``"virtual"`` — deterministic virtual time: the measured timeline is
        the engine's pricing of the plan under the ground-truth profiles
        (``truth``), chained on carried measured clocks.  Planning latency
        does not pollute the stream, so throughput comparisons are exact.
    carry_clocks:
        rebase each plan onto the previous plan's carried link/device
        clocks (overlapped back-to-back plans).  Off = a global barrier
        between plans.
    feedback:
        pump measured compute events into ``domain.dyn`` after each job
        (model re-fit → ``PlanCache`` invalidation → re-plan, automatically).
    max_inflight:
        how many jobs may be planned ahead of the oldest unfinished one.
        In virtual mode this sets the observation lag (a plan dispatched
        while k jobs are in flight cannot have seen their measurements).
    """

    def __init__(self, domain: Domain, *,
                 executor: str = "threads",
                 task_factory: TaskFactory | None = None,
                 truth: TruthFn | None = None,
                 cache: bool = True,
                 feedback: bool = True,
                 carry_clocks: bool = True,
                 max_inflight: int = 2,
                 time_scale: float = 1.0):
        if executor not in ("threads", "virtual"):
            raise ValueError(f"unknown executor {executor!r}")
        self.domain = domain
        self.poas = POAS(domain, cache=PlanCache() if cache else None)
        self.dyn: DynamicScheduler | None = getattr(domain, "dyn", None)
        self.carry = bool(carry_clocks)
        self.max_inflight = max(1, int(max_inflight))
        self.executor = executor
        self.truth = truth
        self.time_scale = time_scale
        names = [d.name for d in domain.predict()]
        self.pump: ObservationPump | None = None
        if feedback and self.dyn is not None:
            self.pump = ObservationPump(self.dyn, names,
                                        time_scale=time_scale)
        self.jobs: list[StreamJob] = []
        self._task_factory = task_factory or model_sleep_tasks(
            truth, time_scale=time_scale)
        self._core = StreamCore() if executor == "threads" else None
        self._plan_clocks = ClockState()
        self._meas_clocks = ClockState()
        self._virtual_events: list = []
        self._pending_obs: list[StreamJob] = []   # virtual-mode obs lag
        self._queue: queue.SimpleQueue = queue.SimpleQueue()
        self._inflight = threading.Semaphore(self.max_inflight)
        self._lock = threading.Lock()
        self._closed = False
        self._planner = threading.Thread(target=self._plan_loop,
                                         name="poas-planner", daemon=True)
        self._planner.start()

    # -- admission ----------------------------------------------------------

    def submit(self, workload: Workload) -> StreamJob:
        """Admit one workload; returns immediately with its ``StreamJob``."""
        with self._lock:
            if self._closed:
                raise RuntimeError("runtime is shut down")
            job = StreamJob(uid=len(self.jobs), workload=workload)
            self.jobs.append(job)
        self._queue.put(job)
        return job

    def run_stream(self, workloads: Sequence[Workload],
                   timeout: float | None = 120.0) -> list[StreamJob]:
        """Submit every workload, wait for all of them, return their jobs."""
        jobs = [self.submit(w) for w in workloads]
        for j in jobs:
            j.wait(timeout)
        return jobs

    def drain(self, timeout: float | None = 120.0) -> None:
        with self._lock:
            jobs = list(self.jobs)
        for j in jobs:
            j._done.wait(timeout)

    def shutdown(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self._queue.put(None)
        self._planner.join(timeout=60)
        if self._core is not None:
            self._core.shutdown()

    def __enter__(self) -> "CoExecutionRuntime":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # -- introspection ------------------------------------------------------

    @property
    def plan_cache(self) -> PlanCache | None:
        return self.poas.cache

    def stream_timeline(self) -> Timeline:
        """Every job's measured events on one time axis — the cross-plan
        invariant surface."""
        if self._core is not None:
            return self._core.stream_timeline()
        with self._lock:
            events = list(self._virtual_events)
        return Timeline(sorted(events, key=lambda e: (e.start, e.end)))

    def total_makespan(self) -> float:
        return self.stream_timeline().makespan

    def stats(self) -> dict:
        with self._lock:
            done = [j for j in self.jobs if j.done and j.error is None]
        spans = sorted(j.span for j in done)
        p = lambda q: spans[min(len(spans) - 1, int(q * len(spans)))] \
            if spans else 0.0
        return {
            "jobs_done": len(done),
            "total_makespan_s": self.total_makespan(),
            "p50_job_span_s": p(0.50),
            "p95_job_span_s": p(0.95),
            "observations": self.pump.observations if self.pump else 0,
            "refit_epoch": self.dyn.epoch if self.dyn else 0,
            "plan_cache": self.poas.cache.stats() if self.poas.cache else {},
        }

    # -- the loop -----------------------------------------------------------

    def _next_clocks(self, timeline: Timeline, clocks: ClockState) -> ClockState:
        if self.carry:
            return carry_clocks(timeline, clocks)
        return ClockState(floor=max(timeline.makespan, clocks.floor))

    def _plan_loop(self) -> None:
        while True:
            job = self._queue.get()
            if job is None:
                return
            self._inflight.acquire()
            try:
                self._plan_and_dispatch(job)
            except BaseException as exc:
                job.error = exc
                job._done.set()
                self._inflight.release()

    def _plan_and_dispatch(self, job: StreamJob) -> None:
        if self.executor == "virtual":
            # flush observations old enough that a real pipeline would have
            # seen them (jobs completed before this one was planned)
            lag = self.max_inflight - 1
            while self._pending_obs and self._pending_obs[0].uid <= job.uid - 1 - lag:
                self._feed(self._pending_obs.pop(0))
        if self.dyn is not None:
            job.epoch_at_plan = self.dyn.epoch
        plan = self.poas.plan(job.workload)
        job.plan = plan
        spec = plan.schedule.spec
        if spec is not None:
            job.planned = spec.rebase(self._plan_clocks)
            self._plan_clocks = self._next_clocks(job.planned,
                                                  self._plan_clocks)
        else:
            job.planned = plan.schedule.timeline
        if self.executor == "virtual":
            self._execute_virtual(job)
        else:
            self._execute_threads(job)

    # -- virtual-time execution --------------------------------------------

    def _execute_virtual(self, job: StreamJob) -> None:
        spec = job.plan.schedule.spec
        if spec is None:
            raise ValueError("virtual execution needs Schedule.spec")
        truth_devs = [self.truth(job.uid, d) if self.truth else d
                      for d in spec.devices]
        job.measured = spec.rebase(self._meas_clocks, devices=truth_devs)
        self._meas_clocks = self._next_clocks(job.measured, self._meas_clocks)
        with self._lock:
            self._virtual_events.extend(job.measured.events)
        self._pending_obs.append(job)
        job._done.set()
        self._inflight.release()

    # -- threaded execution -------------------------------------------------

    def _execute_threads(self, job: StreamJob) -> None:
        tasks = self._task_factory(job, job.plan)
        order = job.plan.schedule.timeline.link_ticket_order()
        handle = self._core.dispatch(tasks, order, job=f"j{job.uid}")
        handle.add_done_callback(lambda h: self._complete(job, h))

    def _complete(self, job: StreamJob, handle) -> None:
        # Runs as a JobHandle done-callback on a device worker thread: it
        # must ALWAYS complete the job and free the in-flight slot, or one
        # bad observation (pump -> observe -> refit listeners) would wedge
        # the planner and every later job on that device.
        try:
            job.measured = handle.timeline()
            if handle.errors:
                job.error = handle.errors[0]
            elif self.pump is not None:
                self._feed(job)
        except BaseException as exc:
            if job.error is None:
                job.error = exc
        finally:
            job._done.set()
            self._inflight.release()

    def _feed(self, job: StreamJob) -> None:
        if self.pump is None or job.measured is None:
            return
        spec = job.plan.schedule.spec if job.plan else None
        if spec is None:
            return
        if isinstance(spec, GraphTimelineSpec):
            # DAG jobs observe per task (many sizes per device per job)
            self.pump.feed_tasks(job.measured, spec.task_ops())
        else:
            self.pump.feed(job.measured, spec.ops_by_device())


# ---------------------------------------------------------------------------
# Cross-plan invariant checks (tests + BENCH_streaming acceptance)
# ---------------------------------------------------------------------------


def verify_stream_invariants(jobs: Sequence[StreamJob], *,
                             eps: float = 1e-9) -> list[str]:
    """The Fig. 2 invariants, across plan boundaries.  Returns violations
    (empty = pass):

    * per link, ALL jobs' transfers serialize (no two copy events overlap,
      even from different plans);
    * per job and device, compute chunk j starts only after input chunk j
      landed, and output chunk j only after compute chunk j;
    * per job and link, the measured grant order equals the planned
      priority/ticket order.
    """
    problems: list[str] = []
    done = [j for j in jobs if j.measured is not None and j.error is None]

    # per-link serialization across the whole stream
    by_link: dict[str, list] = {}
    for j in done:
        for e in j.measured.events:
            if e.kind != "compute" and e.link is not None:
                by_link.setdefault(e.link, []).append(e)
    for link, evs in by_link.items():
        evs.sort(key=lambda e: (e.start, e.end))
        for a, b in zip(evs, evs[1:]):
            if b.start < a.end - eps:
                problems.append(
                    f"link {link}: {b.device}/{b.kind} starts {a.end - b.start:.3g}s "
                    f"before {a.device}/{a.kind} ends")

    for j in done:
        # copy-before-compute-before-copy-out, chunk-wise; task-graph
        # timelines group per (device, task) — a device runs many tasks
        for name, task in {(e.device, e.task) for e in j.measured.events}:
            evs = [e for e in j.measured.device_events(name)
                   if e.task == task]
            ins = sorted((e for e in evs if e.kind == "copy_in"),
                         key=lambda e: e.chunk)
            comps = sorted((e for e in evs if e.kind == "compute"),
                           key=lambda e: e.chunk)
            outs = sorted((e for e in evs if e.kind == "copy_out"),
                          key=lambda e: e.chunk)
            if task is not None:
                # DAG tasks: every input copy (external + edge reads) must
                # land before the single compute starts
                for i_ev in ins:
                    if comps and comps[0].start < i_ev.end - eps:
                        problems.append(
                            f"job {j.uid} {name}/{task}: compute before "
                            f"input copy {i_ev.chunk} landed")
                for c_ev, o_ev in zip(comps[-1:], outs):
                    if o_ev.start < c_ev.end - eps:
                        problems.append(f"job {j.uid} {name}/{task}: "
                                        "copy_out before compute ended")
                continue
            for i_ev, c_ev in zip(ins, comps):
                if c_ev.start < i_ev.end - eps:
                    problems.append(f"job {j.uid} {name}: compute chunk "
                                    f"{c_ev.chunk} before its input landed")
            for c_ev, o_ev in zip(comps, outs):
                if o_ev.start < c_ev.end - eps:
                    problems.append(f"job {j.uid} {name}: copy_out chunk "
                                    f"{o_ev.chunk} before its compute ended")
        # planned per-link grant order is replayed
        if j.plan is None:
            continue
        planned = j.plan.schedule.timeline.link_ticket_order()
        measured = j.measured.link_ticket_order()
        for link, want in planned.items():
            got = measured.get(link, [])
            want = [t for t in want if t in set(got)]  # subset task lists
            if got != want:
                problems.append(f"job {j.uid} link {link}: grant order "
                                f"{got} != planned {want}")
    return problems
