"""Persistent streaming co-execution runtime — plan → execute → observe →
re-plan as one loop (DESIGN.md §9).

The paper runs POAS once per application; its §3.4.2 dynamic mode, and any
deployment serving sustained traffic, need a *continuous* loop instead.
``CoExecutionRuntime`` keeps the whole pipeline alive across plans:

* an **admission queue** of POAS workloads for any registered ``Domain``;
* a planner thread running the four phases per job through the shared
  ``POAS``/``PlanCache`` (a cache hit skips the solve entirely);
* **plan-carry-over**: each plan's timeline is rebased onto the previous
  plan's carried link/device clocks (``core.bus.ClockState``), so plan
  k+1's input copies overlap plan k's tail instead of waiting for a global
  barrier;
* execution through the persistent ``StreamCore`` (long-lived per-device
  workers + per-link ticket buses, ``core.executor``) or through a
  deterministic **virtual-time** backend that prices the measured run on
  ground-truth device models;
* an **observation pump** converting each measured ``Timeline``'s compute
  events into ``DynamicScheduler.observe`` calls, so model re-fits,
  ``PlanCache`` invalidation, and re-planning happen automatically inside
  the loop — a device that starts throttling mid-stream sheds load within
  a few jobs without any caller wiring.
"""
from __future__ import annotations

import dataclasses
import math
import queue
import threading
import time
from typing import Callable, Mapping, Sequence

from .bus import (ClockState, GraphTimelineSpec, Timeline, _has_copy,
                  carry_clocks, graph_finish_times)
from .device_model import (DeviceProfile, LinearTimeModel, RooflineTimeModel)
from .domain import Domain, PlanCache, Workload
from .executor import DeviceTask, StreamCore
from .framework import POAS, POASPlan
from .optimize import solve_list_schedule
from .schedule import DynamicScheduler


# ---------------------------------------------------------------------------
# Observation pump — measured timelines feed the Predict phase
# ---------------------------------------------------------------------------


class ObservationPump:
    """Converts measured timelines into ``DynamicScheduler.observe`` calls.

    One pump is the single feedback path for every layer: the runtime feeds
    each job's measured compute events (``feed``), the serving dispatcher
    feeds per-bucket generation times, and the hetero train-step loop feeds
    per-pod step times (both via ``observe``).  ``time_scale`` converts
    measured wall seconds back to model seconds when execution is
    deliberately time-scaled (sleep-based testbeds).
    """

    def __init__(self, dyn: DynamicScheduler,
                 device_names: Sequence[str], *, time_scale: float = 1.0):
        self.dyn = dyn
        self.index = {name: i for i, name in enumerate(device_names)}
        self.time_scale = time_scale
        self.observations = 0

    def observe(self, device: str, ops: float, seconds: float) -> None:
        """One measured (ops, seconds) sample for a device, by name."""
        self.dyn.observe(self.index[device], float(ops),
                         float(seconds) / self.time_scale)
        self.observations += 1

    def feed(self, measured: Timeline,
             ops_by_device: Mapping[str, float]) -> int:
        """Pump every device's measured compute time (chunk durations
        summed) into the scheduler; returns the number of observations."""
        fed = 0
        for name, ops in ops_by_device.items():
            if name not in self.index or ops <= 0.0:
                continue
            seconds = sum(e.duration for e in measured.device_events(name)
                          if e.kind == "compute")
            if seconds > 0.0:
                self.observe(name, ops, seconds)
                fed += 1
        return fed

    def feed_tasks(self, measured: Timeline,
                   task_ops: Sequence[tuple[str, str, float]]) -> int:
        """Per-task observations for DAG jobs: each ``(task, device, ops)``
        row becomes its own ``observe`` call with that task's measured
        compute time — a single job yields many distinct (ops, seconds)
        samples per device, so the regression gets rank from one job
        instead of needing a stream of differently-sized jobs."""
        fed = 0
        for task, device, ops in task_ops:
            if device not in self.index or ops <= 0.0:
                continue
            seconds = sum(e.duration for e in measured.events
                          if e.task == task and e.device == device
                          and e.kind == "compute")
            if seconds > 0.0:
                self.observe(device, ops, seconds)
                fed += 1
        return fed


# ---------------------------------------------------------------------------
# Ground-truth helpers (testbeds: what the hardware *really* does)
# ---------------------------------------------------------------------------


def throttled(device: DeviceProfile, factor: float) -> DeviceProfile:
    """Ground-truth profile computing ``factor``× slower than ``device``
    (the paper's overheating scenario / a straggling pod)."""
    m = device.compute
    if isinstance(m, LinearTimeModel):
        slow = LinearTimeModel(a=m.a * factor, b=m.b * factor)
    elif isinstance(m, RooflineTimeModel):
        slow = RooflineTimeModel(peak_ops_per_s=m.peak_ops_per_s / factor,
                                 hbm_bytes_per_s=m.hbm_bytes_per_s / factor,
                                 bytes_per_op=m.bytes_per_op,
                                 overhead_s=m.overhead_s * factor)
    else:  # pragma: no cover - exotic model
        raise TypeError(f"cannot throttle {type(m).__name__}")
    return dataclasses.replace(device, compute=slow)


TruthFn = Callable[[int, DeviceProfile], DeviceProfile]
"""(job uid, planned device) -> the profile the hardware really runs at.

Must be anchored to FIXED ground-truth profiles: the planned device passed
in may already carry a re-fitted model, and deriving the truth from it
(e.g. ``throttled(planned, 2)``) compounds the slowdown on every re-fit —
the model chases its own tail to infinity.  Use ``truth_from_profiles``.
"""


def truth_from_profiles(base: Sequence[DeviceProfile],
                        slowdown: Callable[[int, str], float] | None = None
                        ) -> TruthFn:
    """A ``TruthFn`` pinned to fixed ground-truth ``base`` profiles.

    ``slowdown(job_uid, device_name)`` returns the throttle factor in
    effect for that job (1.0 = nominal) — e.g. a device overheating 2x
    from job 8 onward is ``lambda uid, name: 2.0 if uid >= 8 and
    name == "xpu" else 1.0``.
    """
    by_name = {d.name: d for d in base}

    def fn(uid: int, planned: DeviceProfile) -> DeviceProfile:
        d = by_name.get(planned.name, planned)
        f = slowdown(uid, d.name) if slowdown is not None else 1.0
        return throttled(d, f) if f != 1.0 else d

    return fn


def model_sleep_tasks(truth: TruthFn | None = None, *,
                      time_scale: float = 1.0) -> "TaskFactory":
    """Task factory whose stages sleep their ground-truth model durations —
    the simulated-testbed execution backend for the threaded runtime.

    ``truth`` substitutes what the device *really* does for what the plan
    believes (e.g. a mid-stream throttle); it is evaluated at execution
    time keyed on the job uid, so throttles are deterministic regardless of
    thread timing.  ``time_scale`` shrinks the sleeps; pair it with the
    runtime's ``time_scale`` so the pump converts back to model seconds.
    """

    def factory(job: "StreamJob", plan: POASPlan) -> list[DeviceTask]:
        spec = plan.schedule.spec
        if spec is None:
            raise ValueError("model_sleep_tasks needs Schedule.spec "
                             "(every shipped domain provides it)")
        if isinstance(spec, GraphTimelineSpec):
            return _graph_sleep_tasks(job, spec, truth, time_scale)
        kinds = {(e.device, e.kind) for e in plan.schedule.timeline.events}
        tasks: list[DeviceTask] = []
        for d, c in zip(spec.devices, spec.ops):
            if c <= 0.0:
                continue

            def true_dev(d=d) -> DeviceProfile:
                return truth(job.uid, d) if truth is not None else d

            def sleep_in(d=d, c=c):
                time.sleep(true_dev(d).copy.in_time(c, spec.n, spec.k)
                           * time_scale)

            def sleep_compute(d=d, c=c):
                time.sleep(true_dev(d).compute(c) * time_scale)

            def sleep_out(d=d, c=c):
                time.sleep(true_dev(d).copy.out_time(c, spec.n, spec.k)
                           * time_scale)

            has_in = (d.name, "copy_in") in kinds
            has_out = (d.name, "copy_out") in kinds
            tasks.append(DeviceTask(device=d.name,
                                    copy_in=sleep_in if has_in else None,
                                    compute=sleep_compute,
                                    copy_out=sleep_out if has_out else None))
        return tasks

    return factory


def _graph_sleep_tasks(job: "StreamJob", spec: GraphTimelineSpec,
                       truth: TruthFn | None,
                       time_scale: float) -> list[DeviceTask]:
    """Sleep-stage ``DeviceTask``s for a task-graph plan: one stage group
    per DAG task (``task``/``deps`` set so the StreamCore blocks on
    upstream completion), durations re-priced per stage under the
    ground-truth profiles via the spec's own engine rebase."""
    truth_devs = [truth(job.uid, d) if truth is not None else d
                  for d in spec.devices]
    seconds = spec.stage_seconds(truth_devs)
    parents = spec.parents_of()
    tasks: list[DeviceTask] = []
    # planned order, NOT node order: each device's worker runs its stage
    # groups strictly in dispatch order, so a same-device dependency queued
    # out of topological order would deadlock the worker on its own queue
    for i in spec.order:
        t, a = spec.tasks[i], spec.assign[i]
        if a < 0:
            continue
        dev = spec.devices[a].name
        stage = seconds.get(t.name, {})

        def sleeper(s: float):
            return (lambda: time.sleep(s * time_scale))

        tasks.append(DeviceTask(
            device=dev,
            copy_in=sleeper(stage["copy_in"]) if stage.get("copy_in")
            else None,
            compute=sleeper(stage.get("compute", 0.0)),
            copy_out=sleeper(stage["copy_out"]) if stage.get("copy_out")
            else None,
            task=t.name, deps=parents.get(t.name, ())))
    return tasks


# ---------------------------------------------------------------------------
# Stream jobs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ReplanRecord:
    """One mid-graph re-plan splice on a live DAG job (DESIGN.md §11).

    ``frozen`` are the completed/running tasks kept in place, ``spliced``
    the not-yet-started tasks whose tickets were revoked and re-issued
    under ``spec`` (the re-solved full-graph spec, frozen assignments
    pinned); ``planned`` is the frontier's re-planned partial timeline —
    its per-link ticket order is what the executor spliced in, and what
    ``verify_stream_invariants`` checks the measured grant order against.
    """

    at: float                    # stream time (model seconds) of the splice
    straggler: str               # task whose slack tripped the monitor
    frozen: tuple[str, ...]
    spliced: tuple[str, ...]
    spec: GraphTimelineSpec
    planned: Timeline


@dataclasses.dataclass
class StreamJob:
    """One admitted workload's lifecycle through the loop."""

    uid: int
    workload: Workload
    plan: POASPlan | None = None
    planned: Timeline | None = None    # rebased onto carried clocks
    measured: Timeline | None = None
    error: BaseException | None = None
    epoch_at_plan: int = 0             # DynamicScheduler.epoch when planned
    replans: list[ReplanRecord] = dataclasses.field(default_factory=list)
    _done: threading.Event = dataclasses.field(
        default_factory=threading.Event)
    # mid-execution bookkeeping (threads: the straggler monitor runs on
    # device worker threads; virtual: the deterministic replay)
    _fed_tasks: set = dataclasses.field(default_factory=set)
    _planned_compute: dict = dataclasses.field(default_factory=dict)
    _handle: object = None
    _replan_attempts: int = 0
    # tasks whose straggler trigger was evaluated and produced no splice
    # (the re-solve confirmed the lock-in): don't re-solve for them again
    _checked_tasks: set = dataclasses.field(default_factory=set)
    _replan_lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock)

    def wait(self, timeout: float | None = None) -> "StreamJob":
        if not self._done.wait(timeout):
            raise TimeoutError(f"job {self.uid} still running")
        if self.error is not None:
            raise self.error
        return self

    @property
    def done(self) -> bool:
        return self._done.is_set()

    @property
    def start(self) -> float:
        if self.measured is None:
            return 0.0
        return min((e.start for e in self.measured.events), default=0.0)

    @property
    def finish(self) -> float:
        return self.measured.makespan if self.measured else 0.0

    @property
    def span(self) -> float:
        """Measured latency of this job (first stage start → last end)."""
        return self.finish - self.start

    @property
    def final_spec(self):
        """The spec the job actually executed under: the last re-plan's
        spec when the job was spliced mid-graph, else the planned one."""
        if self.replans:
            return self.replans[-1].spec
        return self.plan.schedule.spec if self.plan is not None else None


TaskFactory = Callable[[StreamJob, POASPlan], Sequence[DeviceTask]]

def _ancestor_closed_freeze(spec: GraphTimelineSpec,
                            started: Sequence[str]
                            ) -> tuple[list[str], list[str]]:
    """(frozen, frontier) for a mid-graph re-plan: the started set closed
    over ancestors, and the migratable remainder, both in task order.

    A stage group counts as started the moment its device worker picks it
    up — possibly while a cross-device parent is still pending (the group
    blocks in its dependency wait).  That consumer's stages were built
    against the parent's original placement, so the parent must freeze in
    place too: without the closure the progress snapshot would not be
    ancestor-closed and ``frontier_subgraph`` would (rightly) reject it.
    """
    parents = spec.parents_of()
    frozen = set(started)
    stack = list(started)
    while stack:
        for u in parents.get(stack.pop(), ()):
            if u not in frozen:
                frozen.add(u)
                stack.append(u)
    frozen_l = [t.name for t in spec.tasks if t.name in frozen]
    frontier = [t.name for t, a in zip(spec.tasks, spec.assign)
                if a >= 0 and t.name not in frozen]
    return frozen_l, frontier


# Per-descent evaluation cap for the threaded mid-graph re-solve: it runs
# in-line on the straggling device's worker thread (freezing its queue), and
# on a serialized bus the other devices' first copies wait on the straggler's
# revoked grants — every engine evaluation directly delays the whole splice.
_REPLAN_MAX_EVALS = 80

# Predicted-gain gate: splice only when the re-solved frontier beats the
# locked-in plan (re-priced under the same re-fitted models, ext and clocks)
# by at least this factor — a marginal prediction is not worth the splice.
_REPLAN_MIN_GAIN = 1.05


# ---------------------------------------------------------------------------
# The runtime
# ---------------------------------------------------------------------------


class CoExecutionRuntime:
    """Persistent plan→execute→observe→re-plan loop over one bound domain.

    Parameters
    ----------
    domain:
        any registered POAS ``Domain``.  If it carries a ``DynamicScheduler``
        (``domain.dyn``) and ``feedback`` is on, measured timelines are
        pumped back into it.
    executor:
        ``"threads"`` — the real ``StreamCore`` (long-lived per-device
        workers, per-link ticket buses surviving across plans); stage
        callables come from ``task_factory`` (default: ground-truth sleeps
        via ``model_sleep_tasks``).
        ``"virtual"`` — deterministic virtual time: the measured timeline is
        the engine's pricing of the plan under the ground-truth profiles
        (``truth``), chained on carried measured clocks.  Planning latency
        does not pollute the stream, so throughput comparisons are exact.
    carry_clocks:
        rebase each plan onto the previous plan's carried link/device
        clocks (overlapped back-to-back plans).  Off = a global barrier
        between plans.
    feedback:
        pump measured compute events into ``domain.dyn`` after each job
        (model re-fit → ``PlanCache`` invalidation → re-plan, automatically).
    max_inflight:
        how many jobs may be planned ahead of the oldest unfinished one.
        In virtual mode this sets the observation lag (a plan dispatched
        while k jobs are in flight cannot have seen their measurements).
    replan:
        mid-graph re-planning (DESIGN.md §11): while a DAG job executes,
        per-task measurements feed the pump *during* execution, and a task
        whose measured compute exceeds ``straggler_threshold`` × its
        planned time freezes the completed/running tasks, re-solves the
        not-yet-started frontier under the re-fitted models (assignments
        pinned, clocks carried), and splices the new assignment into the
        live run via the StreamCore's ticket revoke/re-issue.  In virtual
        mode the same protocol is replayed deterministically at the moment
        the first straggling compute would have finished.
    straggler_threshold:
        measured/planned per-task compute slack ratio that triggers a
        re-plan (needs ``replan=True`` and a dynamic domain).
    replan_min_frontier:
        minimum number of not-yet-started tasks worth re-solving for.
    max_replans_per_job:
        re-plan attempts allowed per job (1 = classic one-shot rescue).
    """

    def __init__(self, domain: Domain, *,
                 executor: str = "threads",
                 task_factory: TaskFactory | None = None,
                 truth: TruthFn | None = None,
                 cache: bool = True,
                 feedback: bool = True,
                 carry_clocks: bool = True,
                 max_inflight: int = 2,
                 time_scale: float = 1.0,
                 replan: bool = False,
                 straggler_threshold: float = 1.5,
                 replan_min_frontier: int = 2,
                 max_replans_per_job: int = 1):
        if executor not in ("threads", "virtual"):
            raise ValueError(f"unknown executor {executor!r}")
        self.domain = domain
        self.poas = POAS(domain, cache=PlanCache() if cache else None)
        self.dyn: DynamicScheduler | None = getattr(domain, "dyn", None)
        self.carry = bool(carry_clocks)
        self.max_inflight = max(1, int(max_inflight))
        self.executor = executor
        self.truth = truth
        self.time_scale = time_scale
        names = [d.name for d in domain.predict()]
        self.pump: ObservationPump | None = None
        if feedback and self.dyn is not None:
            self.pump = ObservationPump(self.dyn, names,
                                        time_scale=time_scale)
        self.replan = bool(replan)
        self.straggler_threshold = float(straggler_threshold)
        self.replan_min_frontier = max(1, int(replan_min_frontier))
        self.max_replans_per_job = max(0, int(max_replans_per_job))
        self.jobs: list[StreamJob] = []
        self._task_factory = task_factory or model_sleep_tasks(
            truth, time_scale=time_scale)
        self._core = StreamCore() if executor == "threads" else None
        if self._core is not None and (self.pump is not None or self.replan):
            # per-task measurements flow DURING execution, not only at job
            # completion — the straggler monitor and the observation pump
            # both hang off the core's event hook
            self._core.on_event = self._on_stream_event
        self._plan_clocks = ClockState()
        self._meas_clocks = ClockState()
        self._virtual_events: list = []
        self._pending_obs: list[StreamJob] = []   # virtual-mode obs lag
        self._queue: queue.SimpleQueue = queue.SimpleQueue()
        self._inflight = threading.Semaphore(self.max_inflight)
        self._lock = threading.Lock()
        self._closed = False
        self._planner = threading.Thread(target=self._plan_loop,
                                         name="poas-planner", daemon=True)
        self._planner.start()

    # -- admission ----------------------------------------------------------

    def submit(self, workload: Workload) -> StreamJob:
        """Admit one workload; returns immediately with its ``StreamJob``."""
        with self._lock:
            if self._closed:
                raise RuntimeError("runtime is shut down")
            job = StreamJob(uid=len(self.jobs), workload=workload)
            self.jobs.append(job)
        self._queue.put(job)
        return job

    def run_stream(self, workloads: Sequence[Workload],
                   timeout: float | None = 120.0) -> list[StreamJob]:
        """Submit every workload, wait for all of them, return their jobs."""
        jobs = [self.submit(w) for w in workloads]
        for j in jobs:
            j.wait(timeout)
        return jobs

    def drain(self, timeout: float | None = 120.0) -> None:
        with self._lock:
            jobs = list(self.jobs)
        for j in jobs:
            j._done.wait(timeout)

    def shutdown(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self._queue.put(None)
        self._planner.join(timeout=60)
        if self._core is not None:
            self._core.shutdown()

    def __enter__(self) -> "CoExecutionRuntime":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # -- introspection ------------------------------------------------------

    @property
    def plan_cache(self) -> PlanCache | None:
        return self.poas.cache

    def stream_timeline(self) -> Timeline:
        """Every job's measured events on one time axis — the cross-plan
        invariant surface."""
        if self._core is not None:
            return self._core.stream_timeline()
        with self._lock:
            events = list(self._virtual_events)
        return Timeline(sorted(events, key=lambda e: (e.start, e.end)))

    def total_makespan(self) -> float:
        return self.stream_timeline().makespan

    def stats(self) -> dict:
        with self._lock:
            done = [j for j in self.jobs if j.done and j.error is None]
        spans = sorted(j.span for j in done)
        # nearest-rank percentile: ceil(q*n)-1, NOT int(q*n) — the latter
        # returns the max for p50 of two samples
        p = lambda q: spans[max(0, math.ceil(q * len(spans)) - 1)] \
            if spans else 0.0
        return {
            "jobs_done": len(done),
            "total_makespan_s": self.total_makespan(),
            "p50_job_span_s": p(0.50),
            "p95_job_span_s": p(0.95),
            "observations": self.pump.observations if self.pump else 0,
            "refit_epoch": self.dyn.epoch if self.dyn else 0,
            "replans": sum(len(j.replans) for j in done),
            "plan_cache": self.poas.cache.stats() if self.poas.cache else {},
        }

    # -- the loop -----------------------------------------------------------

    def _next_clocks(self, timeline: Timeline, clocks: ClockState) -> ClockState:
        if self.carry:
            return carry_clocks(timeline, clocks)
        return ClockState(floor=max(timeline.makespan, clocks.floor))

    def _plan_loop(self) -> None:
        while True:
            job = self._queue.get()
            if job is None:
                return
            self._inflight.acquire()
            try:
                self._plan_and_dispatch(job)
            except BaseException as exc:
                job.error = exc
                job._done.set()
                self._inflight.release()

    def _plan_and_dispatch(self, job: StreamJob) -> None:
        if self.executor == "virtual":
            # flush observations old enough that a real pipeline would have
            # seen them (jobs completed before this one was planned)
            lag = self.max_inflight - 1
            while self._pending_obs and self._pending_obs[0].uid <= job.uid - 1 - lag:
                self._feed(self._pending_obs.pop(0))
        if self.dyn is not None:
            job.epoch_at_plan = self.dyn.epoch
        plan = self.poas.plan(job.workload)
        job.plan = plan
        spec = plan.schedule.spec
        if spec is not None:
            job.planned = spec.rebase(self._plan_clocks)
            self._plan_clocks = self._next_clocks(job.planned,
                                                  self._plan_clocks)
        else:
            job.planned = plan.schedule.timeline
        if self.executor == "virtual":
            self._execute_virtual(job)
        else:
            self._execute_threads(job)

    # -- virtual-time execution --------------------------------------------

    def _execute_virtual(self, job: StreamJob) -> None:
        spec = job.plan.schedule.spec
        if spec is None:
            raise ValueError("virtual execution needs Schedule.spec")
        truth_devs = [self.truth(job.uid, d) if self.truth else d
                      for d in spec.devices]
        base = self._meas_clocks
        job.measured = spec.rebase(base, devices=truth_devs)
        if self.replan and isinstance(spec, GraphTimelineSpec):
            replayed = self._replay_replan_virtual(job, spec, truth_devs,
                                                   base, job.measured)
            if replayed is not None:
                job.measured = replayed
        self._meas_clocks = self._next_clocks(job.measured, self._meas_clocks)
        with self._lock:
            self._virtual_events.extend(job.measured.events)
        self._pending_obs.append(job)
        job._done.set()
        self._inflight.release()

    def _replay_replan_virtual(self, job: StreamJob,
                               spec: GraphTimelineSpec,
                               truth_devs: Sequence[DeviceProfile],
                               base: ClockState,
                               measured: Timeline) -> Timeline | None:
        """Deterministic virtual-time replay of the mid-graph re-plan
        protocol: detect the straggler at the moment its measured compute
        would have finished, freeze everything that had started by then,
        feed the observations the monitor would have seen, re-solve the
        frontier under the re-fitted models, and re-price it under the
        ground truth from the frozen tasks' carried clocks.  Returns the
        spliced timeline, or None when nothing triggers (or the re-solve
        confirms the lock-in)."""
        planned_s = {t.name: spec.devices[a].compute(t.ops)
                     for t, a in zip(spec.tasks, spec.assign) if a >= 0}
        comp = {e.task: e for e in measured.events if e.kind == "compute"}
        stragglers = [n for n, e in comp.items()
                      if planned_s.get(n, 0.0) > 0.0 and e.duration >
                      self.straggler_threshold * planned_s[n]]
        if not stragglers or job._replan_attempts >= self.max_replans_per_job:
            return None
        # detection moment: the first straggling compute to finish — the
        # earliest point a measured-vs-planned monitor has the evidence
        trip = min(stragglers, key=lambda n: comp[n].end)
        t_r = comp[trip].end
        first_start = {t.name: min((e.start for e in measured.events
                                    if e.task == t.name), default=math.inf)
                       for t in spec.tasks}
        # ancestor-close the freeze: the engine does not gate a task's
        # EXTERNAL input copy on its parents, so a consumer's first event
        # can precede a pending parent's — same closure as the threaded
        # monitor
        started, pend = _ancestor_closed_freeze(
            spec, [t.name for t in spec.tasks
                   if first_start[t.name] < t_r - 1e-12])
        index = {t.name: i for i, t in enumerate(spec.tasks)}
        if len(pend) < self.replan_min_frontier:
            return None
        if hasattr(job.workload, "frontier_subgraph"):
            job.workload.frontier_subgraph(started)
        # observations the pump would have delivered by t_r
        if self.pump is not None:
            for name in started:
                e = comp.get(name)
                if e is not None and e.end <= t_r + 1e-12 \
                        and name not in job._fed_tasks \
                        and spec.tasks[index[name]].ops > 0.0:
                    job._fed_tasks.add(name)
                    self.pump.observe(e.device,
                                      spec.tasks[index[name]].ops,
                                      e.duration * self.pump.time_scale)
        started_set = set(started)
        frozen_events = [e for e in measured.events
                         if e.task in started_set]
        # frozen tickets stay ahead of re-issued ones on every link, so the
        # frontier re-prices from the clocks the frozen tail leaves behind
        clocks = carry_clocks(Timeline(frozen_events), base)
        devices = self.dyn.snapshot() if self.dyn is not None \
            else list(spec.devices)
        # frozen pricing: same derivation as the threaded monitor (virtual
        # frozen events are complete, so the measured branches always hit)
        ext = self._frozen_ext(spec, started, Timeline(frozen_events),
                               t_r, devices, 1.0)
        pinned = {index[n]: spec.assign[index[n]] for n in started}
        res = solve_list_schedule(devices, spec.tasks, spec.edges,
                                  bus=spec.topology, pinned=pinned,
                                  ext=ext, clocks=clocks,
                                  seed_assign=spec.assign)
        job._replan_attempts += 1
        if not self._worth_splicing(res, devices, spec, ext, clocks):
            return None   # the re-solve confirms the lock-in
        new_spec = dataclasses.replace(spec, devices=tuple(devices),
                                       assign=tuple(res.assign),
                                       order=tuple(res.order))
        ext_names = {spec.tasks[i].name: v for i, v in ext.items()}
        planned_frontier = new_spec.rebase_partial(clocks, ext=ext_names)
        truth_frontier = new_spec.rebase_partial(clocks, ext=ext_names,
                                                 devices=truth_devs)
        job.replans.append(ReplanRecord(
            at=t_r, straggler=trip, frozen=tuple(started),
            spliced=tuple(pend), spec=new_spec, planned=planned_frontier))
        return Timeline(sorted(frozen_events + truth_frontier.events,
                               key=lambda e: (e.start, e.end)))

    # -- threaded execution -------------------------------------------------

    def _execute_threads(self, job: StreamJob) -> None:
        tasks = self._task_factory(job, job.plan)
        order = job.plan.schedule.timeline.link_ticket_order()
        spec = job.plan.schedule.spec
        if isinstance(spec, GraphTimelineSpec):
            # what the straggler monitor compares measured computes against
            job._planned_compute = {
                t.name: spec.devices[a].compute(t.ops)
                for t, a in zip(spec.tasks, spec.assign) if a >= 0}
        handle = self._core.dispatch(tasks, order, job=f"j{job.uid}")
        job._handle = handle
        handle.add_done_callback(lambda h: self._complete(job, h))

    # -- mid-graph re-planning (threads; DESIGN.md §11) ---------------------

    def _on_stream_event(self, jid: str, ev) -> None:
        """StreamCore event hook (runs on device worker threads): feed
        per-task compute measurements into the pump the moment they land,
        and trip the straggler monitor on planned-vs-measured slack."""
        if ev.kind != "compute" or ev.task is None:
            return
        try:
            uid = int(jid.lstrip("j"))
        except ValueError:
            return
        with self._lock:
            job = self.jobs[uid] if 0 <= uid < len(self.jobs) else None
        if job is None or job.plan is None:
            return
        spec = job.final_spec
        if not isinstance(spec, GraphTimelineSpec):
            return
        ops = next((float(t.ops) for t in spec.tasks if t.name == ev.task),
                   0.0)
        if self.pump is not None and ops > 0.0 and ev.duration > 0.0 \
                and ev.task not in job._fed_tasks:
            job._fed_tasks.add(ev.task)
            self.pump.observe(ev.device, ops, ev.duration)
        if not self.replan:
            return
        planned_s = job._planned_compute.get(ev.task, 0.0)
        measured_s = ev.duration / self.time_scale
        if planned_s <= 0.0 or measured_s <= \
                self.straggler_threshold * planned_s:
            return
        if ev.task in job._checked_tasks:
            return   # this task's slack was already re-solved: lock-in held
        self._replan_threaded(job, ev)

    def _frozen_ext(self, spec: GraphTimelineSpec, started: Sequence[str],
                    measured: Timeline, now_model: float,
                    devices: Sequence[DeviceProfile],
                    time_scale: float) -> dict[int, tuple[float, float]]:
        """(compute_end, avail) per frozen task, in model seconds: measured
        values where the stage already landed, refitted-model estimates for
        the still-running remainder; ``avail = inf`` marks an output that
        never reaches the host (so the re-solve cannot move its consumers
        off-device)."""
        index = {t.name: i for i, t in enumerate(spec.tasks)}
        stage_planned = spec.stage_seconds(devices)
        ext: dict[int, tuple[float, float]] = {}
        for name in started:
            i = index[name]
            a = spec.assign[i]
            if a < 0:
                continue
            t = spec.tasks[i]
            evs = measured.task_events(name)
            comp_ends = [e.end for e in evs if e.kind == "compute"]
            out_ends = [e.end for e in evs if e.kind == "copy_out"]
            if comp_ends:
                c_end = max(comp_ends) / time_scale
            else:   # running: charge the refitted model from now
                c_end = now_model + devices[a].compute(t.ops)
            if out_ends:
                avail = max(out_ends) / time_scale
            elif not _has_copy(devices[a]) or t.out_bytes <= 0.0:
                avail = c_end   # host-resident the moment compute ends
            elif stage_planned.get(name, {}).get("copy_out"):
                # staging planned but not yet measured: estimate
                avail = c_end + stage_planned[name]["copy_out"]
            else:
                avail = math.inf   # never staged: not host-readable
            ext[i] = (c_end, avail)
        return ext

    def _replan_threaded(self, job: StreamJob, ev) -> None:
        with job._replan_lock:
            if job._replan_attempts >= self.max_replans_per_job:
                return
            handle = job._handle
            core = self._core
            if handle is None or core is None or handle.done:
                return
            spec = job.final_spec
            pending = core.pending_tasks(handle.job)
            started, frontier = _ancestor_closed_freeze(
                spec, [t.name for t in spec.tasks if t.name not in pending])
            pend = set(frontier)
            if len(pend) < self.replan_min_frontier:
                return
            if hasattr(job.workload, "frontier_subgraph"):
                # sanity: the closed snapshot is ancestor-closed by
                # construction; a raise here means the progress view is
                # corrupt
                job.workload.frontier_subgraph(started)
            ts = self.time_scale
            devices = self.dyn.snapshot() if self.dyn is not None \
                else list(spec.devices)
            now_model = core.now() / ts
            measured = handle.timeline()
            ext = self._frozen_ext(spec, started, measured, now_model,
                                   devices, ts)
            clocks = self._splice_clocks(spec, ext, core.stream_timeline(),
                                         ts)
            index = {t.name: i for i, t in enumerate(spec.tasks)}
            pinned = {index[n]: spec.assign[index[n]] for n in started}
            # the re-solve runs ON the straggler's worker thread — that is
            # deliberate (it freezes the straggler's queue so its successors
            # stay migratable) but means solver latency stalls the splice:
            # cap the descent hard
            res = solve_list_schedule(devices, spec.tasks, spec.edges,
                                      bus=spec.topology, pinned=pinned,
                                      ext=ext, clocks=clocks,
                                      seed_assign=spec.assign,
                                      max_evals=_REPLAN_MAX_EVALS)
            new_spec = dataclasses.replace(spec, devices=tuple(devices),
                                           assign=tuple(res.assign),
                                           order=tuple(res.order))
            if not self._worth_splicing(res, devices, spec, ext, clocks):
                # the re-solve confirms (or barely beats) the lock-in:
                # nothing to splice, and a no-op trigger (e.g.
                # sleep-overhead noise on a tiny task) must NOT burn the
                # job's re-plan budget.  The monitor baseline refreshes
                # from the re-fitted models under the assignment that
                # KEEPS executing — the original one, not the rejected
                # re-solve's.
                job._planned_compute = {
                    t.name: devices[a].compute(t.ops)
                    for t, a in zip(spec.tasks, spec.assign) if a >= 0}
                job._checked_tasks.add(ev.task)
                return
            job._replan_attempts += 1
            job._planned_compute = {
                t.name: devices[a].compute(t.ops)
                for t, a in zip(new_spec.tasks, new_spec.assign) if a >= 0}
            ext_names = {spec.tasks[i].name: v for i, v in ext.items()}
            frontier = new_spec.rebase_partial(clocks, ext=ext_names)
            sched = dataclasses.replace(job.plan.schedule, spec=new_spec,
                                        timeline=frontier)
            plan2 = dataclasses.replace(job.plan, schedule=sched)
            repl = [t for t in self._task_factory(job, plan2)
                    if t.task in pend]
            spliced = core.reissue(handle, repl,
                                   frontier.link_ticket_order())
            job.replans.append(ReplanRecord(
                at=now_model, straggler=ev.task, frozen=tuple(started),
                spliced=tuple(spliced), spec=new_spec, planned=frontier))

    def _worth_splicing(self, res, devices: Sequence[DeviceProfile],
                        spec: GraphTimelineSpec,
                        ext: Mapping[int, tuple[float, float]],
                        clocks: ClockState) -> bool:
        """Splice only for a real predicted gain: the re-solved makespan
        must beat the locked-in assignment re-priced under the SAME
        re-fitted models, frozen ext times, and carried clocks — and under
        its OWN planned order (that is what keeps executing if the splice
        is rejected)."""
        if tuple(res.assign) == tuple(spec.assign):
            return False
        seed_mk = max(graph_finish_times(devices, spec.tasks, spec.edges,
                                         spec.assign, topology=spec.topology,
                                         order=spec.order, clocks=clocks,
                                         ext=ext))
        return res.makespan * _REPLAN_MIN_GAIN < seed_mk

    def _splice_clocks(self, spec: GraphTimelineSpec,
                       ext: Mapping[int, tuple[float, float]],
                       stream: Timeline, time_scale: float) -> ClockState:
        """Where each link/device clock stands for the frontier re-pricing:
        the measured stream so far, floored by the frozen tasks' estimated
        tails (their pending copy_outs stay ahead of re-issued tickets on
        each link; a running compute holds its device)."""
        base = carry_clocks(stream)
        links = {k: v / time_scale for k, v in base.links.items()}
        devs = {k: v / time_scale for k, v in base.devices.items()}
        for i, (c_end, avail) in ext.items():
            a = spec.assign[i]
            if a < 0:
                continue
            dname = spec.devices[a].name
            devs[dname] = max(devs.get(dname, 0.0), c_end)
            if math.isfinite(avail) and avail > c_end:
                lk = spec.topology.link_of(dname, "out")
                if lk is not None:
                    links[lk.name] = max(links.get(lk.name, 0.0), avail)
        return ClockState(links=links, devices=devs)

    def _complete(self, job: StreamJob, handle) -> None:
        # Runs as a JobHandle done-callback on a device worker thread: it
        # must ALWAYS complete the job and free the in-flight slot, or one
        # bad observation (pump -> observe -> refit listeners) would wedge
        # the planner and every later job on that device.
        try:
            job.measured = handle.timeline()
            if handle.errors:
                job.error = handle.errors[0]
            elif self.pump is not None:
                self._feed(job)
        except BaseException as exc:
            if job.error is None:
                job.error = exc
        finally:
            job._done.set()
            self._inflight.release()

    def _feed(self, job: StreamJob) -> None:
        if self.pump is None or job.measured is None:
            return
        spec = job.final_spec
        if spec is None:
            return
        if isinstance(spec, GraphTimelineSpec):
            # DAG jobs observe per task (many sizes per device per job);
            # tasks already fed during execution (the straggler monitor's
            # early feed) are skipped, not observed twice
            rows = [r for r in spec.task_ops()
                    if r[0] not in job._fed_tasks]
            self.pump.feed_tasks(job.measured, rows)
        else:
            self.pump.feed(job.measured, spec.ops_by_device())


# ---------------------------------------------------------------------------
# Cross-plan invariant checks (tests + BENCH_streaming acceptance)
# ---------------------------------------------------------------------------


def _planned_link_order(j: StreamJob) -> dict[str, list[tuple]]:
    """The per-link grant order the job was *actually* issued under: the
    original plan's order for tickets never re-issued, then — for each
    mid-graph re-plan, in splice order — the frontier's re-planned order
    for the tasks that replan owns (the last splice of a task wins, exactly
    as the live buses saw it)."""
    planned = j.plan.schedule.timeline.link_ticket_order()
    if not j.replans:
        return planned
    owner: dict[str, int] = {}
    for idx, r in enumerate(j.replans):
        for name in r.spliced:
            owner[name] = idx
    out = {link: [t for t in seq
                  if not (len(t) == 3 and t[0] in owner)]
           for link, seq in planned.items()}
    for idx, r in enumerate(j.replans):
        for link, seq in r.planned.link_ticket_order().items():
            out.setdefault(link, []).extend(
                t for t in seq if owner.get(t[0]) == idx)
    return out


def verify_stream_invariants(jobs: Sequence[StreamJob], *,
                             eps: float = 1e-9) -> list[str]:
    """The Fig. 2 invariants, across plan boundaries.  Returns violations
    (empty = pass):

    * per link, ALL jobs' transfers serialize (no two copy events overlap,
      even from different plans);
    * per job and device, compute chunk j starts only after input chunk j
      landed, and output chunk j only after compute chunk j;
    * per job and link, the measured grant order equals the planned
      priority/ticket order — for a mid-graph re-planned job, the splice of
      the original order (frozen tasks) with each re-plan's frontier order.
    """
    problems: list[str] = []
    done = [j for j in jobs if j.measured is not None and j.error is None]

    # per-link serialization across the whole stream
    by_link: dict[str, list] = {}
    for j in done:
        for e in j.measured.events:
            if e.kind != "compute" and e.link is not None:
                by_link.setdefault(e.link, []).append(e)
    for link, evs in by_link.items():
        evs.sort(key=lambda e: (e.start, e.end))
        for a, b in zip(evs, evs[1:]):
            if b.start < a.end - eps:
                problems.append(
                    f"link {link}: {b.device}/{b.kind} starts {a.end - b.start:.3g}s "
                    f"before {a.device}/{a.kind} ends")

    for j in done:
        # copy-before-compute-before-copy-out, chunk-wise; task-graph
        # timelines group per (device, task) — a device runs many tasks
        for name, task in {(e.device, e.task) for e in j.measured.events}:
            evs = [e for e in j.measured.device_events(name)
                   if e.task == task]
            ins = sorted((e for e in evs if e.kind == "copy_in"),
                         key=lambda e: e.chunk)
            comps = sorted((e for e in evs if e.kind == "compute"),
                           key=lambda e: e.chunk)
            outs = sorted((e for e in evs if e.kind == "copy_out"),
                          key=lambda e: e.chunk)
            if task is not None:
                # DAG tasks: every input copy (external + edge reads) must
                # land before the single compute starts
                for i_ev in ins:
                    if comps and comps[0].start < i_ev.end - eps:
                        problems.append(
                            f"job {j.uid} {name}/{task}: compute before "
                            f"input copy {i_ev.chunk} landed")
                # EVERY output event must start after compute ends — the
                # old zip(comps[-1:], outs) paired only the first output
                # with the last compute, silently skipping the rest
                if comps:
                    c_end = comps[-1].end
                    for o_ev in outs:
                        if o_ev.start < c_end - eps:
                            problems.append(f"job {j.uid} {name}/{task}: "
                                            "copy_out before compute ended")
                continue
            for i_ev, c_ev in zip(ins, comps):
                if c_ev.start < i_ev.end - eps:
                    problems.append(f"job {j.uid} {name}: compute chunk "
                                    f"{c_ev.chunk} before its input landed")
            for c_ev, o_ev in zip(comps, outs):
                if o_ev.start < c_ev.end - eps:
                    problems.append(f"job {j.uid} {name}: copy_out chunk "
                                    f"{o_ev.chunk} before its compute ended")
        # planned per-link grant order is replayed (splice-aware)
        if j.plan is None:
            continue
        planned = _planned_link_order(j)
        measured = j.measured.link_ticket_order()
        for link, want in planned.items():
            got = measured.get(link, [])
            got_set = set(got)   # hoisted: one set, not one per element
            want = [t for t in want if t in got_set]   # subset task lists
            if got != want:
                problems.append(f"job {j.uid} link {link}: grant order "
                                f"{got} != planned {want}")
    return problems
