"""POAS phase 3 — *Adapt*.

Maps solver outputs (op counts per device) back onto problem coordinates.
For GEMM this is the paper's ``ops_to_mnk`` algorithm (§4.3):

* data adjustments — fix ``n`` and ``k`` to their original values, derive
  ``m_x = c_x / (n*k)``, then decompose each device's slice into near-square
  sub-products maximizing the squareness heuristic (Eq. 5);
* hardware adjustments — round ``m_x`` to each device's alignment grain
  (tensor cores: multiples of 8; TPU MXU: sublane grain), and bound
  sub-product working sets by the device cache/VMEM size.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from .device_model import DeviceProfile


# ---------------------------------------------------------------------------
# Squareness heuristic (paper Eq. 5)
# ---------------------------------------------------------------------------


def squareness(ms: Sequence[int], ks: Sequence[int], n: int) -> float:
    """sq = Σ_i min(m'_i,k'_i)/max(m'_i,k'_i) * m'_i*k'_i*n   (Eq. 5)."""
    sq = 0.0
    for m_i, k_i in zip(ms, ks):
        if m_i <= 0 or k_i <= 0:
            continue
        sq += (min(m_i, k_i) / max(m_i, k_i)) * float(m_i) * k_i * n
    return sq


def _divisors(x: int) -> list[int]:
    out = []
    i = 1
    while i * i <= x:
        if x % i == 0:
            out.append(i)
            if i != x // i:
                out.append(x // i)
        i += 1
    return sorted(out)


@dataclasses.dataclass(frozen=True)
class SubProduct:
    """One sub-GEMM tile: (m', n, k') with row/col offsets into the slice."""
    m: int
    k: int
    row0: int
    k0: int


def decompose_square(m: int, k: int, n: int, *,
                     ops_lo: float = 0.0, ops_hi: float = math.inf,
                     max_candidates: int = 64,
                     max_tiles: int = 4096) -> list[SubProduct]:
    """Paper §4.3.1 task (2): express an (m,n,k) product as a best-effort list
    of near-square sub-products.

    ``k'`` is restricted to divisors of ``k`` (so A tiles never leave gaps in
    the k direction — paper: "k % k' == 0").  For each candidate ``k'`` we
    choose ``m'`` as close to ``k'`` as possible subject to the profiled op
    range [ops_lo, ops_hi] (sub-products must match the op counts seen during
    profiling, §5.1.3), then score the full tiling with Eq. 5 and keep the
    argmax.
    """
    if m <= 0 or k <= 0:
        return []
    best: tuple[float, list[SubProduct]] | None = None
    divs = _divisors(k)
    if len(divs) > max_candidates:  # keep the largest (most square) ones
        divs = divs[-max_candidates:]
    for kp in divs:
        # Candidate m' targets: as square as possible, inside the ops window.
        m_lo = max(1, int(math.ceil(ops_lo / (float(kp) * n))) if ops_lo else 1)
        m_hi = min(m, int(ops_hi // (float(kp) * n)) if ops_hi < math.inf else m)
        if m_hi < 1:
            continue
        mp = min(max(kp, m_lo), m_hi)  # closest to square within window
        if (-(-m // mp)) * (-(-k // kp)) > max_tiles:
            continue  # degenerate tiny tiles — skip candidate
        tiles: list[SubProduct] = []
        ms, ks = [], []
        row = 0
        while row < m:
            h = min(mp, m - row)
            col = 0
            while col < k:
                w = min(kp, k - col)
                tiles.append(SubProduct(m=h, k=w, row0=row, k0=col))
                ms.append(h)
                ks.append(w)
                col += w
            row += h
        score = squareness(ms, ks, n)
        if best is None or score > best[0]:
            best = (score, tiles)
    return best[1] if best else [SubProduct(m=m, k=k, row0=0, k0=0)]


# ---------------------------------------------------------------------------
# ops_to_mnk (paper §4.3)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DeviceAssignment:
    device: str
    m: int              # rows of the output slice
    row0: int           # starting row in the global C
    ops: float          # m * n * k actually assigned
    sub_products: list[SubProduct] = dataclasses.field(default_factory=list)
    # Pipelined-copy row chunks (device ``pipeline_chunks`` mapped to
    # contiguous align_m-sized row groups; sums to ``m``).  The runtime
    # streams A/C chunk by chunk so compute on chunk 1 overlaps the
    # transfer of chunk 2 (core.bus).
    chunk_rows: tuple[int, ...] = ()

    def chunk_offsets(self) -> list[int]:
        out, acc = [], self.row0
        for r in self.chunk_rows:
            out.append(acc)
            acc += r
        return out


@dataclasses.dataclass
class GemmPlan:
    m: int
    n: int
    k: int
    assignments: list[DeviceAssignment]

    def total_rows(self) -> int:
        return sum(a.m for a in self.assignments)


def ops_to_mnk(devices: Sequence[DeviceProfile], ops: Sequence[float],
               m: int, n: int, k: int, *,
               decompose: bool = True,
               ops_windows: Sequence[tuple[float, float]] | None = None
               ) -> GemmPlan:
    """Map solver op counts to row slices of C (paper §4.3.1 task (1)).

    ``n`` and ``k`` stay at their original values (partial-``n`` splits would
    produce partial sums of C; fixed ``k`` means only rows are distributed) so
    ``m_x = c_x / (n*k)``.  Rows are rounded to each device's ``align_m``
    grain with largest-remainder distribution so that ``Σ m_x == m`` exactly;
    leftover rows go to the fastest device (it absorbs slack with least
    makespan damage).
    """
    nk = float(n) * k
    raw = [c / nk for c in ops]
    # 1. floor to alignment grain
    m_i = [int(r // max(d.align_m, 1)) * max(d.align_m, 1)
           for r, d in zip(raw, devices)]
    # 2. distribute remaining rows in align_m-sized packets, preferring the
    #    device with the largest fractional shortfall whose packet still
    #    fits; a final partial packet goes to the smallest-alignment device
    #    (alignment broken only as a last resort).
    def speed(i):
        return devices[i].effective_speed
    remaining = m - sum(m_i)
    while remaining > 0:
        fitting = [i for i in range(len(devices))
                   if max(devices[i].align_m, 1) <= remaining]
        if fitting:
            i = max(fitting, key=lambda j: (raw[j] - m_i[j], speed(j)))
            packet = max(devices[i].align_m, 1)
        else:
            i = min(range(len(devices)),
                    key=lambda j: (max(devices[j].align_m, 1), -speed(j)))
            packet = remaining
        m_i[i] += packet
        remaining -= packet
    # 3. over-assignment (alignment rounding can exceed m): trim from the
    #    slowest devices first.
    if remaining < 0:
        for i in sorted(range(len(devices)), key=speed):
            while remaining < 0 and m_i[i] > 0:
                take = min(max(devices[i].align_m, 1), m_i[i], -remaining)
                m_i[i] -= take
                remaining += take
    assert sum(m_i) == m, (m_i, m)

    assignments: list[DeviceAssignment] = []
    row = 0
    for j, (d, rows) in enumerate(zip(devices, m_i)):
        subs: list[SubProduct] = []
        if rows > 0 and decompose:
            lo, hi = (0.0, math.inf)
            if ops_windows is not None:
                lo, hi = ops_windows[j]
            cache_hi = _cache_ops_bound(d, n)
            subs = decompose_square(rows, k, n, ops_lo=lo,
                                    ops_hi=min(hi, cache_hi))
        assignments.append(DeviceAssignment(
            device=d.name, m=rows, row0=row, ops=float(rows) * n * k,
            sub_products=subs,
            chunk_rows=_row_chunks(rows, getattr(d, "pipeline_chunks", 1),
                                   max(d.align_m, 1))))
        row += rows
    return GemmPlan(m=m, n=n, k=k, assignments=assignments)


def _row_chunks(rows: int, chunks: int, grain: int) -> tuple[int, ...]:
    """Split ``rows`` into up to ``chunks`` contiguous groups, each (except
    possibly the last) a multiple of ``grain`` — the hardware-adjustment
    rule (§4.3.2) applied at pipeline-chunk granularity.  Fewer chunks come
    back when ``rows`` is too small to split at the grain."""
    if rows <= 0:
        return ()
    chunks = max(1, int(chunks))
    if chunks == 1:
        return (rows,)
    per = max(grain, -(-rows // (chunks * grain)) * grain)
    out: list[int] = []
    left = rows
    while left > 0 and len(out) < chunks - 1:
        take = min(per, left)
        out.append(take)
        left -= take
    if left > 0:
        out.append(left)
    return tuple(out)


def _cache_ops_bound(d: DeviceProfile, n: int) -> float:
    """Hardware adjustment (paper §4.3.2, CPU case): sub-product working set
    (A tile + B panel + C tile) must fit the device cache / VMEM."""
    if math.isinf(d.cache_bytes):
        return math.inf
    dt = max(d.copy.dtype_size, 4)
    # working set for an (m',n,k') tile with m'≈k': m'k' + k'n + m'n elements.
    # Solve m'^2 + 2*m'*n <= cache/dt  for m'=k'.
    cap = d.cache_bytes / dt
    mp = (-2.0 * n + math.sqrt(4.0 * n * n + 4.0 * cap)) / 2.0
    mp = max(mp, 1.0)
    return mp * mp * n  # ops of one square tile


def plan_ops(plan: GemmPlan) -> list[float]:
    return [a.ops for a in plan.assignments]


# ---------------------------------------------------------------------------
# Generic adapt primitives (shared by the serving and train-step domains)
# ---------------------------------------------------------------------------


def pack_largest_first(weights: Sequence[float],
                       budgets: Sequence[float]) -> list[list[int]]:
    """Greedy largest-first packing of weighted items into budgeted buckets.

    Items are placed heaviest-first into the bucket with the most remaining
    budget, so bucket weight totals track the budgets (the solver's op
    shares) to within one item.  Returns item *indices* per bucket.
    """
    remaining = [float(b) for b in budgets]
    buckets: list[list[int]] = [[] for _ in budgets]
    order = sorted(range(len(weights)), key=lambda i: -weights[i])
    for idx in order:
        g = max(range(len(remaining)), key=lambda j: remaining[j])
        buckets[g].append(idx)
        remaining[g] -= weights[idx]
    return buckets


def round_shares_to_grain(raw: Sequence[float], grains: Sequence[int],
                          total: int) -> list[int]:
    """Round fractional shares to per-bucket grains, conserving ``total``.

    Floors each share to its grain, then hands out the remainder in
    grain-sized packets by largest fractional shortfall; over-assignment is
    trimmed from the largest bucket (it absorbs the change with the least
    relative distortion).  The hetero-DP domain uses this for the paper's
    hardware-adjustment step (§4.3.2) in batch-row coordinates.
    """
    grains = [max(int(g), 1) for g in grains]
    sizes = [int(r // g) * g for r, g in zip(raw, grains)]
    rem = total - sum(sizes)
    order = sorted(range(len(raw)),
                   key=lambda i: -(raw[i] - sizes[i]))
    j = 0
    while rem > 0:
        i = order[j % len(order)]
        add = min(grains[i], rem)
        sizes[i] += add
        rem -= add
        j += 1
    while rem < 0:
        i = max(range(len(sizes)), key=lambda q: sizes[q])
        take = min(grains[i], sizes[i], -rem)
        sizes[i] -= take
        rem += take
    return sizes
