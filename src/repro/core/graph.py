"""Task-graph workloads — POAS for precedence-constrained DAGs (DESIGN.md §10).

Every shipped domain so far assumes one *divisible* workload whose ops are
split by share; the paper's claim that POAS "transforms any application"
needs applications with internal structure.  This module adds that workload
class end to end:

* ``TaskGraph`` / ``TaskNode`` — a validated DAG of tasks (per-task op
  counts, external input bytes, output bytes, precedence edges) that
  implements the ``Workload`` protocol (``total_ops`` = sum over nodes)
  with a structural ``cost_signature``, so the ``PlanCache`` works
  unchanged;
* ``TaskGraphDomain`` (registered as ``"task-graph"``) — the four POAS
  phases for DAGs: Predict reuses the per-device models (re-fitted by the
  ``DynamicScheduler`` under per-task observations), Optimize is the
  HEFT-style ``solve_list_schedule`` priced on the unified timeline engine,
  Adapt maps the assignment back to per-device task lists (``GraphPlan``),
  Schedule emits a ``GraphTimelineSpec``-backed timeline the streaming
  runtime rebase/executes like any other plan;
* ``transformer_block`` — the case-study builder: a transformer block
  (grouped QKV/attention heads → projection → residual → grouped MLP)
  as a schedulable DAG across CPU/GPU/XPU, instead of one divisible matmul;
* ``verify_graph_dependencies`` — the timeline invariant: no task's
  compute starts before every upstream task's output has landed.
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Hashable, Iterable, Sequence

from .bus import (BusTopology, GraphTimelineSpec, TaskSpec, Timeline,
                  _graph_topo_order)
from .device_model import DeviceProfile, priority_order
from .domain import register_domain
from .optimize import (GraphScheduleResult, OptimizeResult,
                       solve_hierarchical, solve_list_schedule)
from .schedule import DynamicScheduler, Schedule


# ---------------------------------------------------------------------------
# The workload
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TaskNode:
    """One task: ``ops`` multiply-accumulates, ``in_bytes`` of external
    (host-resident) input — weights, graph inputs — and ``out_bytes`` of
    produced data (what travels on out-edges / returns to host at sinks)."""

    name: str
    ops: float
    in_bytes: float = 0.0
    out_bytes: float = 0.0


@dataclasses.dataclass(frozen=True)
class TaskGraph:
    """A validated precedence DAG implementing the ``Workload`` protocol.

    ``edges`` are ``(producer_name, consumer_name)`` pairs.  Validation
    (unique names, known endpoints, no self-edges, acyclicity) runs at
    construction; ``topo_order`` / ``critical_path`` / ``cost_signature``
    are the queries the solver, cache, and benchmarks need.
    """

    nodes: tuple[TaskNode, ...]
    edges: tuple[tuple[str, str], ...] = ()
    #: optional structural metadata from builders: a partition of (some of)
    #: the task names into repeated blocks, in construction order — the
    #: template detector's free fast path (``detect_templates``).  Carries
    #: no cost information, so it is excluded from ``cost_signature``.
    blocks: tuple[tuple[str, ...], ...] = ()

    def __post_init__(self) -> None:
        object.__setattr__(self, "_memo", {})
        names = [t.name for t in self.nodes]
        if len(set(names)) != len(names):
            dup = sorted(n for n, c in Counter(names).items() if c > 1)
            raise ValueError(f"duplicate task names: {dup}")
        index = {n: i for i, n in enumerate(names)}
        parents: dict[str, list[str]] = {n: [] for n in names}
        children: dict[str, list[str]] = {n: [] for n in names}
        for u, v in self.edges:
            for end in (u, v):
                if end not in index:
                    raise ValueError(f"edge ({u!r}, {v!r}) references "
                                     f"unknown task {end!r}")
            if u == v:
                raise ValueError(f"self-edge on task {u!r}")
            parents[v].append(u)
            children[u].append(v)
        object.__setattr__(self, "_index", index)
        object.__setattr__(self, "_parents",
                           {n: tuple(ps) for n, ps in parents.items()})
        object.__setattr__(self, "_children",
                           {n: tuple(cs) for n, cs in children.items()})
        seen_blk: set[str] = set()
        for blk in self.blocks:
            for bn in blk:
                if bn not in index:
                    raise ValueError(f"block references unknown task {bn!r}")
                if bn in seen_blk:
                    raise ValueError(f"task {bn!r} appears in two blocks")
                seen_blk.add(bn)
        _graph_topo_order(len(self.nodes), self.edge_indices())  # acyclic?

    # -- queries -------------------------------------------------------------

    def __len__(self) -> int:
        return len(self.nodes)

    def index(self, name: str) -> int:
        return self._index[name]

    def node(self, name: str) -> TaskNode:
        return self.nodes[self._index[name]]

    def edge_indices(self) -> tuple[tuple[int, int], ...]:
        memo = self._memo
        out = memo.get("edge_indices")
        if out is None:
            out = tuple((self._index[u], self._index[v])
                        for u, v in self.edges)
            memo["edge_indices"] = out
        return out

    def parents(self, name: str) -> tuple[str, ...]:
        return self._parents[name]

    def children(self, name: str) -> tuple[str, ...]:
        return self._children[name]

    def total_ops(self) -> float:
        return float(sum(t.ops for t in self.nodes))

    def topo_order(self) -> list[int]:
        memo = self._memo
        out = memo.get("topo_order")
        if out is None:
            out = _graph_topo_order(len(self.nodes), self.edge_indices())
            memo["topo_order"] = out
        return list(out)

    def critical_path(self) -> tuple[float, list[str]]:
        """Ops-weighted longest path: the lower bound no schedule can beat
        regardless of device count (returns total ops along it and the
        task names)."""
        n = len(self.nodes)
        edges = self.edge_indices()
        children: list[list[int]] = [[] for _ in range(n)]
        for u, v in edges:
            children[u].append(v)
        length = [0.0] * n
        nxt: list[int | None] = [None] * n
        for i in reversed(self.topo_order()):
            best, best_c = 0.0, None
            for c in children[i]:
                if length[c] > best:
                    best, best_c = length[c], c
            length[i] = self.nodes[i].ops + best
            nxt[i] = best_c
        start = max(range(n), key=lambda i: length[i])
        path, i = [], start
        while i is not None:
            path.append(self.nodes[i].name)
            i = nxt[i]
        return length[start], path

    def task_specs(self) -> tuple[TaskSpec, ...]:
        memo = self._memo
        out = memo.get("task_specs")
        if out is None:
            out = tuple(TaskSpec(t.name, float(t.ops), float(t.in_bytes),
                                 float(t.out_bytes)) for t in self.nodes)
            memo["task_specs"] = out
        return out

    def cost_signature(self) -> Hashable:
        """Everything the solved plan depends on: per-task numbers plus the
        edge structure (device models are keyed separately by the cache).
        Memoized — the graph is immutable and this tuple is rebuilt on every
        ``PlanCache`` probe, which at 10^4 nodes dominated cache hits."""
        memo = self._memo
        out = memo.get("cost_signature")
        if out is None:
            out = (tuple((t.name, t.ops, t.in_bytes, t.out_bytes)
                         for t in self.nodes), self.edges)
            memo["cost_signature"] = out
        return out

    def template_partition(self, *, min_repeats: int = 4
                           ) -> "TemplatePartition | None":
        """Memoized ``detect_templates`` (the graph is immutable, and the
        domain re-detects on every plan-cache miss)."""
        memo = self._memo
        key = ("template_partition", min_repeats)
        if key not in memo:
            memo[key] = detect_templates(self, min_repeats=min_repeats)
        return memo[key]

    def frontier_subgraph(self, started: Iterable[str]
                          ) -> tuple["TaskGraph",
                                     tuple[tuple[str, str], ...]]:
        """The not-yet-started successor frontier (mid-graph re-planning,
        DESIGN.md §11): the subgraph of tasks NOT in ``started``, plus the
        boundary edges (started producer → frontier consumer) that cross
        the freeze line.

        ``started`` must be *ancestor-closed* — a task cannot have started
        before its parents finished, so a started task with a not-started
        parent means the caller's progress snapshot is corrupt (raises).
        In the returned subgraph each boundary edge's payload is folded
        into the consumer's ``in_bytes`` (the frozen producer's output must
        be read back from the host once the frontier is re-placed); callers
        that re-solve the *full* graph with pinned assignments (the exact
        path — same-device boundary edges stay free) want the boundary list
        and the frontier names, not the folded bytes.
        """
        started_set = set(started)
        unknown = started_set - set(self._index)
        if unknown:
            raise ValueError(f"unknown started tasks: {sorted(unknown)}")
        for u, v in self.edges:
            if v in started_set and u not in started_set:
                raise ValueError(
                    f"started task {v!r} has a not-started parent {u!r}: "
                    "the started set is not ancestor-closed")
        frontier = [t for t in self.nodes if t.name not in started_set]
        boundary = tuple((u, v) for u, v in self.edges
                         if u in started_set and v not in started_set)
        extra_in: dict[str, float] = {}
        for u, v in boundary:
            extra_in[v] = extra_in.get(v, 0.0) + self.node(u).out_bytes
        nodes = tuple(dataclasses.replace(
            t, in_bytes=t.in_bytes + extra_in.get(t.name, 0.0))
            for t in frontier)
        edges = tuple((u, v) for u, v in self.edges
                      if u not in started_set and v not in started_set)
        return TaskGraph(nodes=nodes, edges=edges), boundary


# ---------------------------------------------------------------------------
# Template detection (DESIGN.md §15)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TemplatePartition:
    """A partition of a ``TaskGraph`` into repeated template instances.

    ``instances[a]`` lists instance *a*'s node indices in topological
    order (slot order); ``template_of[a]`` is its template id;
    ``signatures[t]`` is template *t*'s canonical signature — per-slot
    costs, internal edges in slot coordinates, and boundary arity
    (in-edges as ``(consumer_slot, producer_out_bytes)``, out-edges as
    ``(producer_slot, count)``).  Names are excluded, so structurally
    equal blocks match across layers, microbatches, graphs, and tenants;
    the signature is also everything ``solve_hierarchical`` needs to
    build and cache a representative sub-solve, so the template cache
    key *is* the solve input."""

    instances: tuple[tuple[int, ...], ...]
    template_of: tuple[int, ...]
    signatures: tuple[Hashable, ...]

    @property
    def n_templates(self) -> int:
        return len(self.signatures)

    def repeats(self) -> Counter:
        """Template id -> instance count."""
        return Counter(self.template_of)


def _generic_instances(n: int, children: Sequence[Sequence[int]],
                       topo: Sequence[int], nodes: Sequence[TaskNode]
                       ) -> list[list[int]]:
    """Fallback instance discovery for graphs without builder blocks.

    Per weakly-connected component (in topological order): cut after
    position ``p`` whenever at most one producer's edges cross into the
    suffix — computed with a difference array over producer spans
    ``[pos(u), last_child_pos(u))`` — giving *minimal* segments; then
    merge consecutive segments at the smallest period under which the
    segment-key sequence (costs + internal edge shape, boundary-blind)
    is fully periodic, so one instance spans one structural repeat
    rather than one articulation slice."""
    parent = list(range(n))

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for u in range(n):
        for v in children[u]:
            ru, rv = find(u), find(v)
            if ru != rv:
                parent[ru] = rv
    comps: dict[int, list[int]] = {}
    for i in topo:
        comps.setdefault(find(i), []).append(i)

    def seg_key(comp: list[int], cpos: dict[int, int], lo: int,
                hi: int) -> Hashable:
        seg = comp[lo:hi]
        costs = tuple((nodes[i].ops, nodes[i].in_bytes, nodes[i].out_bytes)
                      for i in seg)
        internal = sorted((cpos[i] - lo, cpos[c] - lo)
                          for i in seg for c in children[i]
                          if lo <= cpos[c] < hi)
        return costs, tuple(internal)

    instances: list[list[int]] = []
    for comp in comps.values():
        m = len(comp)
        cpos = {node: k for k, node in enumerate(comp)}
        diff = [0] * (m + 1)
        for node in comp:
            ch = children[node]
            if ch:
                diff[cpos[node]] += 1
                diff[max(cpos[c] for c in ch)] -= 1
        bounds = [0]
        run = 0
        for k in range(m):
            run += diff[k]
            if run <= 1:
                bounds.append(k + 1)
        segs = list(zip(bounds[:-1], bounds[1:]))
        keys = [seg_key(comp, cpos, lo, hi) for lo, hi in segs]
        msg = len(segs)
        merged = None
        for p in range(1, msg // 2 + 1):
            if all(keys[i] == keys[i + p] for i in range(msg - p)):
                merged = [comp[segs[i][0]:segs[min(i + p, msg) - 1][1]]
                          for i in range(0, msg, p)]
                break
        if merged is not None:
            instances.extend(merged)
        else:
            instances.extend(comp[lo:hi] for lo, hi in segs)
    return instances


def detect_templates(graph: TaskGraph, *, min_repeats: int = 4
                     ) -> TemplatePartition | None:
    """Partition ``graph`` into repeated template instances, or ``None``
    when the graph is not repetitive enough for tiling to pay off.

    Builder-emitted ``blocks`` are the free fast path (uncovered nodes
    become singleton instances); otherwise the generic detector cuts
    each weakly-connected component at single-crossing-producer points
    and merges the minimal segments at the smallest structural period.
    Instances are grouped into templates by canonical signature — node
    costs, internal edge shape, boundary arity — so blocks differing in
    any one node's costs or in how they are fed never merge.  Returns
    ``None`` unless the dominant template repeats ``min_repeats`` times
    AND template-covered instances span most of the graph (tiling a
    mostly-unique graph would just be per-fragment EFT)."""
    n = len(graph.nodes)
    if n == 0 or min_repeats < 2:
        return None
    edges = graph.edge_indices()
    topo = graph.topo_order()
    pos = [0] * n
    for p, i in enumerate(topo):
        pos[i] = p

    if graph.blocks:
        inst = [sorted((graph.index(b) for b in blk), key=pos.__getitem__)
                for blk in graph.blocks]
        covered = {i for s in inst for i in s}
        inst.extend([i] for i in topo if i not in covered)
    else:
        children: list[list[int]] = [[] for _ in range(n)]
        for u, v in edges:
            children[u].append(v)
        inst = _generic_instances(n, children, topo, graph.nodes)
    if not inst or n < 2.0 * len(inst):
        return None   # degenerate: near-singleton instances, nothing to tile

    inst_of = [-1] * n
    slot_of = [0] * n
    for a, s in enumerate(inst):
        for k, i in enumerate(s):
            inst_of[i] = a
            slot_of[i] = k
    internal: list[list[tuple[int, int]]] = [[] for _ in inst]
    inb: list[list[tuple[int, float]]] = [[] for _ in inst]
    outb: list[list[int]] = [[] for _ in inst]
    for u, v in edges:
        a, b = inst_of[u], inst_of[v]
        if a == b:
            internal[a].append((slot_of[u], slot_of[v]))
        else:
            outb[a].append(slot_of[u])
            inb[b].append((slot_of[v], float(graph.nodes[u].out_bytes)))

    sig_id: dict[Hashable, int] = {}
    signatures: list[Hashable] = []
    template_of: list[int] = []
    for a, s in enumerate(inst):
        costs = tuple((graph.nodes[i].ops, graph.nodes[i].in_bytes,
                       graph.nodes[i].out_bytes) for i in s)
        sig = (costs, tuple(sorted(internal[a])), tuple(sorted(inb[a])),
               tuple(sorted(Counter(outb[a]).items())))
        t = sig_id.get(sig)
        if t is None:
            t = len(signatures)
            sig_id[sig] = t
            signatures.append(sig)
        template_of.append(t)

    counts = Counter(template_of)
    if max(counts.values()) < min_repeats:
        return None
    covered_nodes = sum(len(s) for a, s in enumerate(inst)
                        if counts[template_of[a]] >= min_repeats)
    if 2 * covered_nodes < n:
        return None
    return TemplatePartition(instances=tuple(tuple(s) for s in inst),
                             template_of=tuple(template_of),
                             signatures=tuple(signatures))


# ---------------------------------------------------------------------------
# Adapt output: the assignment in domain coordinates
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GraphPlan:
    """Phase-3 output: which tasks each device runs, in planned order.

    ``assignments`` maps device name -> task names (planned execution
    order per device); ``assign``/``order`` are the solver coordinates the
    schedule phase rebuilds the timeline from.  Frozen because instances
    are shared across ``PlanCache`` hits.
    """

    assignments: tuple[tuple[str, tuple[str, ...]], ...]
    assign: tuple[int, ...]
    order: tuple[int, ...]

    def tasks_of(self, device: str) -> tuple[str, ...]:
        for name, tasks in self.assignments:
            if name == device:
                return tasks
        return ()


# ---------------------------------------------------------------------------
# The domain
# ---------------------------------------------------------------------------


@register_domain("task-graph")
class TaskGraphDomain:
    """DS-POAS for precedence-constrained task graphs."""

    name = "task-graph"

    def __init__(self, devices: Sequence[DeviceProfile], *,
                 bus: str | BusTopology = "serialized",
                 dynamic: bool = False, refine: bool = True,
                 hierarchical: bool | str = "auto",
                 min_repeats: int = 4):
        self._devices = list(devices)
        self.topology = BusTopology.from_spec(bus, self._devices)
        self.bus = self.topology.spec
        self.refine = refine
        self.hierarchical = hierarchical
        self.min_repeats = min_repeats
        self.dyn = DynamicScheduler(self._devices, bus=self.topology) \
            if dynamic else None

    def predict(self) -> Sequence[DeviceProfile]:
        return self.dyn.snapshot() if self.dyn is not None else self._devices

    def set_devices(self, devices: Sequence[DeviceProfile], *,
                    topology: "str | BusTopology | None" = None) -> None:
        """Elastic membership change-point (DESIGN.md §16): swap the
        planning device set, so the next admission solves on the new
        cluster.  ``topology`` replaces the bus when given; spec-string
        topologies are rebuilt for the new device list automatically,
        while a custom ``BusTopology`` is kept as-is (its attach rows are
        name-keyed, so rows for departed devices are simply unused —
        joiners need an explicit ``topology``).  Dynamic mode carries
        re-fitted models for surviving devices and invalidates hooked
        plan caches via the scheduler's re-fit listeners."""
        self._devices = list(devices)
        if topology is not None:
            self.topology = BusTopology.from_spec(topology, self._devices)
        elif self.topology.spec in ("serialized", "independent"):
            self.topology = BusTopology.from_spec(self.topology.spec,
                                                  self._devices)
        self.bus = self.topology.spec
        if self.dyn is not None:
            self.dyn.bus = self.topology
            self.dyn.set_devices(self._devices)

    def optimize(self, devices: Sequence[DeviceProfile],
                 w: TaskGraph) -> GraphScheduleResult:
        # the template-tiled path (DESIGN.md §15) kicks in automatically
        # when the detector finds enough repeated structure; flat list
        # scheduling stays the path for one-off / irregular graphs
        if self.hierarchical and isinstance(w, TaskGraph):
            part = w.template_partition(min_repeats=self.min_repeats)
            if part is not None:
                return solve_hierarchical(devices, w.task_specs(),
                                          w.edge_indices(), partition=part,
                                          bus=self.topology,
                                          refine=self.refine)
        return solve_list_schedule(devices, w.task_specs(),
                                   w.edge_indices(), bus=self.topology,
                                   refine=self.refine)

    def adapt(self, devices: Sequence[DeviceProfile],
              opt: GraphScheduleResult, w: TaskGraph) -> GraphPlan:
        per_dev: dict[str, list[str]] = {d.name: [] for d in devices}
        for i in opt.order:
            per_dev[devices[opt.assign[i]].name].append(w.nodes[i].name)
        return GraphPlan(
            assignments=tuple((name, tuple(tasks))
                              for name, tasks in per_dev.items()),
            assign=tuple(opt.assign), order=tuple(opt.order))

    def schedule(self, devices: Sequence[DeviceProfile], plan: GraphPlan,
                 w: TaskGraph) -> Schedule:
        spec = GraphTimelineSpec(devices=tuple(devices),
                                 tasks=w.task_specs(),
                                 edges=w.edge_indices(),
                                 assign=plan.assign, order=plan.order,
                                 topology=self.topology)
        tl = spec.rebase()
        ops = [0.0] * len(devices)
        for i, a in enumerate(plan.assign):
            ops[a] += float(w.nodes[i].ops)
        finish = [tl.device_finish(d.name) for d in devices]
        res = OptimizeResult(ops=ops, makespan=tl.makespan,
                             finish_times=finish, bus=self.bus)
        return Schedule(result=res, timeline=tl,
                        priorities=priority_order(list(devices)), spec=spec)

    def cost_signature(self, w: TaskGraph) -> Hashable:
        return w.cost_signature()


# ---------------------------------------------------------------------------
# Case-study builder: a transformer block as a DAG
# ---------------------------------------------------------------------------


def transformer_block(*, d_model: int = 4096, seq: int = 4096,
                      ff_mult: int = 4, groups: int = 4,
                      dtype_size: int = 2, name: str = "block",
                      d_ff: int | None = None) -> TaskGraph:
    """A transformer block (attention → residual → MLP) as a ``TaskGraph``.

    The QKV projection, attention, and both MLP matmuls are split into
    ``groups`` independent head/column groups — the DAG width co-execution
    exploits (each group is a self-contained chain, so the list scheduler
    can spread groups across devices while the projection/combine joins
    keep the precedence structure honest).  Ops are multiply-accumulates;
    bytes follow the activation/weight shapes at ``dtype_size``.

    Shapes per group g (d = d_model, s = seq, f = ff_mult*d, G = groups):
      qkv_g   (s,d)x(d,3d/G)   reads X + its weight slice, emits Q/K/V_g
      attn_g  scores+mix       2*s*s*(d/G) ops over Q/K/V_g, emits (s,d/G)
      proj    (s,d)x(d,d)      joins every attn_g, emits the residual input
      res1    elementwise add  s*d cheap ops (host-friendly)
      up_g    (s,d)x(d,f/G)    column-split first MLP matmul
      down_g  (s,f/G)x(f/G,d)  row-split second matmul (partial sums)
      combine sum of partials  joins every down_g, emits the block output
    """
    f = d_ff if d_ff is not None else ff_mult * d_model
    if groups < 1 or d_model % groups or f % groups:
        raise ValueError("groups must divide d_model and the FF width "
                         "(ff_mult*d_model, or d_ff when given)")
    d, s, G = d_model, seq, groups
    dg, fg = d // G, f // G
    x_bytes = float(s * d * dtype_size)          # one (s, d) activation
    nodes: list[TaskNode] = []
    edges: list[tuple[str, str]] = []

    for g in range(G):
        qkv = f"{name}.qkv{g}"
        attn = f"{name}.attn{g}"
        nodes.append(TaskNode(qkv, ops=float(s) * d * (3 * dg),
                              in_bytes=x_bytes + d * (3 * dg) * dtype_size,
                              out_bytes=float(s * 3 * dg * dtype_size)))
        nodes.append(TaskNode(attn, ops=2.0 * s * s * dg,
                              out_bytes=float(s * dg * dtype_size)))
        edges.append((qkv, attn))
        edges.append((attn, f"{name}.proj"))
    nodes.append(TaskNode(f"{name}.proj", ops=float(s) * d * d,
                          in_bytes=float(d * d * dtype_size),
                          out_bytes=x_bytes))
    nodes.append(TaskNode(f"{name}.res1", ops=float(s * d),
                          in_bytes=x_bytes, out_bytes=x_bytes))
    edges.append((f"{name}.proj", f"{name}.res1"))
    for g in range(G):
        up = f"{name}.up{g}"
        down = f"{name}.down{g}"
        nodes.append(TaskNode(up, ops=float(s) * d * fg,
                              in_bytes=float(d * fg * dtype_size),
                              out_bytes=float(s * fg * dtype_size)))
        nodes.append(TaskNode(down, ops=float(s) * fg * d,
                              in_bytes=float(fg * d * dtype_size),
                              out_bytes=x_bytes))
        edges.append((f"{name}.res1", up))
        edges.append((up, down))
        edges.append((down, f"{name}.combine"))
    nodes.append(TaskNode(f"{name}.combine", ops=float(s * d * G),
                          out_bytes=x_bytes))
    return TaskGraph(nodes=tuple(nodes), edges=tuple(edges))


def transformer_stack(config=None, *, layers: int | None = None,
                      microbatches: int = 1, seq: int = 4096,
                      groups: int = 4, dtype_size: int = 2,
                      name: str | None = None) -> TaskGraph:
    """A whole-model DAG: ``layers`` transformer blocks × ``microbatches``
    independent pipelines, shaped by a model from the in-repo config zoo.

    ``config`` is an ``ArchConfig``, a config name for
    ``repro.configs.get_config`` (e.g. ``"stablelm-12b"``), or None for
    the default block geometry.  ``layers`` defaults to the config's
    ``num_layers``.  Each microbatch processes ``seq // microbatches``
    tokens through its own chain of blocks (block l feeds block l+1 —
    ``combine`` → every ``qkv`` group); distinct microbatches share no
    edges, which is the width the scheduler spreads across devices.  This
    is the 10²–10⁴-node regime the scheduler benchmark sweeps
    (``benchmarks/scheduler.py``), built from the same configs the rest of
    the repo trains, so graph scale tracks real model shapes.

    ``groups`` is clamped to the largest divisor of both widths not above
    the requested value, so any config is accepted as-is.
    """
    d_model, d_ff = 4096, 16384
    cfg_name = "block"
    if config is not None:
        if isinstance(config, str):
            from repro.configs import get_config   # lazy: avoids a cycle
            cfg_name = config
            config = get_config(config)
        else:
            cfg_name = getattr(config, "name", "model")
        d_model = int(config.d_model)
        d_ff = int(config.d_ff)
        if layers is None:
            layers = int(config.num_layers)
    if layers is None:
        layers = 1
    if layers < 1 or microbatches < 1:
        raise ValueError("layers and microbatches must be >= 1")
    g = max(1, min(groups, d_model, d_ff))
    while d_model % g or d_ff % g:
        g -= 1
    seq_mb = max(1, seq // microbatches)
    base = name if name is not None else str(cfg_name)

    nodes: list[TaskNode] = []
    edges: list[tuple[str, str]] = []
    blocks: list[tuple[str, ...]] = []
    for m in range(microbatches):
        prev: str | None = None
        for l in range(layers):
            block = transformer_block(d_model=d_model, d_ff=d_ff,
                                      seq=seq_mb, groups=g,
                                      dtype_size=dtype_size,
                                      name=f"{base}.l{l}.m{m}")
            nodes.extend(block.nodes)
            edges.extend(block.edges)
            blocks.append(tuple(t.name for t in block.nodes))
            if prev is not None:
                for gi in range(g):
                    edges.append((prev, f"{base}.l{l}.m{m}.qkv{gi}"))
            prev = f"{base}.l{l}.m{m}.combine"
    return TaskGraph(nodes=tuple(nodes), edges=tuple(edges),
                     blocks=tuple(blocks))


def moe_block(*, d_model: int = 4096, seq: int = 4096,
              d_ff: int = 16384, experts: int = 8,
              experts_per_token: int = 2, groups: int = 4,
              dtype_size: int = 2, name: str = "moe") -> TaskGraph:
    """A mixture-of-experts transformer block as a ``TaskGraph``.

    The attention half is identical to ``transformer_block`` (grouped
    qkv → attn → proj → res1); the dense MLP is replaced by the MoE
    pattern: a cheap ``router`` fans out to ``experts`` *parallel* expert
    branches — each an ``up``/``down`` matmul pair over its token share
    ``seq * experts_per_token / experts`` — joined by a weighted
    ``combine``.  Every expert reads its OWN weight slab
    (``2 * d_model * d_ff`` bytes), so at low tokens-per-expert the DAG
    is copy-bound where the dense block is compute-bound — exactly the
    wide, link-pressured fan-out ALP co-execution is for.
    """
    f = d_ff
    if groups < 1 or d_model % groups:
        raise ValueError("groups must divide d_model")
    if experts < 1 or experts_per_token < 1 or experts_per_token > experts:
        raise ValueError("need 1 <= experts_per_token <= experts")
    d, s, G, E = d_model, seq, groups, experts
    dg = d // G
    tok_e = float(s) * experts_per_token / E    # tokens per expert
    x_bytes = float(s * d * dtype_size)
    nodes: list[TaskNode] = []
    edges: list[tuple[str, str]] = []

    for g in range(G):
        qkv = f"{name}.qkv{g}"
        attn = f"{name}.attn{g}"
        nodes.append(TaskNode(qkv, ops=float(s) * d * (3 * dg),
                              in_bytes=x_bytes + d * (3 * dg) * dtype_size,
                              out_bytes=float(s * 3 * dg * dtype_size)))
        nodes.append(TaskNode(attn, ops=2.0 * s * s * dg,
                              out_bytes=float(s * dg * dtype_size)))
        edges.append((qkv, attn))
        edges.append((attn, f"{name}.proj"))
    nodes.append(TaskNode(f"{name}.proj", ops=float(s) * d * d,
                          in_bytes=float(d * d * dtype_size),
                          out_bytes=x_bytes))
    nodes.append(TaskNode(f"{name}.res1", ops=float(s * d),
                          in_bytes=x_bytes, out_bytes=x_bytes))
    edges.append((f"{name}.proj", f"{name}.res1"))
    router = f"{name}.router"
    nodes.append(TaskNode(router, ops=float(s) * d * E,
                          in_bytes=float(d * E * dtype_size),
                          out_bytes=float(s * E * dtype_size)))
    edges.append((f"{name}.res1", router))
    for e in range(E):
        up = f"{name}.up{e}"
        down = f"{name}.down{e}"
        nodes.append(TaskNode(up, ops=tok_e * d * f,
                              in_bytes=float(d * f * dtype_size)
                              + tok_e * d * dtype_size,
                              out_bytes=tok_e * f * dtype_size))
        nodes.append(TaskNode(down, ops=tok_e * f * d,
                              in_bytes=float(f * d * dtype_size),
                              out_bytes=tok_e * d * dtype_size))
        edges.append((router, up))
        edges.append((up, down))
        edges.append((down, f"{name}.combine"))
    nodes.append(TaskNode(f"{name}.combine",
                          ops=float(s * d * experts_per_token),
                          out_bytes=x_bytes))
    return TaskGraph(nodes=tuple(nodes), edges=tuple(edges))


def moe_stack(config=None, *, layers: int | None = None,
              microbatches: int = 1, seq: int = 4096,
              experts: int | None = None,
              experts_per_token: int | None = None,
              moe_every: int | None = None,
              groups: int = 4, dtype_size: int = 2,
              name: str | None = None) -> TaskGraph:
    """A whole MoE model DAG from the in-repo config zoo — expert fan-out
    as parallel DAG branches (``moe_block``), dense ``transformer_block``
    layers interleaved per the config's ``moe_every`` stride.

    ``config`` is an ``ArchConfig``, a config name (``"dbrx-132b"``,
    ``"llama4-maverick-400b-a17b"``), or None for the default geometry;
    explicit keyword arguments override the config's
    ``num_experts``/``experts_per_token``/``moe_every``.  Layer l is a
    MoE layer when ``(l + 1) % moe_every == 0`` (llama4's interleaving
    convention), so ``moe_every=1`` makes every layer MoE (dbrx).  Same
    microbatch pipelining and group clamping as ``transformer_stack``.
    """
    d_model, d_ff = 4096, 16384
    cfg_name = "moe"
    if config is not None:
        if isinstance(config, str):
            from repro.configs import get_config   # lazy: avoids a cycle
            cfg_name = config
            config = get_config(config)
        else:
            cfg_name = getattr(config, "name", "model")
        d_model = int(config.d_model)
        d_ff = int(config.d_ff)
        if layers is None:
            layers = int(config.num_layers)
        if experts is None and getattr(config, "num_experts", None):
            experts = int(config.num_experts)
        if experts_per_token is None \
                and getattr(config, "experts_per_token", None):
            experts_per_token = int(config.experts_per_token)
        if moe_every is None and getattr(config, "moe_every", None):
            moe_every = int(config.moe_every)
    layers = 1 if layers is None else layers
    experts = 8 if experts is None else experts
    experts_per_token = min(2, experts) if experts_per_token is None \
        else experts_per_token
    moe_every = 1 if moe_every is None else moe_every
    if layers < 1 or microbatches < 1 or moe_every < 1:
        raise ValueError("layers, microbatches and moe_every must be >= 1")
    g = max(1, min(groups, d_model, d_ff))
    while d_model % g or d_ff % g:
        g -= 1
    seq_mb = max(1, seq // microbatches)
    base = name if name is not None else str(cfg_name)

    nodes: list[TaskNode] = []
    edges: list[tuple[str, str]] = []
    blocks: list[tuple[str, ...]] = []
    for m in range(microbatches):
        prev: str | None = None
        for l in range(layers):
            bname = f"{base}.l{l}.m{m}"
            if (l + 1) % moe_every == 0:
                block = moe_block(d_model=d_model, d_ff=d_ff, seq=seq_mb,
                                  experts=experts,
                                  experts_per_token=experts_per_token,
                                  groups=g, dtype_size=dtype_size,
                                  name=bname)
            else:
                block = transformer_block(d_model=d_model, d_ff=d_ff,
                                          seq=seq_mb, groups=g,
                                          dtype_size=dtype_size,
                                          name=bname)
            nodes.extend(block.nodes)
            edges.extend(block.edges)
            blocks.append(tuple(t.name for t in block.nodes))
            if prev is not None:
                for gi in range(g):
                    edges.append((prev, f"{bname}.qkv{gi}"))
            prev = f"{bname}.combine"
    return TaskGraph(nodes=tuple(nodes), edges=tuple(edges),
                     blocks=tuple(blocks))


def ssm_block(*, d_model: int = 4096, seq: int = 4096,
              d_state: int = 128, expand: int = 2, head_dim: int = 64,
              ssm_groups: int = 1, chunk: int = 256, conv: int = 4,
              dtype_size: int = 2, name: str = "ssm") -> TaskGraph:
    """A mamba2-style SSD block as a ``TaskGraph`` — the scan-chain DAG
    shape (ROADMAP: whole-model DAGs beyond attention stacks).

    SSD (state-space duality) splits the sequence into chunks: each
    chunk's *intra* term is a quadratic attention-like matmul — chunks
    mutually independent, the DAG width — while the *inter* term carries
    a recurrent ``(d_inner, d_state)`` state chunk-to-chunk — a serial
    scan chain, the DAG depth.  That mix (wide independent quadratic
    work threaded by a cheap serial spine) is structurally unlike the
    transformer/MoE builders and exercises the scheduler's handling of
    long mandatory chains.

    Shapes (d = d_model, s = seq, di = expand*d, ds = d_state,
    nh = di/head_dim, G = ssm_groups, Q = s/chunks):
      inproj    (s,d)x(d,2di+2G*ds+nh)  z gate, x, B, C, dt in one matmul
      conv      depthwise K-tap conv over x/B/C (cheap, elementwise)
      intra{c}  2*Q^2*di ops            chunk-local attention-like term
      state{c}  2*Q*di*ds ops           state update; chains state{c-1}
      outproj   (s,di)x(di,d)           gated output projection
    ``state{c-1}`` also feeds ``intra{c}`` (the inter-chunk output
    contribution), and the final state joins ``outproj``; the state
    payload crossing chunks is ``di*ds`` fp32 bytes."""
    if d_model < 1 or seq < 1 or d_state < 1 or expand < 1:
        raise ValueError("d_model, seq, d_state and expand must be >= 1")
    d, s, ds, G = d_model, float(seq), d_state, ssm_groups
    di = expand * d_model
    nh = max(1, di // head_dim)
    conv_dim = di + 2 * G * ds
    w_in = 2 * di + 2 * G * ds + nh
    x_bytes = float(seq * d * dtype_size)
    n_chunks = max(1, seq // chunk)
    q = s / n_chunks                     # tokens per chunk
    nodes: list[TaskNode] = []
    edges: list[tuple[str, str]] = []

    inproj = f"{name}.inproj"
    cv = f"{name}.conv"
    outproj = f"{name}.outproj"
    nodes.append(TaskNode(inproj, ops=s * d * w_in,
                          in_bytes=x_bytes + float(d * w_in * dtype_size),
                          out_bytes=s * conv_dim * dtype_size))
    nodes.append(TaskNode(cv, ops=s * conv_dim * conv,
                          in_bytes=float(conv_dim * conv * dtype_size),
                          out_bytes=s * conv_dim * dtype_size))
    edges.append((inproj, cv))
    for c in range(n_chunks):
        intra = f"{name}.intra{c}"
        state = f"{name}.state{c}"
        nodes.append(TaskNode(intra, ops=2.0 * q * q * di,
                              out_bytes=q * di * dtype_size))
        nodes.append(TaskNode(state, ops=2.0 * q * di * ds,
                              out_bytes=float(di * ds * 4)))
        edges.append((cv, intra))
        edges.append((cv, state))
        if c > 0:
            edges.append((f"{name}.state{c-1}", state))
            edges.append((f"{name}.state{c-1}", intra))
        edges.append((intra, outproj))
    edges.append((f"{name}.state{n_chunks-1}", outproj))
    nodes.append(TaskNode(outproj, ops=s * di * d,
                          in_bytes=float(di * d * dtype_size),
                          out_bytes=x_bytes))
    return TaskGraph(nodes=tuple(nodes), edges=tuple(edges))


def ssm_stack(config=None, *, layers: int | None = None,
              microbatches: int = 1, seq: int = 4096,
              chunk: int | None = None, dtype_size: int = 2,
              name: str | None = None) -> TaskGraph:
    """A whole SSM model DAG from the in-repo config zoo (ROADMAP's open
    whole-model-DAG item): ``layers`` mamba2-style ``ssm_block``s ×
    ``microbatches`` independent pipelines, block l's ``outproj`` feeding
    block l+1's ``inproj``.  ``config`` is an ``ArchConfig``, a config
    name (``"mamba2-2_7b"``), or None for the default geometry; shapes
    (``d_model``, ``ssm_state``, ``ssm_expand``, ``ssm_head_dim``,
    ``ssm_chunk``, ``ssm_conv``, ``ssm_groups``) come from the config.
    Emits its block partition (``blocks``) like the other stack builders,
    so the template detector gets the per-layer tiling for free."""
    d_model, d_state, expand = 2560, 128, 2
    head_dim, ssm_groups, cfg_chunk, conv = 64, 1, 256, 4
    cfg_name = "ssm"
    if config is not None:
        if isinstance(config, str):
            from repro.configs import get_config   # lazy: avoids a cycle
            cfg_name = config
            config = get_config(config)
        else:
            cfg_name = getattr(config, "name", "model")
        d_model = int(config.d_model)
        d_state = int(config.ssm_state) or d_state
        expand = int(config.ssm_expand)
        head_dim = int(config.ssm_head_dim)
        ssm_groups = int(getattr(config, "ssm_groups", 1))
        cfg_chunk = int(config.ssm_chunk)
        conv = int(getattr(config, "ssm_conv", 4))
        if layers is None:
            layers = int(config.num_layers)
    layers = 1 if layers is None else layers
    chunk = cfg_chunk if chunk is None else chunk
    if layers < 1 or microbatches < 1 or chunk < 1:
        raise ValueError("layers, microbatches and chunk must be >= 1")
    seq_mb = max(1, seq // microbatches)
    base = name if name is not None else str(cfg_name)

    nodes: list[TaskNode] = []
    edges: list[tuple[str, str]] = []
    blocks: list[tuple[str, ...]] = []
    for m in range(microbatches):
        prev: str | None = None
        for l in range(layers):
            bname = f"{base}.l{l}.m{m}"
            block = ssm_block(d_model=d_model, seq=seq_mb, d_state=d_state,
                              expand=expand, head_dim=head_dim,
                              ssm_groups=ssm_groups, chunk=chunk,
                              conv=conv, dtype_size=dtype_size, name=bname)
            nodes.extend(block.nodes)
            edges.extend(block.edges)
            blocks.append(tuple(t.name for t in block.nodes))
            if prev is not None:
                edges.append((prev, f"{bname}.inproj"))
            prev = f"{bname}.outproj"
    return TaskGraph(nodes=tuple(nodes), edges=tuple(edges),
                     blocks=tuple(blocks))


def diamond(ops: float = 1e9, *, bytes_per_edge: float = 1e6,
            width: int = 2, name: str = "dia") -> TaskGraph:
    """The textbook fork-join DAG (source → ``width`` parallel branches →
    sink) — the benchmark/test fixture where list scheduling visibly beats
    naive single-device placement."""
    nodes = [TaskNode(f"{name}.src", ops=ops / 10,
                      in_bytes=bytes_per_edge, out_bytes=bytes_per_edge)]
    edges: list[tuple[str, str]] = []
    for i in range(width):
        mid = f"{name}.mid{i}"
        nodes.append(TaskNode(mid, ops=ops, out_bytes=bytes_per_edge))
        edges.append((f"{name}.src", mid))
        edges.append((mid, f"{name}.sink"))
    nodes.append(TaskNode(f"{name}.sink", ops=ops / 10,
                          out_bytes=bytes_per_edge))
    return TaskGraph(nodes=tuple(nodes), edges=tuple(edges))


# ---------------------------------------------------------------------------
# Timeline invariant: dependencies respected
# ---------------------------------------------------------------------------


def verify_graph_dependencies(graph: TaskGraph | GraphTimelineSpec,
                              timeline: Timeline, *,
                              eps: float = 1e-9) -> list[str]:
    """The DAG invariant on a (planned or measured) timeline: no task's
    compute starts before every upstream task's output has landed —
    upstream compute finished, and any copy feeding this task's device
    completed.  Returns violations (empty = pass)."""
    if isinstance(graph, GraphTimelineSpec):
        edges = [(graph.tasks[u].name, graph.tasks[v].name)
                 for u, v in graph.edges]
    else:
        edges = list(graph.edges)
    problems: list[str] = []

    def compute_span(task: str) -> tuple[float, float] | None:
        evs = [e for e in timeline.task_events(task) if e.kind == "compute"]
        if not evs:
            return None
        return min(e.start for e in evs), max(e.end for e in evs)

    spans = {t: compute_span(t)
             for t in {name for edge in edges for name in edge}}
    for u, v in edges:
        su, sv = spans[u], spans[v]
        if su is None or sv is None:
            continue   # task not executed (partial assignment)
        if sv[0] < su[1] - eps:
            problems.append(f"task {v!r} computes at {sv[0]:.6g} before "
                            f"upstream {u!r} finished at {su[1]:.6g}")
    # every copy feeding a consumer (its copy_in events) must land before
    # that consumer computes — checked once per task, not once per edge
    for v in {b for _, b in edges}:
        sv = spans[v]
        if sv is None:
            continue
        for e in timeline.task_events(v):
            if e.kind == "copy_in" and sv[0] < e.end - eps:
                problems.append(f"task {v!r} computes at {sv[0]:.6g} "
                                f"before its input copy ended at {e.end:.6g}")
    return problems
