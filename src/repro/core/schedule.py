"""POAS phase 4 — *Schedule*.

Static and dynamic schedulers plus the priority-ordered shared-bus
communication scheme (paper §3.4, §4.4, Fig. 2):

* input copies (A, B) run on the shared bus in priority order (fastest
  device first);
* each device computes as soon as its inputs land (overlapping other
  devices' copies);
* output copies (C) are serialized in the same priority order.

``simulate_timeline`` produces the exact event timeline under this policy —
it is a thin front over the unified bus engine (``core.bus``), the same
event graph the optimizer prices feasibility on and the overlapped executor
derives its per-link ticket order from (DESIGN.md §4).  ``DynamicScheduler``
re-fits the per-device linear model from observed step times (EWMA-weighted
regression) and re-plans — this is the paper's §3.4.2 dynamic mode and
doubles as the straggler mitigation of the distributed runtime.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Sequence

import numpy as np

from .bus import (BusEvent, BusTopology, ClockState, GraphTimelineSpec,
                  TaskSpec, Timeline, TimelineSpec, ZERO_CLOCKS,
                  build_graph_timeline, build_timeline)
from .device_model import DeviceProfile, LinearTimeModel, priority_order
from .optimize import OptimizeResult, solve_bisection
from .predict import fit_linear

__all__ = ["BusEvent", "Timeline", "TimelineSpec", "GraphTimelineSpec",
           "simulate_timeline", "simulate_graph_timeline",
           "Schedule", "StaticScheduler", "DynamicScheduler"]


# ---------------------------------------------------------------------------
# Timeline simulation (Fig. 2) — one engine, shared with solver and executor
# ---------------------------------------------------------------------------


def simulate_timeline(devices: Sequence[DeviceProfile], ops: Sequence[float],
                      n: int, k: int, *,
                      topology: BusTopology | str | None = None,
                      order: Sequence[int] | None = None,
                      chunks: Sequence[int] | None = None,
                      clocks: ClockState = ZERO_CLOCKS) -> Timeline:
    """Exact simulation of the Fig. 2 schedule on the unified bus engine.

    ``topology`` defaults to the paper's single serialized bus; pass a
    ``BusTopology`` for independent or mixed link layouts, ``order`` to
    override the priority order, ``chunks`` to override each device's
    ``pipeline_chunks``, and ``clocks`` to start from carried-over
    link/device clocks (streaming runtime, DESIGN.md §9)."""
    return build_timeline(devices, ops, n, k, topology=topology, order=order,
                          chunks=chunks, clocks=clocks)


def simulate_graph_timeline(devices: Sequence[DeviceProfile],
                            tasks: Sequence[TaskSpec],
                            edges: Sequence[tuple[int, int]],
                            assign: Sequence[int], *,
                            topology: BusTopology | str | None = None,
                            order: Sequence[int] | None = None,
                            clocks: ClockState = ZERO_CLOCKS) -> Timeline:
    """Exact simulation of a task-graph schedule on the unified bus engine
    (DESIGN.md §10): same clocks as the divisible Fig. 2 simulation, plus
    precedence — cross-device edges priced as host-staged link copies,
    same-device edges free."""
    return build_graph_timeline(devices, tasks, edges, assign,
                                topology=topology, order=order, clocks=clocks)


# ---------------------------------------------------------------------------
# Static scheduler (paper §3.4.1)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Schedule:
    result: OptimizeResult
    timeline: Timeline
    priorities: list[int]  # device indices, highest priority first
    # Engine inputs the timeline was built from: lets a streaming runtime
    # rebase the plan onto carried-over clocks (or ground-truth models)
    # without knowing any domain geometry (DESIGN.md §9).  Divisible
    # domains attach a TimelineSpec, task-graph domains a GraphTimelineSpec
    # (DESIGN.md §10) — both expose rebase()/ops_by_device().
    spec: TimelineSpec | GraphTimelineSpec | None = None


def make_spec(devices: Sequence[DeviceProfile], ops: Sequence[float],
              n: int, k: int, topology: BusTopology | str | None,
              chunks: Sequence[int] | None = None) -> TimelineSpec:
    """The ``TimelineSpec`` for a schedule built with the default priority
    order (what every shipped domain does)."""
    devs = tuple(devices)
    return TimelineSpec(devices=devs, ops=tuple(float(c) for c in ops),
                        n=n, k=k,
                        topology=BusTopology.from_spec(topology, devs),
                        chunks=tuple(chunks) if chunks is not None else None,
                        order=tuple(priority_order(list(devs))))


class StaticScheduler:
    """Solve once, never re-plan (paper: 'gives excellent results' for GEMM)."""

    def __init__(self, devices: Sequence[DeviceProfile], *,
                 bus: str | BusTopology = "serialized"):
        self.devices = list(devices)
        self.bus = bus

    def plan(self, N: float, *, n: int, k: int) -> Schedule:
        res = solve_bisection(self.devices, N, n=n, k=k, bus=self.bus)
        tl = simulate_timeline(self.devices, res.ops, n, k, topology=self.bus)
        return Schedule(result=res, timeline=tl,
                        priorities=priority_order(self.devices),
                        spec=make_spec(self.devices, res.ops, n, k, self.bus))


# ---------------------------------------------------------------------------
# Dynamic scheduler (paper §3.4.2) — also the straggler mitigator
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Obs:
    ops: float
    seconds: float
    weight: float


class DynamicScheduler:
    """Re-fits each device's linear model from observations and re-plans.

    Observations are weighted by an exponential decay (newest heaviest), so a
    device that starts throttling (the paper's overheating scenario / a
    straggling TPU pod) sees its model — and hence its share — adapt within a
    few steps.

    Thread-safe: the streaming runtime's observation pump delivers
    ``observe`` calls from completion threads while the planner thread reads
    the models through ``snapshot`` — a re-fit can land mid-plan without a
    torn read (the plan is solved against a coherent snapshot; the re-fit
    bumps ``epoch`` and invalidates the ``PlanCache``, so the very next plan
    sees the new models).
    """

    def __init__(self, devices: Sequence[DeviceProfile], *,
                 bus: str | BusTopology = "serialized", decay: float = 0.7,
                 window: int = 32, min_obs: int = 2,
                 reset_threshold: float = 0.5, min_change: float = 0.01):
        self.devices = list(devices)
        self.bus = bus
        self.decay = decay
        self.window = window
        self.min_obs = min_obs
        # Change-point detection: an observation deviating from the current
        # model by more than this relative threshold (e.g. a 2x thermal
        # throttle) drops the device's stale window before fitting —
        # otherwise pre-throttle points blend with post-throttle ones and
        # the regression can transiently fit a near-zero (or negative,
        # clamped) slope that mis-plans worse than never adapting.
        self.reset_threshold = reset_threshold
        # A re-fit whose predicted time at the observed size moves less
        # than this (relative) is treated as confirming the current model:
        # skip it, or a steady-state stream would invalidate the PlanCache
        # (and re-solve) on every observation.  The 1% default absorbs
        # exact confirmations and sub-percent drift; measurement noise
        # above it (wall-clock jitter on very short stages) still re-fits —
        # tracking what was really measured is the point of dynamic mode,
        # so raise min_change per-deployment if plan churn costs more than
        # model freshness.
        self.min_change = min_change
        self._obs: list[list[_Obs]] = [[] for _ in devices]
        self.epoch = 0  # bumped on every model re-fit
        self.window_resets = 0
        self._refit_listeners: list = []
        self._lock = threading.RLock()

    def add_refit_listener(self, fn) -> None:
        """``fn()`` is called after every model re-fit (PlanCache hooks in)."""
        self._refit_listeners.append(fn)

    def snapshot(self) -> list[DeviceProfile]:
        """A coherent copy of the current device models (planner threads
        must never iterate ``devices`` while an observe() re-fit lands)."""
        with self._lock:
            return list(self.devices)

    def set_devices(self, devices: Sequence[DeviceProfile]) -> None:
        """Elastic membership change-point (DESIGN.md §16): replace the
        device set.  Surviving devices (matched by name) keep their
        re-fitted models and observation windows; departed ones drop
        theirs; joiners start from their given profile.  Bumps ``epoch``
        and fires the re-fit listeners, so every ``PlanCache`` hooked to
        this scheduler invalidates and the next plan sees the new set."""
        with self._lock:
            fitted = {d.name: d for d in self.devices}
            obs = {d.name: o for d, o in zip(self.devices, self._obs)}
            self.devices = [fitted.get(d.name, d) for d in devices]
            self._obs = [obs.get(d.name, []) for d in devices]
            self.epoch += 1
        for fn in self._refit_listeners:
            fn()

    def _refit(self, device_index: int, model, at_ops: float) -> None:
        d = self.devices[device_index]
        old, new = d.compute(at_ops), model(at_ops)
        if old > 0.0 and abs(new - old) / old < self.min_change:
            return   # confirms the current model; don't churn the cache
        self.devices[device_index] = dataclasses.replace(d, compute=model)
        self.epoch += 1
        for fn in self._refit_listeners:
            fn()

    def observe(self, device_index: int, ops: float, seconds: float) -> None:
        with self._lock:
            buf = self._obs[device_index]
            pred = self.devices[device_index].compute(ops)
            if buf and pred > 0.0 and \
                    abs(seconds - pred) / pred > self.reset_threshold:
                buf.clear()   # regime change (throttle/recovery): the old
                self.window_resets += 1   # window would poison the fit
            for o in buf:
                o.weight *= self.decay
            buf.append(_Obs(ops=ops, seconds=seconds, weight=1.0))
            del buf[: max(0, len(buf) - self.window)]
            if len(buf) >= self.min_obs and len({o.ops for o in buf}) >= 2:
                model = fit_linear([o.ops for o in buf],
                                   [o.seconds for o in buf],
                                   weights=[o.weight for o in buf])
                self._refit(device_index, model, ops)
            elif buf:
                # single-size observations: rescale slope to match latest rate
                d = self.devices[device_index]
                latest = buf[-1]
                base = d.compute(latest.ops)
                if base > 0 and isinstance(d.compute, LinearTimeModel):
                    ratio = latest.seconds / base
                    m = LinearTimeModel(a=d.compute.a * ratio,
                                        b=d.compute.b * ratio)
                    self._refit(device_index, m, ops)

    def plan(self, N: float, *, n: int, k: int) -> Schedule:
        devices = self.snapshot()
        res = solve_bisection(devices, N, n=n, k=k, bus=self.bus)
        tl = simulate_timeline(devices, res.ops, n, k, topology=self.bus)
        return Schedule(result=res, timeline=tl,
                        priorities=priority_order(devices),
                        spec=make_spec(devices, res.ops, n, k, self.bus))

    def models(self) -> list[LinearTimeModel]:
        return [d.compute for d in self.snapshot()]
