"""POAS phase 4 — *Schedule*.

Static and dynamic schedulers plus the priority-ordered shared-bus
communication scheme (paper §3.4, §4.4, Fig. 2):

* input copies (A, B) run on the shared bus in priority order (fastest
  device first);
* each device computes as soon as its inputs land (overlapping other
  devices' copies);
* output copies (C) are serialized in the same priority order.

``simulate_timeline`` produces the exact event timeline under this policy;
``DynamicScheduler`` re-fits the per-device linear model from observed step
times (EWMA-weighted regression) and re-plans — this is the paper's §3.4.2
dynamic mode and doubles as the straggler mitigation of the distributed
runtime.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .device_model import DeviceProfile, LinearTimeModel, priority_order
from .optimize import OptimizeResult, solve_bisection
from .predict import fit_linear


# ---------------------------------------------------------------------------
# Timeline simulation (Fig. 2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BusEvent:
    device: str
    kind: str       # "copy_in" | "compute" | "copy_out"
    start: float
    end: float

    @property
    def duration(self) -> float:
        return self.end - self.start


@dataclasses.dataclass
class Timeline:
    events: list[BusEvent]

    @property
    def makespan(self) -> float:
        return max((e.end for e in self.events), default=0.0)

    def device_events(self, name: str) -> list[BusEvent]:
        return [e for e in self.events if e.device == name]

    def device_finish(self, name: str) -> float:
        """When the device's last stage (usually copy_out) ends; 0 if idle."""
        return max((e.end for e in self.device_events(name)), default=0.0)

    def idle_time(self, name: str) -> float:
        evs = sorted(self.device_events(name), key=lambda e: e.start)
        if not evs:
            return self.makespan
        idle = evs[0].start
        for a, b in zip(evs, evs[1:]):
            idle += max(0.0, b.start - a.end)
        idle += self.makespan - evs[-1].end
        return idle

    def bus_busy_time(self) -> float:
        return sum(e.duration for e in self.events
                   if e.kind in ("copy_in", "copy_out"))


def simulate_timeline(devices: Sequence[DeviceProfile], ops: Sequence[float],
                      n: int, k: int) -> Timeline:
    """Exact serialized-bus simulation of the Fig. 2 schedule."""
    order = priority_order(devices)
    events: list[BusEvent] = []
    bus_free = 0.0
    compute_end: dict[int, float] = {}
    for i in order:
        d, c = devices[i], ops[i]
        if c <= 0:
            continue
        t_in = d.copy.in_time(c, n, k)
        if t_in > 0:
            events.append(BusEvent(d.name, "copy_in", bus_free, bus_free + t_in))
            bus_free += t_in
            start = bus_free
        else:
            start = 0.0
        t_c = d.compute(c)
        events.append(BusEvent(d.name, "compute", start, start + t_c))
        compute_end[i] = start + t_c
    # Output copies in priority order; they share the same bus, so each must
    # wait for the bus to be free AND its own compute to be done.
    for i in order:
        d, c = devices[i], ops[i]
        if c <= 0 or i not in compute_end:
            continue
        t_out = d.copy.out_time(c, n, k)
        if t_out <= 0:
            continue
        start = max(bus_free, compute_end[i])
        events.append(BusEvent(d.name, "copy_out", start, start + t_out))
        bus_free = start + t_out
    return Timeline(events)


# ---------------------------------------------------------------------------
# Static scheduler (paper §3.4.1)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Schedule:
    result: OptimizeResult
    timeline: Timeline
    priorities: list[int]  # device indices, highest priority first


class StaticScheduler:
    """Solve once, never re-plan (paper: 'gives excellent results' for GEMM)."""

    def __init__(self, devices: Sequence[DeviceProfile], *,
                 bus: str = "serialized"):
        self.devices = list(devices)
        self.bus = bus

    def plan(self, N: float, *, n: int, k: int) -> Schedule:
        res = solve_bisection(self.devices, N, n=n, k=k, bus=self.bus)
        tl = simulate_timeline(self.devices, res.ops, n, k)
        return Schedule(result=res, timeline=tl,
                        priorities=priority_order(self.devices))


# ---------------------------------------------------------------------------
# Dynamic scheduler (paper §3.4.2) — also the straggler mitigator
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Obs:
    ops: float
    seconds: float
    weight: float


class DynamicScheduler:
    """Re-fits each device's linear model from observations and re-plans.

    Observations are weighted by an exponential decay (newest heaviest), so a
    device that starts throttling (the paper's overheating scenario / a
    straggling TPU pod) sees its model — and hence its share — adapt within a
    few steps.
    """

    def __init__(self, devices: Sequence[DeviceProfile], *,
                 bus: str = "serialized", decay: float = 0.7,
                 window: int = 32, min_obs: int = 2):
        self.devices = list(devices)
        self.bus = bus
        self.decay = decay
        self.window = window
        self.min_obs = min_obs
        self._obs: list[list[_Obs]] = [[] for _ in devices]
        self.epoch = 0  # bumped on every model re-fit
        self._refit_listeners: list = []

    def add_refit_listener(self, fn) -> None:
        """``fn()`` is called after every model re-fit (PlanCache hooks in)."""
        self._refit_listeners.append(fn)

    def _refit(self, device_index: int, model) -> None:
        d = self.devices[device_index]
        self.devices[device_index] = dataclasses.replace(d, compute=model)
        self.epoch += 1
        for fn in self._refit_listeners:
            fn()

    def observe(self, device_index: int, ops: float, seconds: float) -> None:
        buf = self._obs[device_index]
        for o in buf:
            o.weight *= self.decay
        buf.append(_Obs(ops=ops, seconds=seconds, weight=1.0))
        del buf[: max(0, len(buf) - self.window)]
        if len(buf) >= self.min_obs and len({o.ops for o in buf}) >= 2:
            model = fit_linear([o.ops for o in buf], [o.seconds for o in buf],
                               weights=[o.weight for o in buf])
            self._refit(device_index, model)
        elif buf:
            # single-size observations: rescale slope to match latest rate
            d = self.devices[device_index]
            latest = buf[-1]
            base = d.compute(latest.ops)
            if base > 0 and isinstance(d.compute, LinearTimeModel):
                ratio = latest.seconds / base
                m = LinearTimeModel(a=d.compute.a * ratio,
                                    b=d.compute.b * ratio)
                self._refit(device_index, m)

    def plan(self, N: float, *, n: int, k: int) -> Schedule:
        res = solve_bisection(self.devices, N, n=n, k=k, bus=self.bus)
        tl = simulate_timeline(self.devices, res.ops, n, k)
        return Schedule(result=res, timeline=tl,
                        priorities=priority_order(self.devices))

    def models(self) -> list[LinearTimeModel]:
        return [d.compute for d in self.devices]
