"""POAS phase 4 — *Schedule*.

Static and dynamic schedulers plus the priority-ordered shared-bus
communication scheme (paper §3.4, §4.4, Fig. 2):

* input copies (A, B) run on the shared bus in priority order (fastest
  device first);
* each device computes as soon as its inputs land (overlapping other
  devices' copies);
* output copies (C) are serialized in the same priority order.

``simulate_timeline`` produces the exact event timeline under this policy —
it is a thin front over the unified bus engine (``core.bus``), the same
event graph the optimizer prices feasibility on and the overlapped executor
derives its per-link ticket order from (DESIGN.md §4).  ``DynamicScheduler``
re-fits the per-device linear model from observed step times (EWMA-weighted
regression) and re-plans — this is the paper's §3.4.2 dynamic mode and
doubles as the straggler mitigation of the distributed runtime.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .bus import BusEvent, BusTopology, Timeline, build_timeline
from .device_model import DeviceProfile, LinearTimeModel, priority_order
from .optimize import OptimizeResult, solve_bisection
from .predict import fit_linear

__all__ = ["BusEvent", "Timeline", "simulate_timeline", "Schedule",
           "StaticScheduler", "DynamicScheduler"]


# ---------------------------------------------------------------------------
# Timeline simulation (Fig. 2) — one engine, shared with solver and executor
# ---------------------------------------------------------------------------


def simulate_timeline(devices: Sequence[DeviceProfile], ops: Sequence[float],
                      n: int, k: int, *,
                      topology: BusTopology | str | None = None,
                      order: Sequence[int] | None = None,
                      chunks: Sequence[int] | None = None) -> Timeline:
    """Exact simulation of the Fig. 2 schedule on the unified bus engine.

    ``topology`` defaults to the paper's single serialized bus; pass a
    ``BusTopology`` for independent or mixed link layouts, ``order`` to
    override the priority order, and ``chunks`` to override each device's
    ``pipeline_chunks``."""
    return build_timeline(devices, ops, n, k, topology=topology, order=order,
                          chunks=chunks)


# ---------------------------------------------------------------------------
# Static scheduler (paper §3.4.1)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Schedule:
    result: OptimizeResult
    timeline: Timeline
    priorities: list[int]  # device indices, highest priority first


class StaticScheduler:
    """Solve once, never re-plan (paper: 'gives excellent results' for GEMM)."""

    def __init__(self, devices: Sequence[DeviceProfile], *,
                 bus: str | BusTopology = "serialized"):
        self.devices = list(devices)
        self.bus = bus

    def plan(self, N: float, *, n: int, k: int) -> Schedule:
        res = solve_bisection(self.devices, N, n=n, k=k, bus=self.bus)
        tl = simulate_timeline(self.devices, res.ops, n, k, topology=self.bus)
        return Schedule(result=res, timeline=tl,
                        priorities=priority_order(self.devices))


# ---------------------------------------------------------------------------
# Dynamic scheduler (paper §3.4.2) — also the straggler mitigator
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Obs:
    ops: float
    seconds: float
    weight: float


class DynamicScheduler:
    """Re-fits each device's linear model from observations and re-plans.

    Observations are weighted by an exponential decay (newest heaviest), so a
    device that starts throttling (the paper's overheating scenario / a
    straggling TPU pod) sees its model — and hence its share — adapt within a
    few steps.
    """

    def __init__(self, devices: Sequence[DeviceProfile], *,
                 bus: str | BusTopology = "serialized", decay: float = 0.7,
                 window: int = 32, min_obs: int = 2):
        self.devices = list(devices)
        self.bus = bus
        self.decay = decay
        self.window = window
        self.min_obs = min_obs
        self._obs: list[list[_Obs]] = [[] for _ in devices]
        self.epoch = 0  # bumped on every model re-fit
        self._refit_listeners: list = []

    def add_refit_listener(self, fn) -> None:
        """``fn()`` is called after every model re-fit (PlanCache hooks in)."""
        self._refit_listeners.append(fn)

    def _refit(self, device_index: int, model) -> None:
        d = self.devices[device_index]
        self.devices[device_index] = dataclasses.replace(d, compute=model)
        self.epoch += 1
        for fn in self._refit_listeners:
            fn()

    def observe(self, device_index: int, ops: float, seconds: float) -> None:
        buf = self._obs[device_index]
        for o in buf:
            o.weight *= self.decay
        buf.append(_Obs(ops=ops, seconds=seconds, weight=1.0))
        del buf[: max(0, len(buf) - self.window)]
        if len(buf) >= self.min_obs and len({o.ops for o in buf}) >= 2:
            model = fit_linear([o.ops for o in buf], [o.seconds for o in buf],
                               weights=[o.weight for o in buf])
            self._refit(device_index, model)
        elif buf:
            # single-size observations: rescale slope to match latest rate
            d = self.devices[device_index]
            latest = buf[-1]
            base = d.compute(latest.ops)
            if base > 0 and isinstance(d.compute, LinearTimeModel):
                ratio = latest.seconds / base
                m = LinearTimeModel(a=d.compute.a * ratio,
                                    b=d.compute.b * ratio)
                self._refit(device_index, m)

    def plan(self, N: float, *, n: int, k: int) -> Schedule:
        res = solve_bisection(self.devices, N, n=n, k=k, bus=self.bus)
        tl = simulate_timeline(self.devices, res.ops, n, k, topology=self.bus)
        return Schedule(result=res, timeline=tl,
                        priorities=priority_order(self.devices))

    def models(self) -> list[LinearTimeModel]:
        return [d.compute for d in self.devices]
