"""Overlapped co-execution runtime — replays a planned ``Timeline`` for real.

``simulate_timeline`` (Fig. 2) *models* the schedule: input copies serialized
on the shared bus in priority order, each device computing as soon as its
inputs land (overlapping other devices' copies), output copies serialized in
the same priority order.  This module *executes* it: one thread per device
runs its copy_in → compute → copy_out stages, with a ticketed shared-bus
lock granting bus access in exactly the planned event order.  Compute never
takes the bus, so device A's compute overlaps device B's copies — the
overlap the sequential loop this replaces could not express (DESIGN.md §4).

The executor records measured wall-clock intervals per stage as a
``Timeline`` of ``BusEvent``s, so the same invariant checks (bus
serialization, priority order, compute-after-copy) apply to a real run and
to the simulation.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Sequence

from .device_model import DeviceProfile
from .schedule import BusEvent, Timeline


@dataclasses.dataclass
class DeviceTask:
    """One device's three stages.  ``None`` stages are skipped (no-copy
    devices such as the host CPU compute in place)."""

    device: str
    copy_in: Callable[[], None] | None
    compute: Callable[[], None]
    copy_out: Callable[[], None] | None


class TicketBus:
    """Shared bus granting exclusive access in a fixed ticket order.

    Tickets are ``(device, kind)`` pairs; the grant sequence is derived from
    the planned timeline, so the measured run serializes transfers in the
    same priority order the optimizer assumed.
    """

    def __init__(self, sequence: Sequence[tuple[str, str]]):
        self._seq = list(sequence)
        self._pos = 0
        self._cv = threading.Condition()

    def acquire(self, ticket: tuple[str, str]) -> None:
        with self._cv:
            if ticket not in self._seq:
                raise ValueError(f"ticket {ticket} not in bus schedule")
            self._cv.wait_for(
                lambda: self._pos < len(self._seq)
                and self._seq[self._pos] == ticket)

    def release(self, ticket: tuple[str, str]) -> None:
        with self._cv:
            assert self._seq[self._pos] == ticket, (self._seq, self._pos,
                                                    ticket)
            self._pos += 1
            self._cv.notify_all()

    def cancel_device(self, device: str) -> None:
        """Drop a crashed device's pending tickets so the bus never stalls."""
        with self._cv:
            self._seq[self._pos:] = [t for t in self._seq[self._pos:]
                                     if t[0] != device]
            self._cv.notify_all()

    def retain(self, tickets: set[tuple[str, str]]) -> None:
        """Keep only the given pending tickets (callers may legitimately run
        a subset of the planned devices; unclaimed tickets must not wedge
        the grant sequence)."""
        with self._cv:
            self._seq[self._pos:] = [t for t in self._seq[self._pos:]
                                     if t in tickets]
            self._cv.notify_all()


class OverlappedExecutor:
    """Thread-per-device executor with a shared-bus lock.

    ``run`` returns the *measured* timeline.  Stage durations are whatever
    the callables really take; the planned timeline only fixes the bus
    grant order, exactly as the paper's runtime does.
    """

    def __init__(self, devices: Sequence[DeviceProfile], planned: Timeline):
        self.devices = list(devices)
        self.planned = planned
        self._bus = TicketBus(self.bus_sequence(planned))

    @staticmethod
    def bus_sequence(planned: Timeline) -> list[tuple[str, str]]:
        """Bus grant order: the planned copy events sorted by start time
        (ties broken copy_in first — inputs precede outputs in Fig. 2)."""
        copies = [e for e in planned.events if e.kind != "compute"]
        copies.sort(key=lambda e: (e.start, 0 if e.kind == "copy_in" else 1))
        return [(e.device, e.kind) for e in copies]

    def run(self, tasks: Sequence[DeviceTask]) -> Timeline:
        # A task list may cover only a subset of the planned devices; release
        # the unclaimed bus tickets up front or their successors would wait
        # forever (acquire has no timeout).
        provided: set[tuple[str, str]] = set()
        for t in tasks:
            if t.copy_in is not None:
                provided.add((t.device, "copy_in"))
            if t.copy_out is not None:
                provided.add((t.device, "copy_out"))
        self._bus.retain(provided)

        events: list[BusEvent] = []
        lock = threading.Lock()
        errors: list[BaseException] = []
        t0 = time.perf_counter()

        def stage(device: str, kind: str, fn: Callable[[], None],
                  on_bus: bool) -> None:
            ticket = (device, kind)
            if on_bus:
                self._bus.acquire(ticket)
            start = time.perf_counter() - t0
            try:
                fn()
            finally:
                # stamp the end BEFORE releasing the bus: the next holder may
                # start immediately, and measured bus events must not overlap
                end = time.perf_counter() - t0
                if on_bus:
                    self._bus.release(ticket)
            with lock:
                events.append(BusEvent(device, kind, start, end))

        def worker(task: DeviceTask) -> None:
            try:
                if task.copy_in is not None:
                    stage(task.device, "copy_in", task.copy_in, on_bus=True)
                stage(task.device, "compute", task.compute, on_bus=False)
                if task.copy_out is not None:
                    stage(task.device, "copy_out", task.copy_out, on_bus=True)
            except BaseException as exc:  # surfaced after join
                self._bus.cancel_device(task.device)
                with lock:
                    errors.append(exc)

        threads = [threading.Thread(target=worker, args=(t,), daemon=True)
                   for t in tasks]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if errors:
            raise errors[0]
        return Timeline(sorted(events, key=lambda e: (e.start, e.end)))
