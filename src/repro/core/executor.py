"""Overlapped co-execution — the threaded half of the streaming runtime.

The unified bus engine (``core.bus``, Fig. 2) *models* the schedule: copies
serialized per link in priority order, each device computing as soon as its
inputs land (overlapping other devices' copies).  This module *executes*
it, and since PR 3 it does so as a **stream**: ``StreamCore`` owns one
long-lived worker thread per device and one ticketed lock per topology
link, both of which survive across plans — each dispatched plan appends its
per-link grant sequence to the live buses, so plan k+1's input copies are
granted as soon as plan k's transfers drain a link, while plan k's tail is
still computing (DESIGN.md §9).  Compute never takes a link, so device A's
compute overlaps device B's copies — the overlap the paper's co-execution
speedup comes from; copies on *different* links proceed concurrently
(DESIGN.md §4).

``OverlappedExecutor`` is the one-shot facade kept for single-plan callers
(``HGemms.execute`` and the PR 1/2 test surface): it spins up a private
``StreamCore``, dispatches the one plan, waits, and shuts the core down.

Measured wall-clock intervals are recorded per stage as ``Timeline``s of
``BusEvent``s — per job *and* for the whole stream — so the same invariant
checks (per-link serialization, priority order, compute-after-copy) apply
to a real run, to a whole job stream across plan boundaries, and to the
simulation.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Callable, Mapping, Sequence

from .bus import BusEvent, Timeline
from .device_model import DeviceProfile


@dataclasses.dataclass
class DeviceTask:
    """One device's three stages.  ``None`` stages are skipped (no-copy
    devices such as the host CPU compute in place).

    Pipelined form: when ``compute_chunks`` is set, the per-chunk callables
    replace the whole-stage ones and the executor streams them — the input
    chunks run back-to-back under one bus ticket (the engine schedules a
    device's chunks contiguously on its link) while a consumer thread
    computes chunk j as soon as chunk j has landed, which is the real
    copy/compute overlap the chunked timeline prices.  Output chunks run
    after compute under the copy_out ticket.

    Task-graph form (DESIGN.md §10): ``task`` names the DAG task this
    stage group runs (a device may run many tasks per job, each its own
    ``DeviceTask``), and ``deps`` lists upstream task names — the worker
    blocks on their completion events before starting any stage, so a task
    never begins before every upstream task's outputs have landed, while
    tickets still serialize the links in the engine's planned order."""

    device: str
    copy_in: Callable[[], None] | None
    compute: Callable[[], None] | None
    copy_out: Callable[[], None] | None
    copy_in_chunks: Sequence[Callable[[], None]] | None = None
    compute_chunks: Sequence[Callable[[], None]] | None = None
    copy_out_chunks: Sequence[Callable[[], None]] | None = None
    task: str | None = None
    deps: tuple[str, ...] = ()

    @property
    def pipelined(self) -> bool:
        return bool(self.compute_chunks)

    def has_copy_in(self) -> bool:
        return self.copy_in is not None or bool(self.copy_in_chunks)

    def has_copy_out(self) -> bool:
        return self.copy_out is not None or bool(self.copy_out_chunks)

    def ticket(self, kind: str) -> tuple:
        """The engine's ticket for one of this task's stages —
        ``(device, kind)`` for divisible plans, ``(task, device, kind)``
        for task-graph plans (matches ``Timeline._copy_tickets``)."""
        base = (self.device, kind)
        return base if self.task is None else (self.task,) + base


class TicketBus:
    """Shared bus granting exclusive access in a fixed ticket order.

    Tickets are hashable tuples — ``(device, kind)`` for one-shot plans,
    ``(job, device, kind)`` in the streaming runtime; the grant sequence is
    derived from the planned timeline, so the measured run serializes
    transfers in the same priority order the optimizer assumed.  ``extend``
    appends a later plan's tickets while earlier ones are still draining —
    this is what lets the bus survive across plans.
    """

    def __init__(self, sequence: Sequence[tuple] = ()):
        self._seq = list(sequence)
        self._pos = 0
        self._cv = threading.Condition()

    def extend(self, sequence: Sequence[tuple]) -> None:
        """Append a later plan's grant sequence (streaming runtime)."""
        with self._cv:
            self._seq.extend(sequence)
            self._cv.notify_all()

    def acquire(self, ticket: tuple, *, append_timeout: float = 1.0) -> None:
        with self._cv:
            if ticket not in self._seq:
                # a concurrent dispatch/reissue may be mid-extend: its worker
                # closures can reach acquire before the grant sequence lands
                # on this bus.  Wait (bounded) for the ticket to appear
                # instead of raising on the benign race.
                if not self._cv.wait_for(lambda: ticket in self._seq,
                                         timeout=append_timeout):
                    raise ValueError(f"ticket {ticket} not in bus schedule")
            self._cv.wait_for(
                lambda: self._pos < len(self._seq)
                and self._seq[self._pos] == ticket)

    def release(self, ticket: tuple) -> None:
        with self._cv:
            # explicit check, not assert: the grant-head invariant must
            # survive `python -O` (a silent out-of-order release would let
            # two transfers share the link and corrupt every measured
            # timeline downstream)
            if self._pos >= len(self._seq) or self._seq[self._pos] != ticket:
                raise RuntimeError(
                    f"out-of-order release: {ticket} is not the grant head "
                    f"(pending={self._seq[self._pos:]!r})")
            self._pos += 1
            # prune the granted prefix: a persistent bus on a sustained
            # stream must not retain every historical ticket (and acquire's
            # membership scan must stay O(pending), not O(all history))
            del self._seq[:self._pos]
            self._pos = 0
            self._cv.notify_all()

    def cancel(self, pred: Callable[[tuple], bool]) -> None:
        """Drop pending tickets matching ``pred`` so the bus never stalls
        behind stages that will no longer run (crashed device, failed job)."""
        with self._cv:
            self._seq[self._pos:] = [t for t in self._seq[self._pos:]
                                     if not pred(t)]
            self._cv.notify_all()

    def cancel_device(self, device: str) -> None:
        """Drop a crashed device's pending tickets (any job)."""
        self.cancel(lambda t: t[-2] == device)

    def retain(self, tickets: set[tuple]) -> None:
        """Keep only the given pending tickets (callers may legitimately run
        a subset of the planned devices; unclaimed tickets must not wedge
        the grant sequence)."""
        self.cancel(lambda t: t not in tickets)

    def depth(self) -> int:
        """Pending (not-yet-granted) tickets — the admission-control queue
        depth signal (DESIGN.md §13)."""
        with self._cv:
            return len(self._seq) - self._pos


# ---------------------------------------------------------------------------
# The persistent streaming core
# ---------------------------------------------------------------------------


class JobHandle:
    """Completion handle for one dispatched plan: its measured events, its
    error (if any), and a done event / callback hook."""

    def __init__(self, job: str, devices: int):
        self.job = job
        self.events: list[BusEvent] = []
        self.errors: list[BaseException] = []
        self._remaining = devices
        self._done = threading.Event()
        self._lock = threading.Lock()
        self._callbacks: list[Callable[["JobHandle"], None]] = []
        if devices == 0:   # a plan may assign every op to devices the task
            self._done.set()   # list doesn't cover; nothing will ever run

    def _device_done(self) -> None:
        with self._lock:
            self._remaining -= 1
            if self._remaining > 0:
                return
            callbacks = list(self._callbacks)
        # callbacks run BEFORE the done event (wait() must observe their
        # errors) and never propagate: _device_done runs on a persistent
        # device worker thread, and a raising callback would kill it —
        # hanging every later job queued on that device
        for fn in callbacks:
            self._run_callback(fn)
        self._done.set()

    def _run_callback(self, fn: Callable[["JobHandle"], None]) -> None:
        try:
            fn(self)
        except BaseException as exc:
            with self._lock:
                self.errors.append(exc)

    def add_done_callback(self, fn: Callable[["JobHandle"], None]) -> None:
        with self._lock:
            if not self._done.is_set():
                self._callbacks.append(fn)
                return
        self._run_callback(fn)

    @property
    def done(self) -> bool:
        return self._done.is_set()

    def wait(self, timeout: float | None = None) -> Timeline:
        """Block until every device finished its stages; raise the first
        stage error; return the job's measured timeline."""
        if not self._done.wait(timeout):
            raise TimeoutError(f"job {self.job!r} still running")
        if self.errors:
            raise self.errors[0]
        return self.timeline()

    def timeline(self) -> Timeline:
        with self._lock:
            events = list(self.events)
        return Timeline(sorted(events, key=lambda e: (e.start, e.end)))


class _TaskDone:
    """Completion latch for one (job, task): set when the task's stage
    group finished (``ok`` records whether it succeeded)."""

    __slots__ = ("event", "ok")

    def __init__(self) -> None:
        self.event = threading.Event()
        self.ok = False


class _DeviceWorker(threading.Thread):
    """One long-lived worker per device: runs dispatched stage groups
    strictly in dispatch order (a device executes one plan at a time)."""

    def __init__(self, device: str):
        super().__init__(name=f"poas-dev-{device}", daemon=True)
        self.device = device
        self.q: queue.SimpleQueue = queue.SimpleQueue()
        self.start()

    def run(self) -> None:
        while True:
            item = self.q.get()
            if item is None:
                return
            item()  # closures handle their own errors


class StreamCore:
    """Long-lived per-device worker threads + per-link ticket buses that
    survive across plans — the persistent half of ``CoExecutionRuntime``.

    ``dispatch`` is non-blocking: it appends the plan's tickets to the live
    buses and enqueues each device's stage group on that device's worker, so
    back-to-back plans overlap (plan k+1's copies start the moment plan k
    drains each link, per-device order preserved by the worker queues).  All
    measured events share one time origin (core creation), so the stream
    timeline is one coherent axis across plan boundaries.
    """

    def __init__(self) -> None:
        self._workers: dict[str, _DeviceWorker] = {}
        self._buses: dict[str, TicketBus] = {}
        self._lock = threading.Lock()
        # the stream record: every job's measured events on one time axis.
        # This is the observable product (stream_timeline / cross-plan
        # invariant checks) and grows with the stream; long-lived callers
        # that don't need the full history can snapshot and reset it.
        self._events: list[BusEvent] = []
        # per-(job, task) completion: cross-device dependency waits for
        # task-graph plans (entries dropped when the job completes)
        self._task_done: dict[tuple[str, str], "_TaskDone"] = {}
        # per-(job, task) [incarnation, status] for named tasks.  status is
        # "pending" until the stage group begins, then "started"; a
        # mid-graph reissue bumps the incarnation of still-pending tasks,
        # turning their already-enqueued closures into no-ops (a SimpleQueue
        # entry cannot be removed) while the replacement closures — carrying
        # the new incarnation — run on their new devices.
        self._task_state: dict[tuple[str, str], list] = {}
        # optional observer: called with (job id, event) after every
        # measured stage lands — the runtime's straggler monitor and
        # during-execution observation feed hang off this (DESIGN.md §11).
        self.on_event: Callable[[str, BusEvent], None] | None = None
        self._jobs = 0
        self._closed = False
        # serializes ticket admission (bus extends + worker enqueues) across
        # dispatch and reissue: without it a concurrent dispatch could land
        # between a reissue's bus-extend and its worker-enqueue, inverting
        # the two jobs' relative order on a shared link vs. a shared device
        # queue — a permanent deadlock (the grant head would sit behind its
        # own waiter).  Always acquired before self._lock, never after.
        self._admit = threading.Lock()
        self._t0 = time.perf_counter()

    # -- plumbing -----------------------------------------------------------

    def _worker(self, device: str) -> _DeviceWorker:
        with self._lock:
            w = self._workers.get(device)
            if w is None:
                w = self._workers[device] = _DeviceWorker(device)
            return w

    def _bus(self, link: str) -> TicketBus:
        with self._lock:
            b = self._buses.get(link)
            if b is None:
                b = self._buses[link] = TicketBus()
            return b

    def _record(self, handle: JobHandle, device: str, kind: str, link: str | None,
                start: float, end: float, chunk: int = 0,
                task: str | None = None) -> None:
        ev = BusEvent(device, kind, start, end, link, chunk, task)
        with self._lock:
            self._events.append(ev)
        with handle._lock:
            handle.events.append(ev)
        cb = self.on_event
        if cb is not None:
            try:
                cb(handle.job, ev)
            except BaseException as exc:
                # observers run on device worker / pipeline threads: a
                # raising monitor must fail the job, never kill the worker
                with handle._lock:
                    handle.errors.append(exc)

    def now(self) -> float:
        """Current stream time (seconds since core creation) — the axis
        every measured event is stamped on."""
        return time.perf_counter() - self._t0

    def stream_timeline(self, *, reset: bool = False) -> Timeline:
        """Every measured event of every job, one time axis — what the
        cross-plan invariant checks run on.  ``reset=True`` hands the
        record over and clears it (long-lived streams that checkpoint
        their history instead of holding it forever)."""
        with self._lock:
            events = list(self._events)
            if reset:
                self._events.clear()
        return Timeline(sorted(events, key=lambda e: (e.start, e.end)))

    def link_depths(self) -> dict[str, int]:
        """Pending-ticket depth per live bus — what the multi-tenant
        admission controller inspects before pricing a deadline
        (DESIGN.md §13).  HTS-style admission works at queue depth, not
        at job completion granularity."""
        with self._lock:
            buses = dict(self._buses)
        return {name: bus.depth() for name, bus in buses.items()}

    def shutdown(self) -> None:
        """Stop the worker threads after their queues drain."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            workers = list(self._workers.values())
        for w in workers:
            w.q.put(None)
        for w in workers:
            w.join(timeout=30)

    # -- dispatch -----------------------------------------------------------

    def dispatch(self, tasks: Sequence[DeviceTask],
                 link_order: Mapping[str, Sequence[tuple]],
                 *, job: str | None = None) -> JobHandle:
        """Admit one plan: ``link_order`` is the engine's per-link grant
        order (``Timeline.link_ticket_order``); tickets for stages the task
        list does not provide are skipped up front so they can never wedge
        a bus.  Task-graph plans name their tasks (``DeviceTask.task``):
        each gets a per-job completion latch, and a task with ``deps``
        blocks on its upstream latches before running any stage.  Returns
        immediately with a ``JobHandle``."""
        with self._lock:
            if self._closed:
                raise RuntimeError("StreamCore is shut down")
            jid = job if job is not None else f"job{self._jobs}"
            self._jobs += 1
        named: list[tuple[str, str]] = []
        for t in tasks:
            if t.compute is None and not t.compute_chunks:
                raise ValueError(f"task {t.device!r} has neither compute "
                                 "nor compute_chunks")
            if t.task is not None:
                named.append((jid, t.task))
        handle = JobHandle(jid, len(tasks))
        if named:
            with self._lock:
                for key in named:
                    self._task_done[key] = _TaskDone()
                    self._task_state[key] = [0, "pending"]
            # all of a job's latches are released together when the job
            # completes (dep waits are intra-job, so this is the earliest
            # safe point) — the registry must not grow with the stream
            handle.add_done_callback(lambda h: self._drop_latches(named))
        with self._admit:
            ticket_link = self._admit_tickets(jid, tasks, link_order)
            for t in tasks:
                self._worker(t.device).q.put(
                    lambda t=t: self._run_task(handle, jid, t, ticket_link))
        return handle

    def _admit_tickets(self, jid: str, tasks: Sequence[DeviceTask],
                       link_order: Mapping[str, Sequence[tuple]]
                       ) -> dict[tuple, str]:
        """Extend the buses with a plan's per-link grant order, filtered to
        the stages the task list actually provides (an unclaimed ticket
        would wedge its link).  Returns ticket -> link for the stage
        closures.  Shared by dispatch and reissue; callers hold
        ``self._admit``."""
        provided: set[tuple] = set()
        for t in tasks:
            if t.has_copy_in():
                provided.add(t.ticket("copy_in"))
            if t.has_copy_out():
                provided.add(t.ticket("copy_out"))
        ticket_link: dict[tuple, str] = {}
        for link, seq in link_order.items():
            kept = []
            for tk in seq:
                tk = tuple(tk)
                if tk in provided:
                    kept.append((jid,) + tk)
                    ticket_link[tk] = link
            if kept:
                self._bus(link).extend(kept)
        return ticket_link

    def _drop_latches(self, keys: Sequence[tuple[str, str]]) -> None:
        with self._lock:
            for key in keys:
                self._task_done.pop(key, None)
                self._task_state.pop(key, None)

    # -- mid-graph re-planning (DESIGN.md §11) ------------------------------

    def pending_tasks(self, jid: str) -> set[str]:
        """Names of the job's not-yet-started (hence migratable) named
        tasks.  A task counts as started the moment its stage group begins
        — including a group still blocked on upstream latches or a ticket
        grant — because its worker thread is already committed to it."""
        with self._lock:
            return {name for (j, name), st in self._task_state.items()
                    if j == jid and st[1] == "pending"}

    def reissue(self, handle: JobHandle, tasks: Sequence[DeviceTask],
                link_order: Mapping[str, Sequence[tuple]]) -> tuple[str, ...]:
        """Splice a mid-graph re-plan into a live job: atomically revoke the
        given tasks' not-yet-started incarnations (their queued closures
        become no-ops, their pending tickets are dropped from every bus) and
        re-dispatch the replacements — new devices, new per-link grant order
        (``link_order`` from the re-planned frontier timeline's
        ``link_ticket_order``).  New tickets are appended at each bus's
        tail, so the splice behaves exactly like a fresh dispatch and the
        streaming deadlock-freedom argument applies unchanged: granted
        prefixes and the frozen tasks' pending tickets are never disturbed.

        Returns the task names actually spliced.  A task that started
        between the caller's ``pending_tasks`` snapshot and this call keeps
        its original placement and tickets; its replacement is discarded.
        """
        jid = handle.job
        by_name: dict[str, DeviceTask] = {}
        for t in tasks:
            if t.task is None:
                raise ValueError("reissue needs named (task-graph) stage "
                                 "groups")
            by_name[t.task] = t
        new_inc: dict[str, int] = {}
        with self._lock:
            if self._closed:
                raise RuntimeError("StreamCore is shut down")
            spliced = [name for name in by_name
                       if self._task_state.get((jid, name),
                                               (0, "started"))[1]
                       == "pending"]
            # top up the handle BEFORE bumping incarnations: a stale
            # closure dequeued right after the bump calls _device_done
            # immediately, and the job must not complete early
            with handle._lock:
                handle._remaining += len(spliced)
            for name in spliced:
                st = self._task_state[(jid, name)]
                st[0] += 1
                new_inc[name] = st[0]
            buses = list(self._buses.values())
        if not spliced:
            return ()
        spliced_set = set(spliced)
        repl = [t for t in tasks if t.task in spliced_set]
        # the whole splice (ticket drop + re-admission + enqueue) happens
        # under the admission lock: a dispatch landing in between would
        # invert the two jobs' relative order on a shared link vs. a
        # shared device queue — a deadlock
        with self._admit:
            for bus in buses:
                bus.cancel(lambda t: t[0] == jid and len(t) == 4
                           and t[1] in spliced_set)
            ticket_link = self._admit_tickets(jid, repl, link_order)
            # enqueue in the caller's order (the re-planned spec's
            # topological order) — a same-device dependency queued out of
            # order would deadlock the device worker on its own queue
            for t in repl:
                self._worker(t.device).q.put(
                    lambda t=t, inc=new_inc[t.task]:
                        self._run_task(handle, jid, t, ticket_link, inc))
        return tuple(t.task for t in repl)

    def _await_deps(self, jid: str, task: DeviceTask) -> None:
        """Block until every upstream task's stage group completed; raise
        if one failed (the data this task needs never landed).  Deps not in
        the registry are treated as satisfied — callers may legitimately
        dispatch a subset of the planned tasks."""
        for dep in task.deps:
            with self._lock:
                latch = self._task_done.get((jid, dep))
            if latch is None:
                continue
            latch.event.wait()
            if not latch.ok:
                raise RuntimeError(f"upstream task {dep!r} failed; "
                                   f"{task.task!r} cannot run")

    def run(self, tasks: Sequence[DeviceTask],
            link_order: Mapping[str, Sequence[tuple]],
            *, job: str | None = None) -> Timeline:
        """Dispatch one plan and block for its measured timeline."""
        return self.dispatch(tasks, link_order, job=job).wait()

    # -- per-device stage groups -------------------------------------------

    def _acquire(self, jid: str, task: DeviceTask, kind: str,
                 ticket_link: Mapping[tuple, str]) -> tuple[TicketBus, tuple]:
        base = task.ticket(kind)
        link = ticket_link.get(base)
        if link is None:
            raise ValueError(f"ticket {base} not in bus schedule")
        bus = self._bus(link)
        ticket = (jid,) + base
        bus.acquire(ticket)
        return bus, ticket

    def _run_task(self, handle: JobHandle, jid: str, task: DeviceTask,
                  ticket_link: Mapping[tuple, str], inc: int = 0) -> None:
        latch = None
        if task.task is not None:
            with self._lock:
                st = self._task_state.get((jid, task.task))
                if st is not None and st[0] != inc:
                    # superseded by a mid-graph reissue: the replacement
                    # closure owns this task now.  This stale stage group
                    # is a no-op — but it still counts toward the handle,
                    # which was topped up at reissue time.
                    handle._device_done()
                    return
                if st is not None:
                    st[1] = "started"
                latch = self._task_done.get((jid, task.task))
        try:
            self._await_deps(jid, task)
            if task.pipelined:
                self._run_pipelined(handle, jid, task, ticket_link)
            else:
                self._run_staged(handle, jid, task, ticket_link)
            if latch is not None:
                latch.ok = True
        except BaseException as exc:  # surfaced via handle.wait()
            # drop the failed stage group's remaining tickets on every bus
            # so no grant sequence wedges; later jobs' tickets stay (the
            # worker thread survives).  Divisible plans have one stage
            # group per device; graph plans cancel per task — sibling
            # tasks on the device still run (a downstream task that needed
            # this one fails its own dependency wait and cancels itself).
            if task.task is None:
                pred = lambda t: t[0] == jid and t[-2] == task.device
            else:
                pred = lambda t: (t[0] == jid and len(t) == 4
                                  and t[1] == task.task)
            with self._lock:
                buses = list(self._buses.values())
            for bus in buses:
                bus.cancel(pred)
            with handle._lock:
                handle.errors.append(exc)
        finally:
            if latch is not None:
                latch.event.set()   # downstream waiters see ok=False on error
            handle._device_done()

    def _run_staged(self, handle: JobHandle, jid: str, task: DeviceTask,
                    ticket_link: Mapping[tuple, str]) -> None:
        def stage(kind: str, fn: Callable[[], None], on_bus: bool) -> None:
            bus = ticket = None
            if on_bus:
                bus, ticket = self._acquire(jid, task, kind, ticket_link)
            start = time.perf_counter() - self._t0
            try:
                fn()
            finally:
                # stamp the end BEFORE releasing the bus: the next holder may
                # start immediately, and measured bus events must not overlap
                end = time.perf_counter() - self._t0
                if bus is not None:
                    bus.release(ticket)
            self._record(handle, task.device, kind,
                         ticket_link.get(task.ticket(kind)), start, end,
                         task=task.task)

        if task.copy_in is not None:
            stage("copy_in", task.copy_in, on_bus=True)
        stage("compute", task.compute, on_bus=False)
        if task.copy_out is not None:
            stage("copy_out", task.copy_out, on_bus=True)

    def _run_pipelined(self, handle: JobHandle, jid: str, task: DeviceTask,
                       ticket_link: Mapping[tuple, str]) -> None:
        """Stream the chunked stages exactly as the engine prices them:
        the copy feeder holds the copy_in ticket across its chunks (the
        engine schedules them contiguously on the link) while the
        consumer thread computes chunk j as soon as it lands, and the
        output loop copies chunk j out as soon as chunk j is computed —
        overlapping the remaining compute chunks, like the engine's
        ``max(link_clock, compute_chunk_end)`` out-chunk starts."""
        dev = task.device
        t0 = self._t0
        in_chunks = list(task.copy_in_chunks or ())
        comp_chunks = list(task.compute_chunks or ())
        out_chunks = list(task.copy_out_chunks or ())
        landed = threading.Semaphore(0)     # input chunk j copied
        computed = threading.Semaphore(0)   # compute chunk j finished
        aborted = threading.Event()
        consumer_errs: list[BaseException] = []

        def consume() -> None:
            try:
                for j, fn in enumerate(comp_chunks):
                    if in_chunks:
                        landed.acquire()
                        if aborted.is_set():
                            return
                    start = time.perf_counter() - t0
                    fn()
                    self._record(handle, dev, "compute", None, start,
                                 time.perf_counter() - t0, chunk=j,
                                 task=task.task)
                    computed.release()
            except BaseException as exc:
                consumer_errs.append(exc)
            finally:
                # on early exit, unblock an output loop waiting on
                # chunks that will never be computed (it re-checks
                # consumer_errs / aborted after each acquire)
                for _ in out_chunks:
                    computed.release()

        consumer = threading.Thread(target=consume, daemon=True)
        if in_chunks:
            bus, ticket = self._acquire(jid, task, "copy_in", ticket_link)
            consumer.start()
            try:
                for j, fn in enumerate(in_chunks):
                    start = time.perf_counter() - t0
                    fn()
                    self._record(handle, dev, "copy_in",
                                 ticket_link.get(task.ticket("copy_in")),
                                 start, time.perf_counter() - t0, chunk=j,
                                 task=task.task)
                    landed.release()
            except BaseException:
                # unblock the consumer before surfacing the error
                aborted.set()
                landed.release()
                raise
            finally:
                bus.release(ticket)
        else:
            consumer.start()
        if out_chunks:
            bus, ticket = self._acquire(jid, task, "copy_out", ticket_link)
            try:
                for j, fn in enumerate(out_chunks):
                    computed.acquire()   # chunk j's matmul is done
                    if consumer_errs or aborted.is_set():
                        break
                    start = time.perf_counter() - t0
                    fn()
                    self._record(handle, dev, "copy_out",
                                 ticket_link.get(task.ticket("copy_out")),
                                 start, time.perf_counter() - t0, chunk=j,
                                 task=task.task)
            finally:
                bus.release(ticket)
        consumer.join()
        if consumer_errs:
            raise consumer_errs[0]


# ---------------------------------------------------------------------------
# One-shot facade (single-plan callers and the PR 1/2 API surface)
# ---------------------------------------------------------------------------


class OverlappedExecutor:
    """Thin one-shot facade over ``StreamCore``: executes a single planned
    timeline with a private core, then shuts it down.

    ``run`` returns the *measured* timeline.  Stage durations are whatever
    the callables really take; the planned timeline only fixes each link's
    grant order, exactly as the paper's runtime does.
    """

    def __init__(self, devices: Sequence[DeviceProfile], planned: Timeline):
        self.devices = list(devices)
        self.planned = planned

    @staticmethod
    def link_sequences(planned: Timeline) -> dict[str, list[tuple[str, str]]]:
        """Per-link grant order of (device, kind) tickets, straight from the
        engine's timeline (chunk events collapse to one ticket; events with
        no link tag — e.g. measured timelines — share a single 'bus')."""
        return planned.link_ticket_order()

    @staticmethod
    def bus_sequence(planned: Timeline) -> list[tuple[str, str]]:
        """Flat grant order across all links (``Timeline.ticket_order``).
        Kept for single-bus callers; ``link_sequences`` is the per-link
        truth."""
        return planned.ticket_order()

    def run(self, tasks: Sequence[DeviceTask]) -> Timeline:
        core = StreamCore()
        try:
            return core.run(tasks, self.planned.link_ticket_order())
        finally:
            core.shutdown()
