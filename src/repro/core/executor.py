"""Overlapped co-execution runtime — replays a planned ``Timeline`` for real.

The unified bus engine (``core.bus``, Fig. 2) *models* the schedule: copies
serialized per link in priority order, each device computing as soon as its
inputs land (overlapping other devices' copies).  This module *executes*
it: one thread per device runs its copy_in → compute → copy_out stages,
with one ticketed lock per topology link granting access in exactly the
engine's per-link ticket order (``Timeline.link_ticket_order``).  Compute
never takes a link, so device A's compute overlaps device B's copies — the
overlap the paper's co-execution speedup comes from; copies on *different*
links (a GPU's PCIe feed vs a TPU group's ICI feed) proceed concurrently
(DESIGN.md §4).

The executor records measured wall-clock intervals per stage as a
``Timeline`` of ``BusEvent``s, so the same invariant checks (per-link
serialization, priority order, compute-after-copy) apply to a real run and
to the simulation.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Sequence

from .bus import BusEvent, Timeline
from .device_model import DeviceProfile


@dataclasses.dataclass
class DeviceTask:
    """One device's three stages.  ``None`` stages are skipped (no-copy
    devices such as the host CPU compute in place).

    Pipelined form: when ``compute_chunks`` is set, the per-chunk callables
    replace the whole-stage ones and the executor streams them — the input
    chunks run back-to-back under one bus ticket (the engine schedules a
    device's chunks contiguously on its link) while a consumer thread
    computes chunk j as soon as chunk j has landed, which is the real
    copy/compute overlap the chunked timeline prices.  Output chunks run
    after compute under the copy_out ticket."""

    device: str
    copy_in: Callable[[], None] | None
    compute: Callable[[], None] | None
    copy_out: Callable[[], None] | None
    copy_in_chunks: Sequence[Callable[[], None]] | None = None
    compute_chunks: Sequence[Callable[[], None]] | None = None
    copy_out_chunks: Sequence[Callable[[], None]] | None = None

    @property
    def pipelined(self) -> bool:
        return bool(self.compute_chunks)

    def has_copy_in(self) -> bool:
        return self.copy_in is not None or bool(self.copy_in_chunks)

    def has_copy_out(self) -> bool:
        return self.copy_out is not None or bool(self.copy_out_chunks)


class TicketBus:
    """Shared bus granting exclusive access in a fixed ticket order.

    Tickets are ``(device, kind)`` pairs; the grant sequence is derived from
    the planned timeline, so the measured run serializes transfers in the
    same priority order the optimizer assumed.
    """

    def __init__(self, sequence: Sequence[tuple[str, str]]):
        self._seq = list(sequence)
        self._pos = 0
        self._cv = threading.Condition()

    def acquire(self, ticket: tuple[str, str]) -> None:
        with self._cv:
            if ticket not in self._seq:
                raise ValueError(f"ticket {ticket} not in bus schedule")
            self._cv.wait_for(
                lambda: self._pos < len(self._seq)
                and self._seq[self._pos] == ticket)

    def release(self, ticket: tuple[str, str]) -> None:
        with self._cv:
            assert self._seq[self._pos] == ticket, (self._seq, self._pos,
                                                    ticket)
            self._pos += 1
            self._cv.notify_all()

    def cancel_device(self, device: str) -> None:
        """Drop a crashed device's pending tickets so the bus never stalls."""
        with self._cv:
            self._seq[self._pos:] = [t for t in self._seq[self._pos:]
                                     if t[0] != device]
            self._cv.notify_all()

    def retain(self, tickets: set[tuple[str, str]]) -> None:
        """Keep only the given pending tickets (callers may legitimately run
        a subset of the planned devices; unclaimed tickets must not wedge
        the grant sequence)."""
        with self._cv:
            self._seq[self._pos:] = [t for t in self._seq[self._pos:]
                                     if t in tickets]
            self._cv.notify_all()


class OverlappedExecutor:
    """Thread-per-device executor with one ticketed lock per topology link.

    ``run`` returns the *measured* timeline.  Stage durations are whatever
    the callables really take; the planned timeline only fixes each link's
    grant order, exactly as the paper's runtime does.
    """

    def __init__(self, devices: Sequence[DeviceProfile], planned: Timeline):
        self.devices = list(devices)
        self.planned = planned
        self._buses: dict[str, TicketBus] = {}
        self._ticket_link: dict[tuple[str, str], str] = {}
        for link, seq in self.link_sequences(planned).items():
            self._buses[link] = TicketBus(seq)
            for ticket in seq:
                self._ticket_link[ticket] = link

    @staticmethod
    def link_sequences(planned: Timeline) -> dict[str, list[tuple[str, str]]]:
        """Per-link grant order of (device, kind) tickets, straight from the
        engine's timeline (chunk events collapse to one ticket; events with
        no link tag — e.g. measured timelines — share a single 'bus')."""
        return planned.link_ticket_order()

    @staticmethod
    def bus_sequence(planned: Timeline) -> list[tuple[str, str]]:
        """Flat grant order across all links (``Timeline.ticket_order``).
        Kept for single-bus callers; ``link_sequences`` is the per-link
        truth."""
        return planned.ticket_order()

    def _bus_for(self, ticket: tuple[str, str]) -> TicketBus:
        link = self._ticket_link.get(ticket)
        if link is None:
            raise ValueError(f"ticket {ticket} not in bus schedule")
        return self._buses[link]

    def run(self, tasks: Sequence[DeviceTask]) -> Timeline:
        # A task list may cover only a subset of the planned devices; release
        # the unclaimed bus tickets up front or their successors would wait
        # forever (acquire has no timeout).
        provided: set[tuple[str, str]] = set()
        for t in tasks:
            if t.compute is None and not t.compute_chunks:
                raise ValueError(f"task {t.device!r} has neither compute "
                                 "nor compute_chunks")
            if t.has_copy_in():
                provided.add((t.device, "copy_in"))
            if t.has_copy_out():
                provided.add((t.device, "copy_out"))
        for bus in self._buses.values():
            bus.retain(provided)

        events: list[BusEvent] = []
        lock = threading.Lock()
        errors: list[BaseException] = []
        t0 = time.perf_counter()

        def record(device: str, kind: str, start: float, end: float,
                   chunk: int = 0) -> None:
            with lock:
                events.append(BusEvent(device, kind, start, end,
                                       self._ticket_link.get((device, kind)),
                                       chunk))

        def stage(device: str, kind: str, fn: Callable[[], None],
                  on_bus: bool) -> None:
            ticket = (device, kind)
            bus = self._bus_for(ticket) if on_bus else None
            if bus is not None:
                bus.acquire(ticket)
            start = time.perf_counter() - t0
            try:
                fn()
            finally:
                # stamp the end BEFORE releasing the bus: the next holder may
                # start immediately, and measured bus events must not overlap
                end = time.perf_counter() - t0
                if bus is not None:
                    bus.release(ticket)
            record(device, kind, start, end)

        def run_pipelined(task: DeviceTask) -> None:
            """Stream the chunked stages exactly as the engine prices them:
            the copy feeder holds the copy_in ticket across its chunks (the
            engine schedules them contiguously on the link) while the
            consumer thread computes chunk j as soon as it lands, and the
            output loop copies chunk j out as soon as chunk j is computed —
            overlapping the remaining compute chunks, like the engine's
            ``max(link_clock, compute_chunk_end)`` out-chunk starts."""
            dev = task.device
            in_chunks = list(task.copy_in_chunks or ())
            comp_chunks = list(task.compute_chunks or ())
            out_chunks = list(task.copy_out_chunks or ())
            landed = threading.Semaphore(0)     # input chunk j copied
            computed = threading.Semaphore(0)   # compute chunk j finished
            aborted = threading.Event()
            consumer_errs: list[BaseException] = []

            def consume() -> None:
                try:
                    for j, fn in enumerate(comp_chunks):
                        if in_chunks:
                            landed.acquire()
                            if aborted.is_set():
                                return
                        start = time.perf_counter() - t0
                        fn()
                        record(dev, "compute", start,
                               time.perf_counter() - t0, chunk=j)
                        computed.release()
                except BaseException as exc:
                    consumer_errs.append(exc)
                finally:
                    # on early exit, unblock an output loop waiting on
                    # chunks that will never be computed (it re-checks
                    # consumer_errs / aborted after each acquire)
                    for _ in out_chunks:
                        computed.release()

            consumer = threading.Thread(target=consume, daemon=True)
            if in_chunks:
                ticket = (dev, "copy_in")
                bus = self._bus_for(ticket)
                bus.acquire(ticket)
                consumer.start()
                try:
                    for j, fn in enumerate(in_chunks):
                        start = time.perf_counter() - t0
                        fn()
                        record(dev, "copy_in", start,
                               time.perf_counter() - t0, chunk=j)
                        landed.release()
                except BaseException:
                    # unblock the consumer before surfacing the error
                    aborted.set()
                    landed.release()
                    raise
                finally:
                    bus.release(ticket)
            else:
                consumer.start()
            if out_chunks:
                ticket = (dev, "copy_out")
                bus = self._bus_for(ticket)
                bus.acquire(ticket)
                try:
                    for j, fn in enumerate(out_chunks):
                        computed.acquire()   # chunk j's matmul is done
                        if consumer_errs or aborted.is_set():
                            break
                        start = time.perf_counter() - t0
                        fn()
                        record(dev, "copy_out", start,
                               time.perf_counter() - t0, chunk=j)
                finally:
                    bus.release(ticket)
            consumer.join()
            if consumer_errs:
                raise consumer_errs[0]

        def worker(task: DeviceTask) -> None:
            try:
                if task.pipelined:
                    run_pipelined(task)
                    return
                if task.copy_in is not None:
                    stage(task.device, "copy_in", task.copy_in, on_bus=True)
                stage(task.device, "compute", task.compute, on_bus=False)
                if task.copy_out is not None:
                    stage(task.device, "copy_out", task.copy_out, on_bus=True)
            except BaseException as exc:  # surfaced after join
                for bus in self._buses.values():
                    bus.cancel_device(task.device)
                with lock:
                    errors.append(exc)

        threads = [threading.Thread(target=worker, args=(t,), daemon=True)
                   for t in tasks]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if errors:
            raise errors[0]
        return Timeline(sorted(events, key=lambda e: (e.start, e.end)))
