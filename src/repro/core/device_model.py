"""Device performance models — the substrate of the POAS *Predict* phase.

The paper models each device's GEMM execution time as a *linear* function of
the operation count ``ops = m*n*k`` (paper §4.1.1), plus a bandwidth-based
copy-time model (paper Eq. 4).  We keep exactly that structure, generalized so
the same machinery drives both the paper's CPU/GPU/XPU case study and the
TPU device-group scheduling used by the distributed runtime.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

# ---------------------------------------------------------------------------
# Time models
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LinearTimeModel:
    """t(ops) = a*ops + b   (seconds).  Paper §4.2.1: ``t_cx = a*c_x + b``."""

    a: float  # seconds per op (one op = one multiply-accumulate)
    b: float = 0.0  # fixed overhead in seconds

    def __call__(self, ops: float) -> float:
        return self.a * float(ops) + self.b

    def inverse(self, t: float) -> float:
        """Largest op count finishing within time ``t`` (0 if none)."""
        if t <= self.b:
            return 0.0
        return (t - self.b) / self.a


@dataclasses.dataclass(frozen=True)
class RooflineTimeModel:
    """TPU-native predictor: t = max(flops/peak, bytes/bw) + overhead.

    Used when a device group's cost comes from XLA ``cost_analysis`` rather
    than profiled regression.  ``bytes_per_op`` converts an op count into HBM
    traffic so the same ``ops``-denominated interface works.
    """

    peak_ops_per_s: float  # MAC ops/s (peak_flops/2)
    hbm_bytes_per_s: float
    bytes_per_op: float = 0.0
    overhead_s: float = 0.0

    def __call__(self, ops: float) -> float:
        ops = float(ops)
        t_compute = ops / self.peak_ops_per_s
        t_memory = ops * self.bytes_per_op / self.hbm_bytes_per_s
        return max(t_compute, t_memory) + self.overhead_s

    def inverse(self, t: float) -> float:
        if t <= self.overhead_s:
            return 0.0
        sec_per_op = max(
            1.0 / self.peak_ops_per_s,
            self.bytes_per_op / self.hbm_bytes_per_s,
        )
        return (t - self.overhead_s) / sec_per_op


TimeModel = LinearTimeModel | RooflineTimeModel


# ---------------------------------------------------------------------------
# Copy model (paper Eq. 4)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CopyModel:
    """Host<->device transfer time for a GEMM slice.

    Paper Eq. 4:  y_x = (dt_x * (c_x*(1/k + 1/n) + k*n)) / bw_x

    A device computing ``c`` ops of an (m,n,k) GEMM holds an A slice of
    ``c/n`` elements (m_x*k), the full B (k*n elements) and produces a C slice
    of ``c/k`` elements (m_x*n).  (We multiply the ``k*n`` term by the dtype
    size as well; the paper's rendering omits it, which is dimensionally
    inconsistent and clearly a typo.)
    """

    bandwidth_bytes_per_s: float
    dtype_size: int = 4
    latency_s: float = 0.0  # paper neglects latency; kept for completeness

    def in_bytes(self, c: float, n: int, k: int) -> float:
        """Bytes moved host->device (A slice + full B)."""
        return self.dtype_size * (c / n + float(k) * n)

    def out_bytes(self, c: float, n: int, k: int) -> float:
        """Bytes moved device->host (C slice)."""
        return self.dtype_size * (c / k)

    def total_bytes(self, c: float, n: int, k: int) -> float:
        return self.in_bytes(c, n, k) + self.out_bytes(c, n, k)

    def __call__(self, c: float, n: int, k: int) -> float:
        if math.isinf(self.bandwidth_bytes_per_s):
            return 0.0
        return self.total_bytes(c, n, k) / self.bandwidth_bytes_per_s + self.latency_s

    def in_time(self, c: float, n: int, k: int) -> float:
        if math.isinf(self.bandwidth_bytes_per_s):
            return 0.0
        return self.in_bytes(c, n, k) / self.bandwidth_bytes_per_s + self.latency_s

    def out_time(self, c: float, n: int, k: int) -> float:
        if math.isinf(self.bandwidth_bytes_per_s):
            return 0.0
        return self.out_bytes(c, n, k) / self.bandwidth_bytes_per_s


NO_COPY = CopyModel(bandwidth_bytes_per_s=math.inf, dtype_size=0)


# ---------------------------------------------------------------------------
# Device profile
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """Everything POAS needs to know about one schedulable compute element.

    For the paper's case study a "device" is a CPU / GPU / XPU; for the
    distributed runtime it is a TPU pod-slice (device group).
    """

    name: str
    kind: str  # "cpu" | "gpu" | "xpu" | "tpu-group"
    compute: TimeModel
    copy: CopyModel = NO_COPY
    # Hardware adjustment constraints (paper §4.3.2):
    align_m: int = 1  # row-count granularity (tensor cores: 8; MXU: 8*128 grain)
    align_k: int = 1
    cache_bytes: float = math.inf  # CPU LLC / TPU VMEM working-set bound
    # Chunked pipelined copies (core.bus): split the input copy into C
    # chunks so compute on chunk 1 overlaps the transfer of chunk 2.  The
    # GEMM adapt phase maps this to row-chunks; 1 = unpipelined (paper).
    pipeline_chunks: int = 1
    # Power model (POAS §6 names energy-aware scheduling as future work;
    # Hill & Reddi's ALP viewpoint makes joules half the pitch).  A device
    # burns ``idle_watts`` whenever the schedule holds it idle and
    # ``joules_per_op`` for every MAC it executes; both default to 0 so
    # pre-power profiles (and pure-makespan solves) are unchanged.
    idle_watts: float = 0.0
    joules_per_op: float = 0.0

    def total_time(self, c: float, n: int, k: int) -> float:
        """Compute + (non-serialized) copy time for ``c`` ops — paper Eq. 1 term."""
        return self.compute(c) + self.copy(c, n, k)

    def with_power(self, idle_watts: float,
                   joules_per_op: float) -> "DeviceProfile":
        return dataclasses.replace(self, idle_watts=idle_watts,
                                   joules_per_op=joules_per_op)

    @property
    def effective_speed(self) -> float:
        """ops/s ignoring copies — used for priority ordering (paper §4.4)."""
        t1 = self.compute(1e12) - self.compute(0.0)
        return 1e12 / t1 if t1 > 0 else math.inf


def priority_order(devices: Sequence[DeviceProfile]) -> list[int]:
    """Paper §4.4: the faster the device, the higher the bus priority."""
    return sorted(range(len(devices)), key=lambda i: -devices[i].effective_speed)


def with_pipeline(devices: Sequence[DeviceProfile],
                  chunks: int) -> list[DeviceProfile]:
    """Copies of ``devices`` with ``pipeline_chunks`` set on every device
    that actually copies (no-copy devices gain nothing from chunking and
    would only pay the per-chunk launch overhead)."""
    return [dataclasses.replace(d, pipeline_chunks=max(1, int(chunks)))
            if not math.isinf(d.copy.bandwidth_bytes_per_s) else d
            for d in devices]


# ---------------------------------------------------------------------------
# Reference profiles
# ---------------------------------------------------------------------------

def _linear_from_tflops(eff_tflops: float, overhead_s: float = 1e-4) -> LinearTimeModel:
    """Effective sustained TFLOP/s -> seconds-per-MAC linear model.

    One op (MAC) = 2 FLOPs.
    """
    ops_per_s = eff_tflops * 1e12 / 2.0
    return LinearTimeModel(a=1.0 / ops_per_s, b=overhead_s)


def paper_mach1() -> list[DeviceProfile]:
    """Simulated profiles for the paper's mach1 (Xeon E5-2603v3 + 2×2080 Ti).

    Effective (not peak) throughputs calibrated so the optimized work split
    reproduces the paper's Table 6 (~0.3 % CPU / ~22 % GPU / ~78 % XPU) and
    Table 7 speedups (1.14–1.28× vs XPU alone).
    """
    pcie3 = 15.75e9
    return [
        DeviceProfile("xeon-e5", "cpu", _linear_from_tflops(0.28), NO_COPY,
                      align_m=1, cache_bytes=15e6),
        DeviceProfile("2080ti-cuda", "gpu", _linear_from_tflops(12.5),
                      CopyModel(pcie3, dtype_size=4)),
        DeviceProfile("2080ti-tensor", "xpu", _linear_from_tflops(48.0),
                      CopyModel(pcie3, dtype_size=2), align_m=8, align_k=8),
    ]


def paper_mach2() -> list[DeviceProfile]:
    """Simulated profiles for the paper's mach2 (EPYC 7413 + 3090 + 2080 Ti).

    Note the paper's quirk: on mach2 the *GPU* is the 3090 (PCIe 4.0,
    31.5 GB/s) while the *XPU* is the 2080 Ti's tensor cores (PCIe 3.0).
    """
    pcie3, pcie4 = 15.75e9, 31.5e9
    return [
        DeviceProfile("epyc-7413", "cpu", _linear_from_tflops(2.4), NO_COPY,
                      align_m=1, cache_bytes=128e6),
        DeviceProfile("3090-cuda", "gpu", _linear_from_tflops(30.0),
                      CopyModel(pcie4, dtype_size=4)),
        DeviceProfile("2080ti-tensor", "xpu", _linear_from_tflops(75.0),
                      CopyModel(pcie3, dtype_size=2), align_m=8, align_k=8),
    ]


# TPU v5e-class constants (per chip), used by the distributed runtime and the
# roofline analysis.  197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
TPU_PEAK_FLOPS = 197e12
TPU_HBM_BW = 819e9
TPU_ICI_BW = 50e9
TPU_VMEM_BYTES = 128 * 1024 * 1024  # ~128 MiB VMEM per v5e core


def tpu_group(name: str, chips: int, *, derate: float = 1.0,
              feed_bw: float = TPU_ICI_BW, overhead_s: float = 5e-5) -> DeviceProfile:
    """A pod-slice of ``chips`` TPU chips as one schedulable POAS device.

    ``derate`` < 1 models stragglers / older generations / thermal throttle.
    """
    peak_ops = chips * TPU_PEAK_FLOPS * derate / 2.0
    return DeviceProfile(
        name, "tpu-group",
        RooflineTimeModel(peak_ops_per_s=peak_ops,
                          hbm_bytes_per_s=chips * TPU_HBM_BW * derate,
                          bytes_per_op=0.0, overhead_s=overhead_s),
        CopyModel(feed_bw * chips, dtype_size=2),
        align_m=8, align_k=128,
        cache_bytes=TPU_VMEM_BYTES,
    )
