"""First-class POAS domains — the paper's "generic model" made concrete.

POAS (§3, Fig. 1) is not a scheduler for one application: binding the four
phases — Predict, Optimize, Adapt, Schedule — to a domain's cost structure
produces a DS-POAS (domain-specific POAS).  This module defines that binding
point as a protocol, a process-wide registry of domain factories, and the
``PlanCache`` that memoizes solved plans across repeated ``plan()`` calls.

Four domains ship with the repo (see DESIGN.md §3, §10):

* ``gemm``             — heterogeneous GEMM (``core.framework.GemmDomain``)
* ``serving-dispatch`` — request-batch dispatch across model replicas
                         (``serving.engine.ServingDispatchDomain``)
* ``train-step``       — heterogeneous data-parallel batch split
                         (``distributed.hetero.TrainStepDomain``)
* ``task-graph``       — precedence-constrained DAGs, list-scheduled
                         (``core.graph.TaskGraphDomain``)
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Any, Callable, Hashable, Protocol, Sequence, runtime_checkable

from .device_model import DeviceProfile
from .optimize import OptimizeResult
from .schedule import Schedule


@runtime_checkable
class Workload(Protocol):
    """Anything with a total op count; domains add their own geometry."""

    def total_ops(self) -> float: ...


@runtime_checkable
class Domain(Protocol):
    """The four POAS phases plus a cost signature for plan caching.

    ``predict``  returns the current device models (phase 1 — for dynamic
                 domains these are the re-fitted models);
    ``optimize`` splits the workload's ops across devices (phase 2);
    ``adapt``    maps op counts back to domain coordinates — GEMM rows,
                 request buckets, batch shards (phase 3);
    ``schedule`` produces the executable priority/bus timeline (phase 4);
    ``cost_signature`` is a hashable key of everything about the *workload*
                 that the solved plan depends on (device models are keyed
                 separately by the cache).

    Streaming conventions (DESIGN.md §9) — all shipped domains follow them:

    * a dynamic domain exposes its ``DynamicScheduler`` as ``self.dyn``
      (``None`` or absent = static).  ``POAS`` hooks the ``PlanCache``
      invalidation to its re-fits, and ``CoExecutionRuntime`` pumps
      measured timelines into it;
    * ``schedule`` fills ``Schedule.spec`` (a ``TimelineSpec``) so the
      runtime can rebase the plan onto carried-over clocks — or re-price
      it under ground-truth models — without knowing domain geometry.
    """

    name: str

    def predict(self) -> Sequence[DeviceProfile]: ...

    def optimize(self, devices: Sequence[DeviceProfile],
                 workload: Workload) -> OptimizeResult: ...

    def adapt(self, devices: Sequence[DeviceProfile], opt: OptimizeResult,
              workload: Workload) -> Any: ...

    def schedule(self, devices: Sequence[DeviceProfile], adapted: Any,
                 workload: Workload) -> Schedule: ...

    def cost_signature(self, workload: Workload) -> Hashable: ...


# ---------------------------------------------------------------------------
# Tenant policy (multi-tenant runtime, DESIGN.md §13)
# ---------------------------------------------------------------------------


TIER_LATENCY = 0
TIER_BATCH = 1


@dataclasses.dataclass(frozen=True)
class QoS:
    """What a tenant is *entitled to* — the domain-agnostic service policy
    the multi-tenant runtime schedules by (DESIGN.md §13).

    ``weight``      — weighted-fair share within a tier (2.0 = twice the
                      admission bandwidth of a weight-1.0 tenant);
    ``tier``        — strict priority class: every ``TIER_LATENCY`` job is
                      admitted before any eligible ``TIER_BATCH`` job, and
                      may preempt a batch job's not-yet-started frontier;
    ``deadline_s``  — default relative deadline per job (None = best
                      effort).  At admission the runtime prices the job's
                      predicted completion on the carried clocks via the
                      engine; an infeasible deadline is rejected before a
                      single ticket is issued.
    """

    weight: float = 1.0
    tier: int = TIER_BATCH
    deadline_s: float | None = None

    def __post_init__(self) -> None:
        if self.weight <= 0.0:
            raise ValueError(f"QoS weight must be > 0, got {self.weight}")
        if self.deadline_s is not None and self.deadline_s <= 0.0:
            raise ValueError("QoS deadline_s must be > 0 when set")


@dataclasses.dataclass
class FunctionDomain:
    """Adapter: four loose callables as a ``Domain`` (legacy construction)."""

    name: str
    predict_fn: Callable[[], Sequence[DeviceProfile]]
    optimize_fn: Callable[..., OptimizeResult]
    adapt_fn: Callable[..., Any]
    schedule_fn: Callable[..., Schedule]

    def predict(self) -> Sequence[DeviceProfile]:
        return self.predict_fn()

    def optimize(self, devices, workload):
        return self.optimize_fn(devices, workload)

    def adapt(self, devices, opt, workload):
        return self.adapt_fn(devices, opt, workload)

    def schedule(self, devices, adapted, workload):
        return self.schedule_fn(devices, adapted, workload)

    def cost_signature(self, workload) -> Hashable:
        # Loose callables carry no geometry contract: a fresh token per call
        # means a cache can never serve a stale plan (it just never hits).
        return (self.name, object())


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Callable[..., Domain]] = {}


def register_domain(name: str) -> Callable[[Callable[..., Domain]],
                                           Callable[..., Domain]]:
    """Class decorator: ``@register_domain("gemm")`` above a Domain class."""

    def deco(factory: Callable[..., Domain]) -> Callable[..., Domain]:
        _REGISTRY[name] = factory
        return factory

    return deco


def get_domain(name: str, *args, **kwargs) -> Domain:
    """Instantiate a registered domain by name."""
    _ensure_builtin_domains()
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown POAS domain {name!r}; "
                       f"registered: {sorted(_REGISTRY)}") from None
    return factory(*args, **kwargs)


def list_domains() -> list[str]:
    _ensure_builtin_domains()
    return sorted(_REGISTRY)


def _ensure_builtin_domains() -> None:
    """Import the modules that register the shipped domains (idempotent)."""
    from . import framework  # noqa: F401  (registers "gemm")
    from . import graph      # noqa: F401  (registers "task-graph")
    try:
        from ..serving import engine  # noqa: F401  ("serving-dispatch")
    except ImportError:  # pragma: no cover - serving needs jax models
        pass
    try:
        from ..distributed import hetero  # noqa: F401  ("train-step")
    except ImportError:  # pragma: no cover
        pass


# ---------------------------------------------------------------------------
# Plan cache
# ---------------------------------------------------------------------------


def device_signature(devices: Sequence[DeviceProfile]) -> Hashable:
    """Hashable fingerprint of the device *models* a plan was solved under.

    DeviceProfile and both time models are frozen dataclasses, so the tuple
    hashes by value: any model re-fit (DynamicScheduler) changes the key.
    """
    return tuple(devices)


class PlanCache:
    """LRU memo for solved POAS plans.

    Keyed on ``(domain name, workload cost signature, device-model
    signature)``: repeated ``plan()`` calls for the same geometry under the
    same predicted models skip the MILP/bisection solve entirely.  A
    ``DynamicScheduler`` re-fit changes the device signature *and* fires the
    registered invalidation hook, so stale entries can neither be served nor
    accumulate.

    Thread-safe: ``PoasDispatcher.split`` / ``HGemms.plan`` may be called
    concurrently from executor threads, and an ``OrderedDict`` being
    reordered by ``move_to_end`` while another thread iterates or pops is
    not — every access holds the lock (the critical sections are tiny
    relative to a solve, so contention is negligible).
    """

    def __init__(self, maxsize: int = 256):
        self.maxsize = maxsize
        self._entries: OrderedDict[Hashable, Any] = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.invalidations = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def key(self, domain: Domain, devices: Sequence[DeviceProfile],
            workload: Workload) -> Hashable:
        return (domain.name, domain.cost_signature(workload),
                device_signature(devices))

    def get(self, key: Hashable) -> Any | None:
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                self.misses += 1
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            return entry

    def put(self, key: Hashable, plan: Any) -> None:
        with self._lock:
            self._entries[key] = plan
            self._entries.move_to_end(key)
            while len(self._entries) > self.maxsize:
                self._entries.popitem(last=False)

    def invalidate(self) -> None:
        """Drop every entry (called on model re-fits)."""
        with self._lock:
            if self._entries:
                self.invalidations += 1
            self._entries.clear()

    def stats(self) -> dict[str, int]:
        with self._lock:
            return {"size": len(self._entries), "hits": self.hits,
                    "misses": self.misses, "invalidations": self.invalidations}
