"""The shared-bus timeline engine — ONE source of truth for solve/simulate/execute.

The paper's co-execution speedup lives on the Fig. 2 timeline: input copies
serialized on the host bus in priority order, compute overlapping other
devices' copies, output copies serialized after compute.  Historically the
repo carried three independent implementations of that timeline (the
optimizer's finish-time model, ``simulate_timeline``, and the overlapped
executor's bus order) which measurably disagreed; this module replaces all
of them with a single event-graph builder (DESIGN.md §4).

Two generalizations over the paper:

* ``BusTopology`` — named serialization ``Link``s with optional bandwidth
  caps; each device maps its copy_in/copy_out to a link (or to none — the
  host CPU computes in place).  The paper's single serialized PCIe bus,
  fully independent per-device links, and mixed topologies (CPU no-copy +
  two GPUs sharing PCIe + a TPU group on its own ICI feed) are all
  instances of the same engine.
* **Chunked pipelined copies** — a device with ``pipeline_chunks = C > 1``
  splits the per-op part of its input copy into C chunks so compute on
  chunk 1 overlaps the transfer of chunk 2 (the overlap the paper leaves as
  future work).  The shared operand (the full B panel for GEMM — the
  c-independent part of the copy) still lands before the first compute
  chunk; per-chunk launch overhead is charged by evaluating the compute
  model at ``c/C`` per chunk and paying the copy launch latency once per
  transfer, so over-chunking is priced, not free.
  Chunks are priced equal-sized; the adapt phase's grain-rounded
  ``chunk_rows`` are near-equal, and callers pass the *adapted* chunk
  count (``len(chunk_rows)``) so a device capped below its nominal
  ``pipeline_chunks`` by the alignment grain is never charged for chunks
  that don't exist.

``build_timeline`` emits the event graph; ``engine_finish_times`` runs the
same control flow without materializing events (the optimizer's feasibility
check calls it thousands of times per solve).

A third generalization backs the streaming runtime (DESIGN.md §9): a
timeline may start from **carried-over clocks** (``ClockState``) instead of
t = 0, so plan k+1's input copies queue behind plan k's tail on each link
while its devices wait only for their *own* previous work — back-to-back
plans overlap exactly the way a single plan's devices do.

A fourth generalization backs task-graph workloads (DESIGN.md §10): the
same clocks also price **precedence-constrained DAGs**
(``build_graph_timeline`` / ``graph_finish_times``), where an event may
depend on another event's finish, not just its device/link clock — a
cross-device dependency edge becomes link copies (producer staged to host
once, each consumer reading over its own in-link), a same-device edge is
free.  Events carry the owning task's name, so the executor's per-link
ticket order, the invariant checks, and the per-task observation pump all
read the one engine.
"""
from __future__ import annotations

import bisect
import dataclasses
import heapq
import math
from typing import Iterable, Mapping, Sequence

import numpy as np

from .device_model import DeviceProfile, LinearTimeModel, priority_order


# ---------------------------------------------------------------------------
# Events and timelines (moved here from core.schedule; re-exported there)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BusEvent:
    device: str
    kind: str       # "copy_in" | "compute" | "copy_out"
    start: float
    end: float
    link: str | None = None   # serialization link the event occupied
    chunk: int = 0            # pipeline chunk index (0 when unchunked)
    # Task-graph timelines attribute every event to a named task (None for
    # the divisible-workload engine, where a device runs exactly one unit).
    task: str | None = None

    @property
    def duration(self) -> float:
        return self.end - self.start


@dataclasses.dataclass
class Timeline:
    events: list[BusEvent]

    @property
    def makespan(self) -> float:
        return max((e.end for e in self.events), default=0.0)

    def device_events(self, name: str) -> list[BusEvent]:
        return [e for e in self.events if e.device == name]

    def device_finish(self, name: str) -> float:
        """When the device's last stage (usually copy_out) ends; 0 if idle."""
        return max((e.end for e in self.device_events(name)), default=0.0)

    def idle_time(self, name: str) -> float:
        evs = sorted(self.device_events(name), key=lambda e: e.start)
        if not evs:
            return self.makespan
        idle = evs[0].start
        for a, b in zip(evs, evs[1:]):
            idle += max(0.0, b.start - a.end)
        idle += self.makespan - evs[-1].end
        return idle

    def bus_busy_time(self) -> float:
        return sum(e.duration for e in self.events
                   if e.kind in ("copy_in", "copy_out"))

    def link_events(self, link: str) -> list[BusEvent]:
        return sorted((e for e in self.events if e.link == link),
                      key=lambda e: (e.start, e.end))

    def task_events(self, task: str) -> list[BusEvent]:
        return [e for e in self.events if e.task == task]

    def _copy_tickets(self) -> list[tuple[str, tuple]]:
        """(link, ticket) in grant order: copy events sorted by start
        (ties: copy_in before copy_out, then chunk), chunk/multi-input
        events collapsed to one ticket per stage.  Tickets are
        ``(device, kind)`` for divisible timelines and
        ``(task, device, kind)`` for task-graph timelines (a device runs
        many tasks, each with its own grant slot)."""
        out: list[tuple[str, tuple]] = []
        seen: set[tuple] = set()
        copies = sorted((e for e in self.events if e.kind != "compute"),
                        key=lambda e: (e.start, 0 if e.kind == "copy_in"
                                       else 1, e.chunk))
        for e in copies:
            ticket = (e.device, e.kind) if e.task is None \
                else (e.task, e.device, e.kind)
            if ticket in seen:
                continue
            seen.add(ticket)
            out.append((e.link or "bus", ticket))
        return out

    def link_ticket_order(self) -> dict[str, list[tuple]]:
        """Per-link grant order of tickets — this is what the overlapped
        executor's per-link ticket buses replay."""
        out: dict[str, list[tuple]] = {}
        for link, ticket in self._copy_tickets():
            out.setdefault(link, []).append(ticket)
        return out

    def ticket_order(self) -> list[tuple]:
        """Flat grant order across all links (per-link truth above)."""
        return [ticket for _, ticket in self._copy_tickets()]


# ---------------------------------------------------------------------------
# Carried-over clocks (streaming runtime, DESIGN.md §9)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ClockState:
    """Where each link and device clock stands when a timeline starts.

    ``links`` / ``devices`` map names to absolute times; anything absent
    falls back to ``floor``.  ``ClockState()`` is the classic t = 0 start;
    ``ClockState(floor=t)`` is a full barrier at ``t`` (what a runtime with
    plan-carry-over disabled uses between plans); ``carry_clocks(timeline)``
    is the overlapping hand-off — each link and device resumes exactly where
    the previous plan left it.
    """

    links: Mapping[str, float] = dataclasses.field(default_factory=dict)
    devices: Mapping[str, float] = dataclasses.field(default_factory=dict)
    floor: float = 0.0

    def link(self, name: str) -> float:
        return max(self.links.get(name, self.floor), self.floor)

    def device(self, name: str) -> float:
        return max(self.devices.get(name, self.floor), self.floor)

    # -- multi-tenant views (DESIGN.md §13) ---------------------------------

    def with_floor(self, t: float) -> "ClockState":
        """The same clocks with nothing allowed to start before ``t`` — an
        arrival gate: a job admitted at ``t`` cannot occupy a link or device
        in its past, even ones the stream has not touched yet."""
        if t <= self.floor:
            return self
        return ClockState(links=self.links, devices=self.devices, floor=t)

    def restrict(self, links: "Iterable[str]",
                 devices: "Iterable[str]") -> "ClockState":
        """A tenant's view of the shared clocks: only the named links and
        devices (the ones its ``BusTopology`` can reach), same floor.  Keeps
        one tenant's private link names from leaking into another tenant's
        rebase while the SHARED names (the contended PCIe bus, the common
        accelerators) still carry across tenants."""
        lset, dset = set(links), set(devices)
        return ClockState(
            links={k: v for k, v in self.links.items() if k in lset},
            devices={k: v for k, v in self.devices.items() if k in dset},
            floor=self.floor)

    def merge(self, other: "ClockState") -> "ClockState":
        """Max-merge two clock states (same algebra as ``carry_clocks``):
        every link/device takes the later of the two clocks, the floor the
        higher of the two floors."""
        links = dict(self.links)
        for k, v in other.links.items():
            links[k] = max(links.get(k, other.floor), v)
        devices = dict(self.devices)
        for k, v in other.devices.items():
            devices[k] = max(devices.get(k, other.floor), v)
        return ClockState(links=links, devices=devices,
                          floor=max(self.floor, other.floor))


ZERO_CLOCKS = ClockState()


def carry_clocks(timeline: Timeline,
                 base: ClockState = ZERO_CLOCKS) -> ClockState:
    """The ``ClockState`` a follow-on plan should start from: each link's
    clock is its last transfer's end, each device's clock its last event's
    end (so the next plan's copies overlap this plan's tail but a device
    never runs two plans' stages at once).

    ``base`` is the state this timeline itself started from; clocks are
    max-merged into it, because a plan that never touched a link (or left a
    device idle) must not rewind that clock — e.g. an all-CPU job between
    two GPU jobs would otherwise reset the PCIe clock to zero and let the
    next plan's copies time-travel under the earlier plan's transfers."""
    links = dict(base.links)
    devices = dict(base.devices)
    for e in timeline.events:
        if e.link is not None:
            links[e.link] = max(links.get(e.link, base.floor), e.end)
        devices[e.device] = max(devices.get(e.device, base.floor), e.end)
    return ClockState(links=links, devices=devices, floor=base.floor)


# ---------------------------------------------------------------------------
# Links and topologies
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Link:
    """One serialization domain (PCIe bus, NVLink, an ICI feed...).

    ``bandwidth_bytes_per_s = None`` means the link never caps a device —
    copy times come from the device's own ``CopyModel``.  A finite value
    caps the effective bandwidth at ``min(device bw, link bw)``.
    """

    name: str
    bandwidth_bytes_per_s: float | None = None


def _has_copy(d: DeviceProfile) -> bool:
    return not math.isinf(d.copy.bandwidth_bytes_per_s)


@dataclasses.dataclass(frozen=True)
class BusTopology:
    """Which link (if any) each device's copy_in / copy_out serializes on.

    ``attach`` rows are ``(device_name, in_link, out_link)``; ``None`` link
    means the stage does not serialize with anything (no-copy devices).  A
    device with a copy model but no attach row gets an implicit private
    link (the independent-bus behaviour).
    """

    links: tuple[Link, ...]
    attach: tuple[tuple[str, str | None, str | None], ...]
    spec: str = "custom"   # short tag carried into OptimizeResult.bus
    # hierarchical (multi-host) extension: ``hosts`` groups device names
    # into host islands; a DAG edge whose producer and consumer live on
    # different hosts pays an extra NIC hop (``nic`` bandwidth cap plus
    # ``nic_latency_s``) between the producer's host-stage and the
    # consumer's copy_in.  Empty ``hosts`` means a flat (single-host)
    # topology and the engine takes the exact pre-existing code path.
    hosts: tuple[tuple[str, tuple[str, ...]], ...] = ()
    nic: Link | None = None
    nic_latency_s: float = 0.0

    def __post_init__(self) -> None:
        by_name = {l.name: l for l in self.links}
        in_map: dict[str, Link | None] = {}
        out_map: dict[str, Link | None] = {}
        for dev, lin, lout in self.attach:
            for l in (lin, lout):
                if l is not None and l not in by_name:
                    raise ValueError(f"device {dev!r} attached to unknown "
                                     f"link {l!r}; links: "
                                     f"{sorted(by_name)}")
            in_map[dev] = by_name[lin] if lin is not None else None
            out_map[dev] = by_name[lout] if lout is not None else None
        # resolved lookup tables (the engine queries these in the solver's
        # feasibility hot path; frozen dataclass, so set via object.*)
        object.__setattr__(self, "_in_map", in_map)
        object.__setattr__(self, "_out_map", out_map)
        host_of: dict[str, int] = {}
        for hi, (_hname, members) in enumerate(self.hosts):
            for dev in members:
                if dev in host_of:
                    raise ValueError(f"device {dev!r} listed under two "
                                     "hosts")
                host_of[dev] = hi
        object.__setattr__(self, "_host_of", host_of)

    # -- construction -------------------------------------------------------

    @classmethod
    def serialized(cls, devices: Sequence[DeviceProfile], *,
                   link: Link | str = "pcie") -> "BusTopology":
        """The paper's model: every copying device on one shared bus."""
        lk = Link(link) if isinstance(link, str) else link
        attach = tuple((d.name, lk.name, lk.name) if _has_copy(d)
                       else (d.name, None, None) for d in devices)
        return cls(links=(lk,), attach=attach, spec="serialized")

    @classmethod
    def independent(cls, devices: Sequence[DeviceProfile], *,
                    prefix: str = "link") -> "BusTopology":
        """Each copying device on its own private link (no contention)."""
        links: list[Link] = []
        attach: list[tuple[str, str | None, str | None]] = []
        for d in devices:
            if _has_copy(d):
                lk = Link(f"{prefix}:{d.name}")
                links.append(lk)
                attach.append((d.name, lk.name, lk.name))
            else:
                attach.append((d.name, None, None))
        return cls(links=tuple(links), attach=tuple(attach),
                   spec="independent")

    @classmethod
    def custom(cls, links: Sequence[Link | str],
               attach: Mapping[str, str | tuple[str | None, str | None] | None],
               *, spec: str = "custom") -> "BusTopology":
        """Mixed topologies: ``attach`` maps device name -> link name (both
        directions), ``(in_link, out_link)``, or ``None`` (no link)."""
        lks = tuple(Link(l) if isinstance(l, str) else l for l in links)
        rows: list[tuple[str, str | None, str | None]] = []
        for dev, spec_l in attach.items():
            if spec_l is None:
                rows.append((dev, None, None))
            elif isinstance(spec_l, str):
                rows.append((dev, spec_l, spec_l))
            else:
                rows.append((dev, spec_l[0], spec_l[1]))
        return cls(links=lks, attach=tuple(rows), spec=spec)

    @classmethod
    def cluster(cls, hosts: Mapping[str, Sequence[DeviceProfile]], *,
                nic_bandwidth_bytes_per_s: float,
                nic_latency_s: float = 0.0,
                bus: str = "pcie") -> "BusTopology":
        """Multi-host stack: each host gets its own internal shared bus
        (``{host}.{bus}``, the paper's serialized model per island) and
        hosts talk through one capped NIC.  Cross-host DAG edges price as
        a two-hop staged copy: producer host-stage -> NIC -> consumer
        copy_in (DESIGN.md §16)."""
        links: list[Link] = []
        attach: list[tuple[str, str | None, str | None]] = []
        groups: list[tuple[str, tuple[str, ...]]] = []
        for hname, devs in hosts.items():
            lk = Link(f"{hname}.{bus}")
            links.append(lk)
            for d in devs:
                attach.append((d.name, lk.name, lk.name) if _has_copy(d)
                              else (d.name, None, None))
            groups.append((hname, tuple(d.name for d in devs)))
        nic = Link("nic", bandwidth_bytes_per_s=nic_bandwidth_bytes_per_s)
        return cls(links=tuple(links), attach=tuple(attach),
                   spec="cluster", hosts=tuple(groups), nic=nic,
                   nic_latency_s=nic_latency_s)

    @classmethod
    def from_spec(cls, bus: "BusTopology | str | None",
                  devices: Sequence[DeviceProfile]) -> "BusTopology":
        """Resolve the legacy ``bus=`` strings (and None) to a topology."""
        if isinstance(bus, BusTopology):
            return bus
        if bus is None or bus == "serialized":
            return cls.serialized(devices)
        if bus == "independent":
            return cls.independent(devices)
        raise ValueError(f"unknown bus spec {bus!r} "
                         "(expected 'serialized', 'independent', or a "
                         "BusTopology)")

    # -- queries ------------------------------------------------------------

    def link(self, name: str) -> Link:
        for l in self.links:
            if l.name == name:
                return l
        raise KeyError(name)

    def link_of(self, device: str, kind: str) -> Link | None:
        """Link serializing ``device``'s ``copy_in``/``copy_out`` (or None).
        Unattached devices return None; the engine gives them a private
        link if they do copy."""
        table = self._in_map if kind in ("in", "copy_in") else self._out_map
        return table.get(device)

    def is_hierarchical(self) -> bool:
        """True when the topology groups devices into host islands."""
        return bool(self.hosts)

    def host_index(self, device: str) -> int | None:
        """Index of the host island holding ``device`` (None when flat or
        the device is not listed under any host)."""
        return self._host_of.get(device)

    def flatten(self) -> "BusTopology":
        """NIC-oblivious view: same links and attach rows, hierarchy
        erased — what a single-host planner would see.  The baseline for
        the cluster-aware placement comparison."""
        if not self.hosts:
            return self
        # distinct spec tag: context caches key on (devices, priority,
        # spec), and the flat view prices differently from the hierarchy
        return dataclasses.replace(self, hosts=(), nic=None,
                                   nic_latency_s=0.0,
                                   spec=self.spec + "-flat")

    def is_contended(self) -> bool:
        """True if any link serializes copies of two or more devices."""
        users: dict[str, set[str]] = {}
        for dev, lin, lout in self.attach:
            for l in (lin, lout):
                if l is not None:
                    users.setdefault(l, set()).add(dev)
        return any(len(v) > 1 for v in users.values())


# ---------------------------------------------------------------------------
# Copy times under a link (device CopyModel capped by link bandwidth)
# ---------------------------------------------------------------------------


def _in_time(d: DeviceProfile, link: Link | None, c: float,
             n: int, k: int) -> float:
    if link is None or link.bandwidth_bytes_per_s is None:
        return d.copy.in_time(c, n, k)   # CopyModel is the source of truth
    bw = min(d.copy.bandwidth_bytes_per_s, link.bandwidth_bytes_per_s)
    if math.isinf(bw):
        return 0.0
    return d.copy.in_bytes(c, n, k) / bw + d.copy.latency_s


def _out_time(d: DeviceProfile, link: Link | None, c: float,
              n: int, k: int) -> float:
    if link is None or link.bandwidth_bytes_per_s is None:
        return d.copy.out_time(c, n, k)  # CopyModel is the source of truth
    bw = min(d.copy.bandwidth_bytes_per_s, link.bandwidth_bytes_per_s)
    if math.isinf(bw):
        return 0.0
    return d.copy.out_bytes(c, n, k) / bw


def _link_bw(d: DeviceProfile, link: Link | None) -> float:
    bw = d.copy.bandwidth_bytes_per_s
    if link is not None and link.bandwidth_bytes_per_s is not None:
        bw = min(bw, link.bandwidth_bytes_per_s)
    return bw


def _bytes_in_time(d: DeviceProfile, link: Link | None, nbytes: float) -> float:
    """Host->device time for raw ``nbytes`` (task-graph copies are byte-
    denominated, not GEMM-shaped) under the device model capped by the link."""
    bw = _link_bw(d, link)
    if nbytes <= 0.0 or math.isinf(bw):
        return 0.0
    return nbytes / bw + d.copy.latency_s


def _bytes_out_time(d: DeviceProfile, link: Link | None, nbytes: float) -> float:
    bw = _link_bw(d, link)
    if nbytes <= 0.0 or math.isinf(bw):
        return 0.0
    return nbytes / bw


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------


def _resolve_chunks(devices: Sequence[DeviceProfile],
                    chunks: Sequence[int] | None) -> list[int]:
    if chunks is None:
        return [max(1, int(getattr(d, "pipeline_chunks", 1)))
                for d in devices]
    return [max(1, int(c)) for c in chunks]


def _simulate(devices: Sequence[DeviceProfile], ops: Sequence[float],
              n: int, k: int, topo: BusTopology, order: Sequence[int],
              chunks: Sequence[int], events: list[BusEvent] | None,
              clocks: ClockState = ZERO_CLOCKS) -> list[float]:
    """One pass over the event graph.  Returns per-device finish times;
    appends ``BusEvent``s when ``events`` is a list (None = fast path).

    Semantics (Fig. 2, per link):
      * input copies serialize on their link in priority order;
      * a device with no input copy time starts computing at t = 0 (the
        solver historically charged it for bus queue time — bug);
      * compute chunk j starts at max(input chunk j landed, chunk j-1 done);
      * output copies serialize on their link in priority order after ALL
        input copies on that link (the link clock carries over — the solver
        historically reset it to 0, letting outputs overlap inputs — bug);
      * output chunk j additionally waits for compute chunk j.

    ``clocks`` shifts the start of the world: each link's first transfer
    begins at its carried clock and each device's first stage begins no
    earlier than its carried clock (a device runs one plan's stages at a
    time — the streaming runtime's per-device workers are sequential), so a
    plan chained after another overlaps its predecessor's tail exactly as
    the Fig. 2 schedule overlaps devices within one plan.
    """
    finish = [0.0] * len(devices)
    free: dict[str, float] = {}           # per-link clock
    chunk_ends: dict[int, list[float]] = {}  # device -> compute chunk ends

    # ---- input copies + compute, devices in priority order
    for i in order:
        d, c = devices[i], float(ops[i])
        if c <= 0.0:
            continue
        C = chunks[i]
        dev0 = clocks.device(d.name)
        link = topo.link_of(d.name, "in")
        t_total = _in_time(d, link, c, n, k)
        t_cc = d.compute(c / C)
        ends: list[float] = []
        if t_total <= 0.0:
            # no-copy device: compute immediately, chunks back to back
            prev = dev0
            for j in range(C):
                if events is not None:
                    events.append(BusEvent(d.name, "compute", prev,
                                           prev + t_cc, None, j))
                prev += t_cc
                ends.append(prev)
        else:
            lname = link.name if link is not None else f"~{d.name}"
            t_shared = _in_time(d, link, 0.0, n, k)  # B panel + latency
            t_chunk = (t_total - t_shared) / C
            # each chunk is a separate transfer: chunks past the first pay
            # the copy launch latency again (chunk 0's is in t_shared)
            lat = d.copy.latency_s
            start = max(free.get(lname, clocks.link(lname)), dev0)
            in_ends: list[float] = []
            for j in range(C):
                dur = t_chunk + (t_shared if j == 0 else lat)
                if events is not None:
                    events.append(BusEvent(d.name, "copy_in", start,
                                           start + dur, lname, j))
                start += dur
                in_ends.append(start)
            free[lname] = start
            prev = dev0
            for j in range(C):
                s = max(in_ends[j], prev)
                if events is not None:
                    events.append(BusEvent(d.name, "compute", s, s + t_cc,
                                           None, j))
                prev = s + t_cc
                ends.append(prev)
        chunk_ends[i] = ends
        finish[i] = ends[-1]

    # ---- output copies, devices in priority order, link clocks carried
    for i in order:
        d, c = devices[i], float(ops[i])
        if c <= 0.0:
            continue
        C = chunks[i]
        link = topo.link_of(d.name, "out")
        t_out = _out_time(d, link, c, n, k)
        if t_out <= 0.0:
            continue
        lname = link.name if link is not None else f"~{d.name}"
        t_chunk = t_out / C
        ends = chunk_ends[i]
        t = free.get(lname, clocks.link(lname))
        for j in range(C):
            s = max(t, ends[j])
            if events is not None:
                events.append(BusEvent(d.name, "copy_out", s, s + t_chunk,
                                       lname, j))
            t = s + t_chunk
        free[lname] = t
        finish[i] = t
    return finish


def build_timeline(devices: Sequence[DeviceProfile], ops: Sequence[float],
                   n: int, k: int, *,
                   topology: BusTopology | str | None = None,
                   order: Sequence[int] | None = None,
                   chunks: Sequence[int] | None = None,
                   clocks: ClockState = ZERO_CLOCKS) -> Timeline:
    """The unified event-graph timeline (what ``simulate_timeline`` returns,
    what the solver's finish times are read from, and what the overlapped
    executor's per-link ticket order is derived from).  ``clocks`` starts
    the timeline from carried-over link/device clocks instead of t = 0
    (streaming runtime)."""
    topo = BusTopology.from_spec(topology, devices)
    if order is None:
        order = priority_order(devices)
    events: list[BusEvent] = []
    _simulate(devices, ops, n, k, topo, order, _resolve_chunks(devices, chunks),
              events, clocks)
    return Timeline(events)


def engine_finish_times(devices: Sequence[DeviceProfile],
                        ops: Sequence[float], n: int, k: int, *,
                        topology: BusTopology | str | None = None,
                        order: Sequence[int] | None = None,
                        chunks: Sequence[int] | None = None,
                        clocks: ClockState = ZERO_CLOCKS) -> list[float]:
    """Per-device finish times from the same control flow as
    ``build_timeline``, without materializing events (solver hot path)."""
    topo = BusTopology.from_spec(topology, devices)
    if order is None:
        order = priority_order(devices)
    return _simulate(devices, ops, n, k, topo, order,
                     _resolve_chunks(devices, chunks), None, clocks)


# ---------------------------------------------------------------------------
# TimelineSpec — everything needed to re-price a planned timeline
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TimelineSpec:
    """The engine inputs a ``Schedule``'s timeline was built from.

    Domains attach this to their ``Schedule`` so a runtime can *rebase* the
    plan — rebuild the identical event graph from carried-over clocks, or
    under different (e.g. ground-truth) device models — without knowing any
    domain geometry.  ``order`` is the planned priority order; replaying a
    plan under substituted models must keep it (the executor's ticket buses
    grant in planned order, not in the substituted models' speed order).
    """

    devices: tuple[DeviceProfile, ...]
    ops: tuple[float, ...]
    n: int
    k: int
    topology: BusTopology
    chunks: tuple[int, ...] | None = None
    order: tuple[int, ...] | None = None

    def rebase(self, clocks: ClockState = ZERO_CLOCKS, *,
               devices: Sequence[DeviceProfile] | None = None) -> Timeline:
        """Rebuild the timeline from ``clocks``; ``devices`` substitutes
        ground-truth profiles (same names/positions) for the planned ones."""
        devs = list(devices) if devices is not None else list(self.devices)
        order = list(self.order) if self.order is not None \
            else priority_order(list(self.devices))
        return build_timeline(devs, list(self.ops), self.n, self.k,
                              topology=self.topology, order=order,
                              chunks=list(self.chunks) if self.chunks else None,
                              clocks=clocks)

    def ops_by_device(self) -> dict[str, float]:
        return {d.name: float(c) for d, c in zip(self.devices, self.ops)}


# ---------------------------------------------------------------------------
# Task-graph engine — precedence-constrained DAGs on the same clocks
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TaskSpec:
    """One DAG task as the engine sees it: an op count plus byte counts.

    ``in_bytes`` is the task's *external* (host-resident) input — weights,
    graph inputs; data produced by upstream tasks travels on the edges and
    is priced from the producer's ``out_bytes``.  ``out_bytes`` is what the
    task emits: it is copied back to host when the task is a sink or feeds
    a consumer on another device (the host-staged transfer of the paper's
    bus model), and read over the consumer's input link per cross-device
    edge."""

    name: str
    ops: float
    in_bytes: float = 0.0
    out_bytes: float = 0.0


def _graph_topo_order(n: int, edges: Sequence[tuple[int, int]]) -> list[int]:
    """Kahn topological order, stable by task index (callers validate
    acyclicity; a cycle here raises).  The ready frontier is a heap: a
    wide DAG (microbatched whole-model stacks keep dozens of chains open
    at once) made the old ``min(ready)`` + ``list.remove`` frontier a
    measurable O(n·width) slice of the 10^4-node hierarchical solve."""
    indeg = [0] * n
    children: list[list[int]] = [[] for _ in range(n)]
    for u, v in edges:
        indeg[v] += 1
        children[u].append(v)
    ready = [i for i in range(n) if indeg[i] == 0]
    heapq.heapify(ready)
    out: list[int] = []
    while ready:
        i = heapq.heappop(ready)
        out.append(i)
        for c in children[i]:
            indeg[c] -= 1
            if indeg[c] == 0:
                heapq.heappush(ready, c)
    if len(out) != n:
        raise ValueError("task graph contains a cycle")
    return out


class GraphSimContext:
    """Immutable per-graph context shared by every ``GraphSimState``.

    Built once per (graph, topology, order, clocks, ext) tuple: adjacency
    in edge-insertion order, each device's resolved in/out link, and the
    positions of the simulated (non-``ext``) tasks in ``order``.  The list
    scheduler builds one of these per solve and extends checkpointed
    ``GraphSimState``s against it instead of re-deriving the lookup tables
    for every candidate placement.
    """

    __slots__ = ("devices", "tasks", "edges", "topo", "order", "clocks",
                 "ext", "n", "parents", "children", "pos_of", "has_copy",
                 "in_link", "in_lname", "out_link", "out_lname", "dev_name",
                 "sim_positions", "link_names", "in_lid", "out_lid",
                 "has_out", "has_in", "ext_in", "par_in", "stage_out",
                 "comp", "host_id", "hier", "nic_dur", "_np", "_ext_seed")

    # every per-graph table that depends only on (devices, tasks, edges,
    # topo, order) — shared, not copied, by ``rebind``
    _SHARED_SLOTS = ("devices", "tasks", "edges", "topo", "order", "n",
                     "parents", "children", "pos_of", "has_copy", "in_link",
                     "in_lname", "out_link", "out_lname", "dev_name",
                     "link_names", "in_lid", "out_lid", "has_out", "has_in",
                     "ext_in", "par_in", "stage_out", "comp", "host_id",
                     "hier", "nic_dur", "_np")

    def __init__(self, devices: Sequence[DeviceProfile],
                 tasks: Sequence[TaskSpec],
                 edges: Sequence[tuple[int, int]],
                 topo: BusTopology, order: Sequence[int],
                 clocks: ClockState = ZERO_CLOCKS,
                 ext: Mapping[int, tuple[float, float]] | None = None):
        self.devices = list(devices)
        self.tasks = list(tasks)
        self.edges = list(edges)
        self.topo = topo
        self.order = list(order)
        self.clocks = clocks
        self.ext = dict(ext) if ext else {}
        n = self.n = len(self.tasks)
        parents: list[list[int]] = [[] for _ in range(n)]
        children: list[list[int]] = [[] for _ in range(n)]
        for u, v in self.edges:
            parents[v].append(u)
            children[u].append(v)
        self.parents = parents
        self.children = children
        self.pos_of = {i: p for p, i in enumerate(self.order)}
        self.has_copy = [_has_copy(d) for d in self.devices]
        self.dev_name = [d.name for d in self.devices]
        self.in_link = [topo.link_of(d.name, "in") for d in self.devices]
        self.out_link = [topo.link_of(d.name, "out") for d in self.devices]
        self.in_lname = [l.name if l is not None else f"~{d.name}"
                         for d, l in zip(self.devices, self.in_link)]
        self.out_lname = [l.name if l is not None else f"~{d.name}"
                          for d, l in zip(self.devices, self.out_link)]
        # positions that can ever be simulated (ext tasks never are) — lets
        # a partial re-solve's suffix walk skip the frozen 95% in O(1)
        self.sim_positions = [p for p, i in enumerate(self.order)
                              if i not in self.ext]
        # integer link ids: the hot loop indexes clock lists instead of
        # hashing link-name strings
        link_id: dict[str, int] = {}
        for nm in self.in_lname + self.out_lname:
            if nm not in link_id:
                link_id[nm] = len(link_id)
        self.link_names = list(link_id)
        self.in_lid = [link_id[nm] for nm in self.in_lname]
        self.out_lid = [link_id[nm] for nm in self.out_lname]
        self.has_out = [t.out_bytes > 0.0 for t in self.tasks]
        self.has_in = [t.in_bytes > 0.0 for t in self.tasks]
        # per-(device, task) duration tables — every copy/compute duration
        # the simulation loop can ever need, priced once via the same
        # formulas as _bytes_in_time/_bytes_out_time/DeviceProfile.compute
        # (elementwise numpy float64 ops match Python floats exactly)
        in_b = np.array([float(t.in_bytes) for t in self.tasks])
        out_b = np.array([float(t.out_bytes) for t in self.tasks])
        ops = np.array([float(t.ops) for t in self.tasks])
        zeros = [0.0] * n
        self.ext_in = []    # [j][i]: task i's external input into device j
        self.par_in = []    # [j][i]: producer i's output copied into j
        self.stage_out = []  # [j][i]: task i's output staged out of j
        self.comp = []      # [j][i]: task i's compute time on j
        for j, d in enumerate(self.devices):
            bw_in = _link_bw(d, self.in_link[j])
            if math.isinf(bw_in):
                self.ext_in.append(zeros)
                self.par_in.append(zeros)
            else:
                lat = d.copy.latency_s
                self.ext_in.append(np.where(in_b <= 0.0, 0.0,
                                            in_b / bw_in + lat).tolist())
                self.par_in.append(np.where(out_b <= 0.0, 0.0,
                                            out_b / bw_in + lat).tolist())
            bw_out = _link_bw(d, self.out_link[j])
            if math.isinf(bw_out):
                self.stage_out.append(zeros)
            else:
                self.stage_out.append(np.where(out_b <= 0.0, 0.0,
                                               out_b / bw_out).tolist())
            tm = d.compute
            if isinstance(tm, LinearTimeModel):
                self.comp.append((tm.a * ops + tm.b).tolist())
            else:
                self.comp.append([tm(t.ops) for t in self.tasks])
        # hierarchical topologies: host island per device plus the per-task
        # NIC hop (out_bytes / nic_bw + nic_latency) a cross-host edge pays
        # between the producer's host-stage and the consumer's copy_in.
        # Flat topologies keep hier=False and never read these — the exact
        # pre-hierarchy float sequence (byte-identity, DESIGN.md §12/§16).
        self.hier = topo.is_hierarchical()
        if self.hier:
            self.host_id = [-1 if (h := topo.host_index(d.name)) is None
                            else h for d in self.devices]
            nic_bw = (topo.nic.bandwidth_bytes_per_s
                      if topo.nic is not None else None)
            lat = topo.nic_latency_s
            if nic_bw is None or math.isinf(nic_bw):
                self.nic_dur = np.where(out_b <= 0.0, 0.0, lat).tolist()
            else:
                self.nic_dur = np.where(out_b <= 0.0, 0.0,
                                        out_b / nic_bw + lat).tolist()
        else:
            self.host_id = [-1] * len(self.devices)
            self.nic_dur = zeros
        self._np = None   # lazy numpy views of the duration tables
        self._ext_seed = None   # lazy (compute_end, avail, finish) template

    def ext_seed(self) -> tuple[list[float], list[float], list[float]]:
        """Per-task ``(compute_end, avail, finish)`` start lists with the
        ``ext`` entries already written — built once per context (or
        ``rebind``) and list-copied by every ``GraphSimState``, so repeated
        state construction against the same frozen set stops re-walking the
        ext dict (a partial re-solve freezes ~90% of a large order, and a
        refined solve builds several states per call)."""
        if self._ext_seed is None:
            n = self.n
            ce_l = [0.0] * n
            av_l = [0.0] * n
            fin_l = [0.0] * n
            for i, (c_end, av) in self.ext.items():
                ce_l[i] = c_end
                av_l[i] = av
                fin_l[i] = c_end   # fixed past/in-flight work; never inf
            self._ext_seed = (ce_l, av_l, fin_l)
        return self._ext_seed

    def rebind(self, clocks: ClockState,
               ext: Mapping[int, tuple[float, float]] | None
               ) -> "GraphSimContext":
        """A context sharing every per-graph table with ``self``, re-keyed
        onto fresh carried clocks and a fresh ``ext`` map — the only inputs
        a repeated re-solve of the *same* graph changes between calls.
        O(n): only ``sim_positions`` is rebuilt; the duration tables,
        adjacency, and link ids (the expensive part of ``__init__``) are
        shared.  The straggler-rescue path re-plans the same DAG every few
        milliseconds; paying full context construction per re-plan was a
        measurable slice of the re-solve latency (DESIGN.md §14)."""
        c = GraphSimContext.__new__(GraphSimContext)
        for slot in GraphSimContext._SHARED_SLOTS:
            setattr(c, slot, getattr(self, slot))
        c.clocks = clocks
        c.ext = dict(ext) if ext else {}
        eset = c.ext
        c.sim_positions = [p for p, i in enumerate(c.order) if i not in eset]
        c._ext_seed = None
        return c

    def np_tables(self) -> "_NpTables":
        """The per-(device, task) duration tables as (d, n) numpy arrays
        (built once, cached; shared across ``rebind``s) — the vectorized
        candidate-pricing lanes index these instead of the python lists."""
        if self._np is None:
            self._np = _NpTables(self)
        return self._np


class _NpTables:
    """Numpy views of a ``GraphSimContext``'s duration tables, for the
    vectorized pricing paths (``optimize._peek_batch``, ``GraphSimBatch``).
    Built from the same python lists the scalar loop reads, so elementwise
    IEEE float64 operations over them match the scalar engine exactly."""

    __slots__ = ("has_copy", "ext_in", "par_in", "stage_out", "comp",
                 "in_lid", "out_lid", "idx", "same_link", "hier", "host",
                 "nic_dur")

    def __init__(self, ctx: "GraphSimContext"):
        self.has_copy = np.array(ctx.has_copy, dtype=bool)
        self.ext_in = np.array(ctx.ext_in)
        self.par_in = np.array(ctx.par_in)
        self.stage_out = np.array(ctx.stage_out)
        self.comp = np.array(ctx.comp)
        self.in_lid = np.array(ctx.in_lid, dtype=np.intp)
        self.out_lid = np.array(ctx.out_lid, dtype=np.intp)
        self.idx = np.arange(len(ctx.devices))
        self.same_link = np.array([a == b for a, b in
                                   zip(ctx.in_lid, ctx.out_lid)])
        self.hier = ctx.hier
        self.host = np.array(ctx.host_id, dtype=np.intp)
        self.nic_dur = np.array(ctx.nic_dur)


class GraphSimState:
    """Resumable task-graph simulation — the checkpoint/extend engine.

    Holds everything ``_simulate_graph`` used to rebuild per pass: the
    per-link and per-device clocks, per-task ``(compute_end, avail)``
    pairs, the finish times, and the placed set.  ``advance(stop)``
    simulates order positions ``[pos, stop)`` under the *current*
    ``assign``/``placed``; ``clone()`` snapshots the state in O(n); and
    ``peek_finish(i, j)`` prices "task ``i`` next, on device ``j``" in
    O(deg(i)) without mutating anything.  The from-scratch
    ``graph_finish_times`` path is a single ``advance`` over a fresh
    state, so incremental results equal from-scratch results *exactly* —
    there is only one simulation loop (DESIGN.md §12).

    Exactness caveat the list scheduler must handle: whether a producer's
    output is host-staged (``_needs_out``) depends on its *placed
    children's* devices, so placing a new task can retroactively change a
    parent's stage decision.  ``stage_flip_pos(i, j)`` reports the
    earliest simulated position whose decision would change — ``None``
    means extending the checkpoint is exact; otherwise the caller must
    re-simulate from a snapshot at or before that position.
    """

    __slots__ = ("ctx", "pos", "lclock", "dclock", "finish", "compute_end",
                 "avail", "reclaim", "assign", "placed")

    def __init__(self, ctx: GraphSimContext, assign: Sequence[int],
                 placed: Sequence[int] | None = None):
        self.ctx = ctx
        self.assign = list(assign)
        flags = bytearray(ctx.n)
        if placed is None:
            for i in ctx.order:
                if self.assign[i] >= 0 and i not in ctx.ext:
                    flags[i] = 1
            for i in ctx.ext:
                flags[i] = 1
        else:
            for i in placed:
                flags[i] = 1
        self.placed = flags
        self.pos = 0
        # clock lists indexed by ctx link id / device index; None = the
        # carried-over start value from ctx.clocks
        self.lclock: list[float | None] = [None] * len(ctx.link_names)
        self.dclock: list[float | None] = [None] * len(ctx.devices)
        ce_l, av_l, fin_l = ctx.ext_seed()
        self.finish = list(fin_l)
        self.compute_end = list(ce_l)
        self.avail = list(av_l)
        # link time a task's host-stage holds, INCLUDING the idle gap its
        # compute-end barrier inserts: stage end minus the link clock as
        # the stage was scheduled.  This is the exact span a vanish flip
        # returns to the link, so ``stage_flip_pos`` callers can LOWER-
        # bound a flipped candidate's price by ``stale peek - reclaim``
        # (DESIGN.md §14).  0.0 for tasks that do not stage.
        self.reclaim = [0.0] * ctx.n

    def clone(self) -> "GraphSimState":
        st = GraphSimState.__new__(GraphSimState)
        st.ctx = self.ctx
        st.pos = self.pos
        st.lclock = list(self.lclock)
        st.dclock = list(self.dclock)
        st.finish = list(self.finish)
        st.compute_end = list(self.compute_end)
        st.avail = list(self.avail)
        st.reclaim = list(self.reclaim)
        st.assign = list(self.assign)
        st.placed = bytearray(self.placed)
        return st

    def snap_clone(self) -> "GraphSimState":
        """A clone for snapshot chains: clocks and per-task times are
        copied, but ``assign``/``placed`` *alias* the live lists — every
        chain snapshot is rebound onto its caller's live assign/placed
        before use (``_SnapChain.state_at``), so copying them per snapshot
        was pure overhead on the hot re-solve path."""
        st = GraphSimState.__new__(GraphSimState)
        st.ctx = self.ctx
        st.pos = self.pos
        st.lclock = list(self.lclock)
        st.dclock = list(self.dclock)
        st.finish = list(self.finish)
        st.compute_end = list(self.compute_end)
        st.avail = list(self.avail)
        st.reclaim = list(self.reclaim)
        st.assign = self.assign
        st.placed = self.placed
        return st

    # -- energy accounting (DESIGN.md §16) -----------------------------------

    def device_busy(self) -> list[float]:
        """Per-device busy seconds of the current assignment: the sum of
        each placed non-ext task's compute time on its device, from the
        same ``ctx.comp`` table the simulation prices.  Assignment-
        determined, so valid before *and* after ``advance``."""
        ctx = self.ctx
        busy = [0.0] * len(ctx.devices)
        for i in range(ctx.n):
            j = self.assign[i]
            if j >= 0 and self.placed[i] and i not in ctx.ext:
                busy[j] += ctx.comp[j][i]
        return busy

    def energy_joules(self, makespan: float | None = None) -> float:
        """Total joules under the device power models: per-op dynamic
        energy plus idle watts over each device's schedule gap.  With no
        ``makespan`` given, uses the simulated finish horizon."""
        ctx = self.ctx
        if makespan is None:
            makespan = max(self.finish, default=0.0)
        busy = self.device_busy()
        e = 0.0
        for i in range(ctx.n):
            j = self.assign[i]
            if j >= 0 and self.placed[i] and i not in ctx.ext:
                e += ctx.devices[j].joules_per_op * float(ctx.tasks[i].ops)
        for d, b in zip(ctx.devices, busy):
            if d.idle_watts > 0.0 and makespan > b:
                e += d.idle_watts * (makespan - b)
        return e

    # -- clock reads (None = carried-over start) -----------------------------

    def link_clock_id(self, lid: int) -> float:
        v = self.lclock[lid]
        if v is None:
            return self.ctx.clocks.link(self.ctx.link_names[lid])
        return v

    def dev_clock_id(self, j: int) -> float:
        v = self.dclock[j]
        if v is None:
            return self.ctx.clocks.device(self.ctx.dev_name[j])
        return v

    # -- the one simulation loop ---------------------------------------------

    def advance(self, stop: int, events: list[BusEvent] | None = None,
                bound: float | None = None) -> bool:
        """Simulate order positions ``[pos, stop)`` (ext/unassigned tasks
        skipped), appending ``BusEvent``s when ``events`` is a list.

        ``bound`` is a branch-and-bound early exit (DESIGN.md §14): every
        simulated task's finish time lower-bounds the final makespan (link
        and device clocks never rewind), so the moment a finish exceeds
        ``bound`` the caller's candidate cannot beat its incumbent and the
        walk aborts, returning False with the state mid-advance (throwaway
        states only).  A completed advance (returns True) is byte-identical
        to an unbounded one — the bound only *skips* work, it never changes
        a simulated value."""
        if stop <= self.pos:
            return True
        ctx = self.ctx
        sp = ctx.sim_positions
        lo = bisect.bisect_left(sp, self.pos)
        hi = bisect.bisect_left(sp, stop)
        assign = self.assign
        if events is not None:
            # event-recording path: the readable reference loop
            finish = self.finish
            for idx in range(lo, hi):
                i = ctx.order[sp[idx]]
                if assign[i] >= 0:
                    self._sim_task(i, events)
                    if bound is not None and finish[i] > bound:
                        self.pos = sp[idx] + 1
                        return False
            self.pos = stop
            return True
        # hot path: ``_sim_task`` inlined with every per-step attribute
        # lookup hoisted out of the loop — the adoption re-simulations of
        # a large partial re-solve run this body thousands of times per
        # solve, where method dispatch and repeated ``self.``/``ctx.``
        # loads were a measured ~30% of the re-plan latency (DESIGN.md
        # §14).  Any semantic change here must be mirrored in _sim_task
        # (the property suite pins the two paths to identical results).
        order = ctx.order
        placed = self.placed
        lclock, dclock = self.lclock, self.dclock
        finish, compute_end = self.finish, self.compute_end
        avail, reclaim = self.avail, self.reclaim
        parents, children = ctx.parents, ctx.children
        has_out, has_in, has_copy = ctx.has_out, ctx.has_in, ctx.has_copy
        in_lid_t, out_lid_t = ctx.in_lid, ctx.out_lid
        ext_in_t, par_in_t = ctx.ext_in, ctx.par_in
        stage_out_t, comp_t = ctx.stage_out, ctx.comp
        link_names, dev_name = ctx.link_names, ctx.dev_name
        clocks = ctx.clocks
        hier, host_t, nic_t = ctx.hier, ctx.host_id, ctx.nic_dur
        inf = math.inf
        for idx in range(lo, hi):
            i = order[sp[idx]]
            j = assign[i]
            if j < 0:
                continue
            lid = in_lid_t[j]
            hc = has_copy[j]
            hj = host_t[j] if hier else -1
            ready = 0.0
            if hc and has_in[i]:
                s = lclock[lid]
                if s is None:
                    s = clocks.link(link_names[lid])
                s += ext_in_t[j][i]
                lclock[lid] = s
                ready = s
            pin = par_in_t[j]
            for u in parents[i]:
                if not placed[u]:
                    continue
                if assign[u] == j:
                    r = compute_end[u]             # same device: free
                elif not hc or not has_out[u]:
                    r = avail[u]                   # host reads staged copy
                    if hier and hj >= 0:
                        q = assign[u]
                        if q >= 0 and 0 <= host_t[q] != hj:
                            r += nic_t[u]          # staged on a remote host
                else:
                    s = lclock[lid]
                    if s is None:
                        s = clocks.link(link_names[lid])
                    au = avail[u]
                    if hier and hj >= 0:
                        q = assign[u]
                        if q >= 0 and 0 <= host_t[q] != hj:
                            au += nic_t[u]         # NIC hop before copy_in
                    if au > s:
                        s = au
                    s += pin[u]
                    lclock[lid] = s
                    r = s
                if r > ready:
                    ready = r
            s = dclock[j]
            if s is None:
                s = clocks.device(dev_name[j])
            if ready > s:
                s = ready
            ce = s + comp_t[j][i]
            dclock[j] = ce
            compute_end[i] = ce
            fin_i = ce
            av_i = ce
            rec_i = 0.0
            if has_out[i] and hc:
                # inlined _would_need_out: pseudo-sink or cross consumer
                seen = False
                need = False
                for c in children[i]:
                    if not placed[c]:
                        continue
                    seen = True
                    if assign[c] != j:
                        need = True
                        break
                if need or not seen:
                    ol = out_lid_t[j]
                    s = lclock[ol]
                    if s is None:
                        s = clocks.link(link_names[ol])
                    prev = s
                    if ce > s:
                        s = ce
                    nd = s + stage_out_t[j][i]
                    lclock[ol] = nd
                    av_i = nd
                    fin_i = nd
                    rec_i = 0.0 if prev == inf else nd - prev
            finish[i] = fin_i
            avail[i] = av_i
            reclaim[i] = rec_i
            if bound is not None and fin_i > bound:
                self.pos = sp[idx] + 1
                return False
        self.pos = stop
        return True

    def _sim_task(self, i: int, events: list[BusEvent] | None = None
                  ) -> None:
        ctx = self.ctx
        assign = self.assign
        j = assign[i]
        t = ctx.tasks[i]
        in_lid = ctx.in_lid[j]
        has_copy = ctx.has_copy[j]
        placed = self.placed
        lclock, compute_end, avail = self.lclock, self.compute_end, self.avail
        ready = 0.0
        chunk = 0

        # external (host) input bytes
        if has_copy and t.in_bytes > 0.0:
            dur = ctx.ext_in[j][i]
            s = lclock[in_lid]
            if s is None:
                s = ctx.clocks.link(ctx.link_names[in_lid])
            if events is not None:
                events.append(BusEvent(ctx.dev_name[j], "copy_in", s,
                                       s + dur, ctx.in_lname[j], chunk,
                                       t.name))
            chunk += 1
            lclock[in_lid] = s + dur
            ready = s + dur

        # precedence edges (cross-host producers pay the NIC hop as a
        # delay on their staged output's availability — DESIGN.md §16)
        hier = ctx.hier
        host_t, nic_t = ctx.host_id, ctx.nic_dur
        hj = host_t[j] if hier else -1
        par_in = ctx.par_in[j]
        for u in ctx.parents[i]:
            if not placed[u]:
                continue
            if assign[u] == j:
                r = compute_end[u]             # same device: free
            elif not has_copy or not ctx.has_out[u]:
                r = avail[u]                   # host reads the staged copy
                if hier and hj >= 0:
                    q = assign[u]
                    if q >= 0 and 0 <= host_t[q] != hj:
                        r += nic_t[u]          # staged on a remote host
            else:
                dur = par_in[u]
                s = lclock[in_lid]
                if s is None:
                    s = ctx.clocks.link(ctx.link_names[in_lid])
                au = avail[u]
                if hier and hj >= 0:
                    q = assign[u]
                    if q >= 0 and 0 <= host_t[q] != hj:
                        au += nic_t[u]         # NIC hop before copy_in
                if au > s:
                    s = au
                if events is not None:
                    events.append(BusEvent(ctx.dev_name[j], "copy_in", s,
                                           s + dur, ctx.in_lname[j], chunk,
                                           t.name))
                chunk += 1
                lclock[in_lid] = s + dur
                r = s + dur
            if r > ready:
                ready = r

        # compute
        s = self.dclock[j]
        if s is None:
            s = ctx.clocks.device(ctx.dev_name[j])
        if ready > s:
            s = ready
        dur = ctx.comp[j][i]
        if events is not None:
            events.append(BusEvent(ctx.dev_name[j], "compute", s, s + dur,
                                   None, 0, t.name))
        ce = s + dur
        self.dclock[j] = ce
        compute_end[i] = ce
        self.finish[i] = ce
        avail[i] = ce   # no-copy device: output is host-resident now
        self.reclaim[i] = 0.0

        # staged / returned output
        if self._would_need_out(i, j):
            out_lid = ctx.out_lid[j]
            dur = ctx.stage_out[j][i]
            s = lclock[out_lid]
            if s is None:
                s = ctx.clocks.link(ctx.link_names[out_lid])
            prev = s
            if ce > s:
                s = ce
            if events is not None:
                events.append(BusEvent(ctx.dev_name[j], "copy_out", s,
                                       s + dur, ctx.out_lname[j], 0, t.name))
            lclock[out_lid] = s + dur
            avail[i] = s + dur
            self.finish[i] = s + dur
            # inf - inf guard: an already-infinite link clock stays
            # infinite whether or not this stage exists, so the vanish
            # reclaims nothing
            self.reclaim[i] = 0.0 if prev == math.inf else s + dur - prev

    # -- stage decision ------------------------------------------------------

    def _would_need_out(self, i: int, j: int) -> bool:
        """Whether task ``i`` on device ``j`` stages its output to host:
        it is a pseudo-sink (no placed consumers) or feeds a placed
        consumer on another device."""
        ctx = self.ctx
        if not ctx.has_out[i] or not ctx.has_copy[j]:
            return False   # host output is already host-resident
        placed, assign = self.placed, self.assign
        seen = False
        for c in ctx.children[i]:
            if not placed[c]:
                continue
            seen = True
            if assign[c] != j:
                return True
        return not seen    # sink (or all consumers unscheduled): return C

    def needs_out(self, i: int) -> bool:
        return self._would_need_out(i, self.assign[i])

    # -- incremental extension -----------------------------------------------

    def peek_finish(self, i: int, j: int) -> float:
        """Price task ``i`` as the next committed task, on device ``j``,
        without mutating the state — exact when ``stage_flip_pos(i, j)``
        is None (no already-simulated producer's stage decision changes)."""
        ctx = self.ctx
        t = ctx.tasks[i]
        in_lid = ctx.in_lid[j]
        has_copy = ctx.has_copy[j]
        placed, assign = self.placed, self.assign
        lc: float | None = None   # local overlay of the in-link clock

        ready = 0.0
        if has_copy and t.in_bytes > 0.0:
            s = self.link_clock_id(in_lid)
            lc = s + ctx.ext_in[j][i]
            ready = lc
        hier = ctx.hier
        host_t, nic_t = ctx.host_id, ctx.nic_dur
        hj = host_t[j] if hier else -1
        par_in = ctx.par_in[j]
        for u in ctx.parents[i]:
            if not placed[u]:
                continue
            if assign[u] == j:
                r = self.compute_end[u]
            elif not has_copy or not ctx.has_out[u]:
                r = self.avail[u]
                if hier and hj >= 0:
                    q = assign[u]
                    if q >= 0 and 0 <= host_t[q] != hj:
                        r += nic_t[u]
            else:
                s = lc if lc is not None else self.link_clock_id(in_lid)
                au = self.avail[u]
                if hier and hj >= 0:
                    q = assign[u]
                    if q >= 0 and 0 <= host_t[q] != hj:
                        au += nic_t[u]
                if au > s:
                    s = au
                lc = s + par_in[u]
                r = lc
            if r > ready:
                ready = r
        s = self.dev_clock_id(j)
        if ready > s:
            s = ready
        ce = s + ctx.comp[j][i]
        if self._would_need_out(i, j):
            out_lid = ctx.out_lid[j]
            if out_lid == in_lid and lc is not None:
                s = lc
            else:
                s = self.link_clock_id(out_lid)
            if ce > s:
                s = ce
            return s + ctx.stage_out[j][i]
        return ce

    def price_lanes(self, i: int, nd: int
                    ) -> tuple[list[float], list[int | None], list[float]]:
        """Fused ``peek_finish`` + ``_stage_flip_info`` over every device
        lane in ONE walk of ``i``'s neighborhood: returns per-device
        ``(peeks, flip_positions, vanish_slacks)``.

        The scalar EFT placer calls this once per task instead of ``d``
        peeks plus ``d`` flip scans — the dominant redundancy was each
        per-lane flip scan re-walking every producer's children, when one
        walk yields the producer's (seen, cross) pair from which every
        lane's flip direction follows in O(1): a producer staging for a
        pseudo-sink (``not seen and not cross``) vanishes only on its own
        lane, one with co-located consumers (``seen and not cross``)
        appears on every other lane, and a cross-feeding producer never
        flips.  Per-lane float operations replicate ``peek_finish``'s
        sequence exactly, so selection stays bit-identical (pinned by the
        property suite)."""
        ctx = self.ctx
        placed, assign = self.placed, self.assign
        pos_of, ext = ctx.pos_of, ctx.ext
        children = ctx.children
        has_out, has_copy = ctx.has_out, ctx.has_copy
        in_lid, out_lid = ctx.in_lid, ctx.out_lid
        compute_end, avail, reclaim = self.compute_end, self.avail, \
            self.reclaim
        mypos = self.pos
        hier, host_t, nic_t = ctx.hier, ctx.host_id, ctx.nic_dur
        flip: list[int | None] = [None] * nd
        slack = [0.0] * nd
        lc: list[float | None] = [None] * nd
        ready = [0.0] * nd
        if ctx.has_in[i]:
            ext_in = ctx.ext_in
            for j in range(nd):
                if has_copy[j]:
                    s = self.link_clock_id(in_lid[j])
                    s += ext_in[j][i]
                    lc[j] = s
                    ready[j] = s
        par_in = ctx.par_in
        for u in ctx.parents[i]:
            if not placed[u]:
                continue
            au = assign[u]
            hou = has_out[u]
            # flip scan: one children walk per qualifying producer
            if au >= 0 and hou and has_copy[au] and u not in ext:
                pu = pos_of.get(u)
                if pu is not None and pu < mypos:
                    seen = False
                    cross = False
                    for c in children[u]:
                        if placed[c]:
                            seen = True
                            if assign[c] != au:
                                cross = True
                                break
                    if not cross:
                        if not seen:
                            # staged as pseudo-sink: vanishes iff i lands
                            # co-located (lane au only)
                            slack[au] += reclaim[u]
                            f = flip[au]
                            if f is None or pu < f:
                                flip[au] = pu
                        else:
                            # co-located consumers: appears on every
                            # cross lane
                            for j in range(nd):
                                if j != au:
                                    f = flip[j]
                                    if f is None or pu < f:
                                        flip[j] = pu
            # peek contribution, lane by lane (scalar op order per lane)
            ceu = compute_end[u]
            avu = avail[u]
            hq = host_t[au] if (hier and au >= 0) else -1
            ndur = nic_t[u]
            for j in range(nd):
                if au == j:
                    r = ceu
                elif not has_copy[j] or not hou:
                    r = avu
                    if hq >= 0 and 0 <= host_t[j] != hq:
                        r += ndur
                else:
                    s = lc[j]
                    if s is None:
                        s = self.link_clock_id(in_lid[j])
                    a2 = avu
                    if hq >= 0 and 0 <= host_t[j] != hq:
                        a2 += ndur
                    if a2 > s:
                        s = a2
                    s += par_in[j][u]
                    lc[j] = s
                    r = s
                if r > ready[j]:
                    ready[j] = r
        hoi = has_out[i]
        kid_devs = ([assign[c] for c in children[i] if placed[c]]
                    if hoi else None)
        comp, stage_out = ctx.comp, ctx.stage_out
        peeks = [0.0] * nd
        for j in range(nd):
            s = self.dev_clock_id(j)
            if ready[j] > s:
                s = ready[j]
            ce = s + comp[j][i]
            if hoi and has_copy[j]:
                if kid_devs:
                    need = False
                    for d in kid_devs:
                        if d != j:
                            need = True
                            break
                else:
                    need = True   # pseudo-sink: output returns to host
                if need:
                    ol = out_lid[j]
                    if ol == in_lid[j] and lc[j] is not None:
                        s2 = lc[j]
                    else:
                        s2 = self.link_clock_id(ol)
                    if ce > s2:
                        s2 = ce
                    ce = s2 + stage_out[j][i]
            peeks[j] = ce
        return peeks, flip, slack

    def stage_flip_pos(self, i: int, j: int) -> int | None:
        """Earliest already-simulated order position whose host-stage
        decision would change if ``assign[i]`` became ``j`` and ``i``
        joined the placed set (None = none; extending the checkpoint is
        exact).  Only ``i``'s producers can flip: a producer that staged
        for a pseudo-sink stops staging when its first placed consumer is
        co-located (vanish), and one whose placed consumers were all
        co-located starts staging when ``i`` lands cross-device (appear).
        """
        return self._stage_flip_info(i, j)[0]

    def _stage_flip_info(self, i: int, j: int
                         ) -> tuple[int | None, bool, bool, float]:
        """``(earliest flip pos | None, appear_only, vanish_only, slack)``.

        Direction of each flip, for the interval bounds the EFT placer
        uses on its stale peeks (DESIGN.md §14): an *appear* flip (a
        producer starts staging) only inserts extra link occupancy, so
        the stale peek is a LOWER bound on the exact price; a *vanish*
        flip (a pseudo-sink producer stops staging) only removes
        occupancy, so the stale peek is an UPPER bound.  ``slack`` is the
        total link time the vanishes return: each flipped producer's
        ``reclaim`` span — its stage duration PLUS the idle gap the
        compute-end barrier inserted on the link (the barrier matters:
        deleting the stage lets queued transfers restart from the
        pre-stage link clock, not merely ``stage_out`` earlier).  The
        engine's clocks are (max, +) compositions of their inputs, so
        returning ``s`` seconds of link time pulls any downstream event
        earlier by at most ``s`` — ``stale peek - slack`` therefore
        LOWER-bounds the exact price for ANY flip mix (appears only push
        it up).  The flags are vacuously True (slack 0.0) on None.
        """
        ctx = self.ctx
        placed, assign = self.placed, self.assign
        best: int | None = None
        appear_only = True
        vanish_only = True
        slack = 0.0
        for u in ctx.parents[i]:
            if not placed[u] or assign[u] < 0 or u in ctx.ext:
                continue
            pu = ctx.pos_of.get(u)
            if pu is None or pu >= self.pos:
                continue   # not simulated yet — commits price it later
            a = assign[u]
            if not ctx.has_out[u] or not ctx.has_copy[a]:
                continue   # never stages regardless of consumers
            old = True     # pseudo-sink default
            seen = False
            for c in ctx.children[u]:
                if not placed[c]:
                    continue
                seen = True
                if assign[c] != a:
                    old = True
                    break
            else:
                if seen:
                    old = False
            new = False    # i joins the consumer set, so it is non-empty
            for c in ctx.children[u]:
                ac = j if c == i else (assign[c] if placed[c] else None)
                if ac is not None and ac != a:
                    new = True
                    break
            if old != new:
                if old:
                    appear_only = False   # True -> False: a vanish
                    slack += self.reclaim[u]
                else:
                    vanish_only = False   # False -> True: an appear
                if best is None or pu < best:
                    best = pu
        return best, appear_only, vanish_only, slack


class GraphSimBatch:
    """Price every device move of ONE task in parallel numpy lanes.

    Lane ``l`` simulates the same suffix as a scalar
    ``clone(); assign[mv] = cand[l]; advance(stop)`` walk, but all lanes
    share one clone of the base state: clocks, ``finish``/``avail``/
    ``compute_end`` become ``(L, ·)`` arrays and each engine step applies
    the exact ``_sim_task`` formula elementwise per lane.  Per-lane IEEE
    float64 elementwise ops match the scalar engine op for op, so a lane's
    values are byte-identical to the scalar walk's (pinned by the
    hypothesis suite).

    Only ``mv``'s device varies across lanes, which keeps the per-task
    control flow almost scalar: lanes diverge arithmetically only at
    ``mv`` itself, at tasks reading ``mv`` as a parent, and at producers
    whose host-stage decision depends on ``mv``'s device (the flip case —
    which is why the caller rewinds the base state to the flip floor
    before batching).

    ``run(stop, bound)`` applies the same branch-and-bound rule as
    ``GraphSimState.advance``: a lane whose simulated finish exceeds
    ``bound`` is dead (its final makespan reads +inf); the walk aborts
    once every lane is dead.  Crossover caveat: per-step numpy dispatch
    costs ~3-5x a scalar step, so batching only wins with enough lanes —
    ``optimize._BATCH_MIN_LANES`` gates it (DESIGN.md §14).
    """

    __slots__ = ("ctx", "mv", "cand", "pos", "lanes", "lclock", "dclock",
                 "finish", "compute_end", "avail", "reclaim", "assign",
                 "placed", "alive", "_li", "_npt")

    def __init__(self, base: GraphSimState, mv: int,
                 cand: Sequence[int]):
        ctx = self.ctx = base.ctx
        self.mv = mv
        self.cand = np.array(cand, dtype=np.intp)
        L = self.lanes = len(cand)
        self.pos = base.pos
        self._li = np.arange(L)
        self._npt = ctx.np_tables()
        # resolve carried-over (None) clocks eagerly: link_clock_id is a
        # pure read of ctx.clocks, so this matches the scalar lazy resolve
        self.lclock = np.tile(
            [base.link_clock_id(k) for k in range(len(ctx.link_names))],
            (L, 1))
        self.dclock = np.tile(
            [base.dev_clock_id(j) for j in range(len(ctx.devices))],
            (L, 1))
        self.finish = np.tile(base.finish, (L, 1))
        self.compute_end = np.tile(base.compute_end, (L, 1))
        self.avail = np.tile(base.avail, (L, 1))
        self.reclaim = np.tile(base.reclaim, (L, 1))
        self.assign = base.assign          # scalar; mv's entry is ignored
        self.placed = base.placed
        self.alive = np.ones(L, dtype=bool)

    def run(self, stop: int, bound: float | None = None) -> bool:
        """Advance every lane to ``stop``; False once all lanes are dead
        (their finishes exceeded ``bound``) — surviving lanes are exact."""
        if stop <= self.pos:
            return True
        ctx = self.ctx
        sp = ctx.sim_positions
        lo = bisect.bisect_left(sp, self.pos)
        hi = bisect.bisect_left(sp, stop)
        assign = self.assign
        alive = self.alive
        for idx in range(lo, hi):
            i = ctx.order[sp[idx]]
            if assign[i] >= 0:
                self._sim(i)
                if bound is not None:
                    alive &= self.finish[:, i] <= bound
                    if not alive.any():
                        self.pos = sp[idx] + 1
                        return False
        self.pos = stop
        return True

    def makespans(self) -> np.ndarray:
        """Per-lane makespan over simulated tasks; +inf for dead lanes."""
        ms = self.finish.max(axis=1)
        return np.where(self.alive, ms, np.inf)

    def extract(self, l: int) -> GraphSimState:
        """Lane ``l`` as a scalar ``GraphSimState`` (clocks resolved) —
        adopted as the new head state when the lane's move is accepted."""
        st = GraphSimState.__new__(GraphSimState)
        st.ctx = self.ctx
        st.pos = self.pos
        st.lclock = self.lclock[l].tolist()
        st.dclock = self.dclock[l].tolist()
        st.finish = self.finish[l].tolist()
        st.compute_end = self.compute_end[l].tolist()
        st.avail = self.avail[l].tolist()
        st.reclaim = self.reclaim[l].tolist()
        st.assign = list(self.assign)
        st.assign[self.mv] = int(self.cand[l])
        st.placed = bytearray(self.placed)
        return st

    # -- engine step (exact per-lane _sim_task) ------------------------------

    def _sim(self, i: int) -> None:
        if i == self.mv:
            self._sim_moved(i)
        else:
            self._sim_scalar_dev(i)

    def _sim_scalar_dev(self, i: int) -> None:
        """Task on its committed device ``j`` in every lane; values may
        still lane-vary through clocks/parent avail perturbed by ``mv``."""
        ctx = self.ctx
        mv = self.mv
        j = self.assign[i]
        t = ctx.tasks[i]
        in_lid = ctx.in_lid[j]
        has_copy = ctx.has_copy[j]
        placed = self.placed
        lclock, compute_end, avail = self.lclock, self.compute_end, self.avail

        ready = None
        if has_copy and t.in_bytes > 0.0:
            nd = lclock[:, in_lid] + ctx.ext_in[j][i]
            lclock[:, in_lid] = nd
            ready = nd
        hier = ctx.hier
        host_t, nic_t = ctx.host_id, ctx.nic_dur
        hj = host_t[j] if hier else -1
        par_in = ctx.par_in[j]
        for u in ctx.parents[i]:
            if not placed[u]:
                continue
            if u == mv:
                # producer device lane-varies: the NIC hop applies on
                # lanes whose candidate host differs from j's host
                av = avail[:, u]
                if hier and hj >= 0:
                    hq = self._npt.host[self.cand]
                    crossm = (hq >= 0) & (hq != hj)
                    if crossm.any():
                        av = np.where(crossm, av + nic_t[u], av)
                if not has_copy or not ctx.has_out[u]:
                    same = self.cand == j
                    r = np.where(same, compute_end[:, u], av)
                else:
                    same = self.cand == j
                    s = np.maximum(lclock[:, in_lid], av)
                    nd = s + par_in[u]
                    lclock[:, in_lid] = np.where(same, lclock[:, in_lid],
                                                 nd)
                    r = np.where(same, compute_end[:, u], nd)
            elif self.assign[u] == j:
                r = compute_end[:, u]
            elif not has_copy or not ctx.has_out[u]:
                r = avail[:, u]
                if hier and hj >= 0:
                    q = self.assign[u]
                    if q >= 0 and 0 <= host_t[q] != hj:
                        r = r + nic_t[u]
            else:
                av = avail[:, u]
                if hier and hj >= 0:
                    q = self.assign[u]
                    if q >= 0 and 0 <= host_t[q] != hj:
                        av = av + nic_t[u]
                s = np.maximum(lclock[:, in_lid], av)
                nd = s + par_in[u]
                lclock[:, in_lid] = nd
                r = nd
            ready = r if ready is None else np.maximum(ready, r)

        s = self.dclock[:, j]
        if ready is not None:
            s = np.maximum(s, ready)
        ce = s + ctx.comp[j][i]
        self.dclock[:, j] = ce
        compute_end[:, i] = ce
        self.finish[:, i] = ce
        avail[:, i] = ce
        self.reclaim[:, i] = 0.0

        need = self._need_out_mask(i, j)
        if need is not None:
            out_lid = ctx.out_lid[j]
            prev = lclock[:, out_lid]
            s = np.maximum(prev, ce)
            nd = s + ctx.stage_out[j][i]
            # before the in-place lclock write; inf-prev lanes reclaim 0.0
            # (mirrors the scalar inf - inf guard)
            fin = prev != np.inf
            rec = np.subtract(nd, prev, out=np.zeros_like(nd), where=fin)
            if need is True:
                lclock[:, out_lid] = nd
                avail[:, i] = nd
                self.finish[:, i] = nd
                self.reclaim[:, i] = rec
            else:
                lclock[:, out_lid] = np.where(need, nd, prev)
                avail[:, i] = np.where(need, nd, ce)
                self.finish[:, i] = np.where(need, nd, ce)
                self.reclaim[:, i] = np.where(need, rec, 0.0)

    def _need_out_mask(self, i: int, j: int) -> "bool | np.ndarray | None":
        """``_would_need_out(i, j)`` per lane: None = False everywhere,
        True = every lane, else an (L,) mask (``mv`` is the only consumer
        whose device lane-varies; it always counts as placed)."""
        ctx = self.ctx
        if not ctx.has_out[i] or not ctx.has_copy[j]:
            return None
        placed, assign = self.placed, self.assign
        mv = self.mv
        seen = False
        has_mv = False
        for c in ctx.children[i]:
            if c == mv:
                has_mv = True
                continue
            if not placed[c]:
                continue
            seen = True
            if assign[c] != j:
                return True
        if has_mv:
            # mv counts as a placed consumer, so "no consumers" is off
            # the table; need(l) = mv cross-device in lane l
            mask = self.cand != j
            if mask.all():
                return True
            if not mask.any():
                return None
            return mask
        return None if seen else True

    def _sim_moved(self, i: int) -> None:
        """The moved task itself: device ``cand[l]`` in lane ``l`` — the
        fancy-indexed mirror of ``_sim_task`` (the ``_peek_batch`` idiom,
        committed instead of peeked)."""
        ctx = self.ctx
        npt = self._npt
        t = ctx.tasks[i]
        jv = self.cand
        li = self._li
        in_l = npt.in_lid[jv]
        hc = npt.has_copy[jv]
        placed = self.placed
        lclock, compute_end, avail = self.lclock, self.compute_end, self.avail

        ready = None
        if t.in_bytes > 0.0 and hc.any():
            s = lclock[li, in_l]
            nd = s + npt.ext_in[jv, i]
            lclock[li, in_l] = np.where(hc, nd, s)
            ready = np.where(hc, nd, 0.0)
        hier = ctx.hier
        host_t, nic_t = ctx.host_id, ctx.nic_dur
        hjv = npt.host[jv] if hier else None
        for u in ctx.parents[i]:
            if not placed[u]:
                continue
            same = jv == self.assign[u]
            # consumer device lane-varies: NIC hop on lanes whose host
            # differs from the (scalar) producer's host
            av = avail[:, u]
            if hier:
                q = self.assign[u]
                if q >= 0 and host_t[q] >= 0:
                    crossm = (hjv >= 0) & (hjv != host_t[q])
                    if crossm.any():
                        av = np.where(crossm, av + nic_t[u], av)
            if not ctx.has_out[u]:
                r = np.where(same, compute_end[:, u], av)
            else:
                docopy = ~same & hc
                s = np.maximum(lclock[li, in_l], av)
                nd = s + npt.par_in[jv, u]
                lclock[li, in_l] = np.where(docopy, nd, lclock[li, in_l])
                r = np.where(same, compute_end[:, u],
                             np.where(docopy, nd, av))
            ready = r if ready is None else np.maximum(ready, r)

        s = self.dclock[li, jv]
        if ready is not None:
            s = np.maximum(s, ready)
        ce = s + npt.comp[jv, i]
        self.dclock[li, jv] = ce
        compute_end[:, i] = ce
        self.finish[:, i] = ce
        avail[:, i] = ce
        self.reclaim[:, i] = 0.0

        # stage decision per lane: mv's children have scalar devices
        if ctx.has_out[i]:
            cross = None
            seen = False
            for c in ctx.children[i]:
                if not placed[c]:
                    continue
                seen = True
                cc = jv != self.assign[c]
                cross = cc if cross is None else (cross | cc)
            need = hc if not seen else (hc & cross)
            if need.any():
                out_l = npt.out_lid[jv]
                prev = lclock[li, out_l]   # fancy index: a copy, not a view
                s = np.maximum(prev, ce)
                nd = s + npt.stage_out[jv, i]
                rec = np.subtract(nd, prev, out=np.zeros_like(nd),
                                  where=prev != np.inf)
                lclock[li, out_l] = np.where(need, nd, prev)
                avail[:, i] = np.where(need, nd, ce)
                self.finish[:, i] = np.where(need, nd, ce)
                self.reclaim[:, i] = np.where(need, rec, 0.0)


def _simulate_graph(devices: Sequence[DeviceProfile],
                    tasks: Sequence[TaskSpec],
                    edges: Sequence[tuple[int, int]],
                    assign: Sequence[int], topo: BusTopology,
                    order: Sequence[int],
                    events: list[BusEvent] | None,
                    clocks: ClockState = ZERO_CLOCKS,
                    ext: Mapping[int, tuple[float, float]] | None = None
                    ) -> list[float]:
    """One pass over a task graph's event graph.  Returns per-task finish
    times (0 for tasks with ``assign[i] < 0`` — the list scheduler prices
    partial assignments during device selection); appends ``BusEvent``s
    when ``events`` is a list.

    This is a thin wrapper over ``GraphSimState`` — one fresh state
    advanced over the whole order — so the incremental checkpoint/extend
    path the list scheduler uses and this from-scratch path are the same
    code by construction.

    ``ext`` prices a task *externally* (mid-graph re-planning, DESIGN.md
    §11): a frozen — completed or currently running — task is not
    simulated; its ``(compute_end, avail)`` come from the mapping instead
    (``avail`` = when its output is host-resident; ``math.inf`` marks an
    output that never reaches the host, so any candidate needing a host
    read of it prices to infinity and is rejected by the solver).  Frozen
    tasks emit no events and their finish is reported as their
    ``compute_end``.

    Semantics (the Fig. 2 rules, generalized to precedence edges):

      * ``order`` must be a topological linearization; each link's clock
        advances in that order, so the executor can replay the grant
        sequence without deadlock (a ticket never waits on a later one);
      * a task's external input copy serializes on its device's in-link;
      * a cross-device edge u→v becomes link copies: u's output is staged
        to host once (one ``copy_out`` on u's out-link, shared by all
        cross-device consumers and by the sink return), then each consumer
        reads it over its own in-link (``copy_in`` depending on the stage
        copy's finish, not just the link clock) — same-device edges are
        free (the data never leaves device memory);
      * compute starts at max(device clock, every input landed); no-copy
        devices (the host) read staged data the moment the producer's
        copy_out ends;
      * a sink task's output returns to host after its compute.

    ``clocks`` starts the world from carried-over link/device clocks
    exactly as the divisible engine does, so graph plans chain into the
    streaming runtime unchanged.
    """
    ctx = GraphSimContext(devices, tasks, edges, topo, order, clocks, ext)
    st = GraphSimState(ctx, assign)
    st.advance(len(ctx.order), events)
    return st.finish


def build_graph_timeline(devices: Sequence[DeviceProfile],
                         tasks: Sequence[TaskSpec],
                         edges: Sequence[tuple[int, int]],
                         assign: Sequence[int], *,
                         topology: BusTopology | str | None = None,
                         order: Sequence[int] | None = None,
                         clocks: ClockState = ZERO_CLOCKS,
                         ext: Mapping[int, tuple[float, float]] | None = None
                         ) -> Timeline:
    """The unified event-graph timeline for a task graph — what the list
    scheduler prices, ``simulate_graph_timeline`` returns, and the
    executor's per-link ticket order is derived from.  ``ext`` freezes
    tasks out of the simulation (mid-graph re-planning): they emit no
    events and feed consumers at the given (compute_end, avail) times."""
    topo = BusTopology.from_spec(topology, devices)
    if order is None:
        order = _graph_topo_order(len(tasks), edges)
    events: list[BusEvent] = []
    _simulate_graph(devices, tasks, edges, assign, topo, order, events,
                    clocks, ext)
    return Timeline(events)


def graph_finish_times(devices: Sequence[DeviceProfile],
                       tasks: Sequence[TaskSpec],
                       edges: Sequence[tuple[int, int]],
                       assign: Sequence[int], *,
                       topology: BusTopology | str | None = None,
                       order: Sequence[int] | None = None,
                       clocks: ClockState = ZERO_CLOCKS,
                       ext: Mapping[int, tuple[float, float]] | None = None
                       ) -> list[float]:
    """Per-task finish times from the same control flow as
    ``build_graph_timeline``, without materializing events (the list
    scheduler's device-selection hot path)."""
    topo = BusTopology.from_spec(topology, devices)
    if order is None:
        order = _graph_topo_order(len(tasks), edges)
    return _simulate_graph(devices, tasks, edges, assign, topo, order, None,
                           clocks, ext)


@dataclasses.dataclass(frozen=True)
class GraphTimelineSpec:
    """The engine inputs a task-graph ``Schedule``'s timeline was built
    from — the DAG analogue of ``TimelineSpec``, same contract: a runtime
    can rebase the identical event graph onto carried-over clocks, or
    re-price it under ground-truth device models, without knowing any
    domain geometry.  ``order`` is the planned (topological) priority list;
    replays must keep it, or the executor's ticket grant order would
    diverge from the plan."""

    devices: tuple[DeviceProfile, ...]
    tasks: tuple[TaskSpec, ...]
    edges: tuple[tuple[int, int], ...]
    assign: tuple[int, ...]
    order: tuple[int, ...]
    topology: BusTopology

    def rebase(self, clocks: ClockState = ZERO_CLOCKS, *,
               devices: Sequence[DeviceProfile] | None = None) -> Timeline:
        devs = list(devices) if devices is not None else list(self.devices)
        return build_graph_timeline(devs, self.tasks, self.edges,
                                    self.assign, topology=self.topology,
                                    order=self.order, clocks=clocks)

    def rebase_partial(self, clocks: ClockState = ZERO_CLOCKS, *,
                       ext: Mapping[str, tuple[float, float]],
                       devices: Sequence[DeviceProfile] | None = None
                       ) -> Timeline:
        """Partial rebase for mid-graph re-planning (DESIGN.md §11): price
        only the remaining subgraph from the carried (measured) clocks.
        ``ext`` maps *frozen task names* — completed or currently running —
        to ``(compute_end, avail)``: those tasks emit no events; frontier
        consumers read them at the given times (``avail = math.inf`` marks
        an output that never reaches the host).  The returned timeline
        holds exactly the frontier's events — its ``link_ticket_order`` is
        what the executor re-issues."""
        devs = list(devices) if devices is not None else list(self.devices)
        index = {t.name: i for i, t in enumerate(self.tasks)}
        return build_graph_timeline(
            devs, self.tasks, self.edges, self.assign,
            topology=self.topology, order=self.order, clocks=clocks,
            ext={index[name]: t for name, t in ext.items()})

    def ops_by_device(self) -> dict[str, float]:
        out: dict[str, float] = {}
        for t, a in zip(self.tasks, self.assign):
            if a >= 0:
                name = self.devices[a].name
                out[name] = out.get(name, 0.0) + float(t.ops)
        return out

    def task_ops(self) -> list[tuple[str, str, float]]:
        """(task, device, ops) per scheduled task — the per-task
        observation surface the streaming runtime pumps back into the
        Predict phase."""
        return [(t.name, self.devices[a].name, float(t.ops))
                for t, a in zip(self.tasks, self.assign) if a >= 0]

    def parents_of(self) -> dict[str, tuple[str, ...]]:
        """Task name -> upstream task names (the executor's cross-device
        dependency wait list)."""
        out: dict[str, list[str]] = {t.name: [] for t in self.tasks}
        for u, v in self.edges:
            out[self.tasks[v].name].append(self.tasks[u].name)
        return {k: tuple(v) for k, v in out.items()}

    def stage_seconds(self, devices: Sequence[DeviceProfile] | None = None
                      ) -> dict[str, dict[str, float]]:
        """Per-task summed stage durations (``{task: {kind: seconds}}``)
        under ``devices`` (default: the planned models) — what a sleep-based
        task factory prices its stages from."""
        tl = self.rebase(devices=devices)
        out: dict[str, dict[str, float]] = {}
        for e in tl.events:
            if e.task is None:  # pragma: no cover - graph events carry tasks
                continue
            kinds = out.setdefault(e.task, {})
            kinds[e.kind] = kinds.get(e.kind, 0.0) + e.duration
        return out
