"""hgemms — the paper's DS-POAS for heterogeneous GEMM (§4).

Splits an (m, n, k) GEMM's rows across heterogeneous devices per the POAS
plan and executes the partitions through the overlapped co-execution runtime
(``core.executor``): one thread per device, input/output copies serialized
on the shared bus in the planned priority order, compute overlapping other
devices' copies.  On this container every partition runs as a real jitted
JAX matmul on the host CPU; per-device *times* come from the device models
(the simulated testbed), while the *numerics* are real — so correctness
(C == A@B), scheduling quality, and the executor's event ordering are all
testable.

On a TPU deployment the per-partition compute is the Pallas MXU matmul
kernel (``repro.kernels.matmul``); the executor dispatches to it when the
device kind is ``tpu-group`` and a TPU backend is present.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import numpy as np

from .adapt import GemmPlan
from .bus import BusTopology
from .device_model import DeviceProfile, with_pipeline
from .domain import PlanCache
from .executor import DeviceTask, OverlappedExecutor
from .framework import GemmWorkload, POASPlan, make_gemm_poas
from .schedule import DynamicScheduler, Timeline, simulate_timeline


@dataclasses.dataclass
class ExecutionReport:
    plan: POASPlan
    timeline: Timeline
    predicted_makespan: float
    simulated_makespan: float      # from device models (+noise if asked)
    wall_seconds: float            # actual host wall time of the partitions
    standalone: dict[str, float]   # predicted time if each device ran alone
    per_device_seconds: dict[str, float]
    measured: Timeline | None = None   # executor's real per-stage intervals

    @property
    def speedups(self) -> dict[str, float]:
        return {name: t / self.simulated_makespan
                for name, t in self.standalone.items()}


class HGemms:
    """Heterogeneous GEMM scheduler (paper §4)."""

    def __init__(self, devices: Sequence[DeviceProfile], *,
                 bus: str | BusTopology = "serialized",
                 dynamic: bool = False, cache: bool = True,
                 pipeline_chunks: int | None = None):
        self.devices = list(devices)
        if pipeline_chunks is not None:
            # chunked pipelined copies (DESIGN.md §4): the adapt phase maps
            # each copying device's chunk count to row-chunks of its A slice
            self.devices = with_pipeline(self.devices, pipeline_chunks)
        self.poas, self.dyn = make_gemm_poas(self.devices, bus=bus,
                                             dynamic=dynamic, cache=cache)
        self.bus = self.poas.domain.bus
        self.topology = self.poas.domain.topology

    @property
    def plan_cache(self) -> PlanCache | None:
        return self.poas.cache

    # -- planning ----------------------------------------------------------

    def plan(self, m: int, n: int, k: int) -> POASPlan:
        return self.poas.plan(GemmWorkload(m=m, n=n, k=k))

    # -- execution ---------------------------------------------------------

    def _partition_tasks(self, a: np.ndarray, b: np.ndarray, c: np.ndarray,
                         gplan: GemmPlan, planned: Timeline) -> list[DeviceTask]:
        """One ``DeviceTask`` per device with work; stages mirror the planned
        timeline (devices with no planned copy event compute in place).
        Devices with pipelined row chunks get per-chunk stage lists so the
        executor streams them — chunk 1's matmul really overlaps chunk 2's
        copy, the overlap the chunked plan prices."""
        import jax
        import jax.numpy as jnp

        @jax.jit
        def mm(x, y):
            return x @ y

        planned_kinds = {(e.device, e.kind) for e in planned.events}
        tasks: list[DeviceTask] = []
        for dev, asg in zip(self.devices, gplan.assignments):
            if asg.m == 0:
                continue
            has_in = (dev.name, "copy_in") in planned_kinds
            has_out = (dev.name, "copy_out") in planned_kinds
            state: dict = {}
            if has_in and len(asg.chunk_rows) > 1:
                tasks.append(self._pipelined_task(
                    mm, a, b, c, dev.name, asg, has_out, state))
                continue
            rows = slice(asg.row0, asg.row0 + asg.m)

            def copy_in(state=state, rows=rows):
                # host -> device: A row-slice + full B
                state["a"] = jnp.asarray(a[rows])
                state["b"] = jnp.asarray(b)

            def compute(state=state, rows=rows):
                if "a" not in state:      # no-copy device computes in place
                    state["a"] = jnp.asarray(a[rows])
                    state["b"] = jnp.asarray(b)
                state["c"] = np.asarray(mm(state["a"], state["b"]))

            def copy_out(state=state, rows=rows):
                c[rows] = state["c"]

            if not has_out:
                # fold the C write into compute so the result still lands
                def compute(state=state, rows=rows, inner=compute):
                    inner()
                    c[rows] = state["c"]
            tasks.append(DeviceTask(
                device=dev.name,
                copy_in=copy_in if has_in else None,
                compute=compute,
                copy_out=copy_out if has_out else None))
        return tasks

    @staticmethod
    def _pipelined_task(mm, a: np.ndarray, b: np.ndarray, c: np.ndarray,
                        device: str, asg, has_out: bool,
                        state: dict) -> DeviceTask:
        """Per-chunk stage lists from the adapt phase's ``chunk_rows``: the
        shared B panel rides input chunk 0 (exactly how the engine prices
        it), chunk j's matmul consumes its own A slice, chunk j's C slice
        lands in the output stage (or inside compute for no-copy-out)."""
        import jax.numpy as jnp

        in_chunks, comp_chunks, out_chunks = [], [], []
        for j, (r0, rr) in enumerate(zip(asg.chunk_offsets(),
                                         asg.chunk_rows)):
            def copy_in(j=j, r0=r0, rr=rr, state=state):
                if j == 0:
                    state["b"] = jnp.asarray(b)
                state["a", j] = jnp.asarray(a[r0:r0 + rr])

            def compute(j=j, r0=r0, rr=rr, state=state):
                state["c", j] = np.asarray(mm(state["a", j], state["b"]))
                if not has_out:
                    c[r0:r0 + rr] = state["c", j]

            def copy_out(j=j, r0=r0, rr=rr, state=state):
                c[r0:r0 + rr] = state["c", j]

            in_chunks.append(copy_in)
            comp_chunks.append(compute)
            out_chunks.append(copy_out)
        return DeviceTask(
            device=device, copy_in=None, compute=None, copy_out=None,
            copy_in_chunks=in_chunks, compute_chunks=comp_chunks,
            copy_out_chunks=out_chunks if has_out else None)

    def execute(self, a: np.ndarray, b: np.ndarray, *,
                noise: float = 0.0, seed: int = 0,
                plan: POASPlan | None = None) -> tuple[np.ndarray, ExecutionReport]:
        """Run the co-executed GEMM.  Returns (C, report).

        Partitions run concurrently through ``OverlappedExecutor`` (real
        numerics, real overlap, bus order from the plan); the per-device
        *time* is taken from its model (optionally noised) so the simulated
        testbed reproduces the paper's timing behaviour deterministically on
        one CPU.
        """
        m, k = a.shape
        k2, n = b.shape
        assert k == k2, (a.shape, b.shape)
        p = plan or self.plan(m, n, k)
        gplan: GemmPlan = p.adapted

        rng = np.random.default_rng(seed)
        c = np.zeros((m, n), dtype=np.result_type(a.dtype, b.dtype))
        planned = p.schedule.timeline
        tasks = self._partition_tasks(a, b, c, gplan, planned)

        t0 = time.perf_counter()
        measured = OverlappedExecutor(self.devices, planned).run(tasks)
        wall = time.perf_counter() - t0

        device_times: dict[str, float] = {}
        ops_list = []
        for di, (dev, asg) in enumerate(zip(self.devices, gplan.assignments)):
            ops_list.append(asg.ops)
            if asg.m == 0:
                device_times[dev.name] = 0.0
                continue
            t = dev.total_time(asg.ops, n, k)
            if noise:
                t *= 1.0 + noise * rng.standard_normal()
            device_times[dev.name] = t
            if self.dyn is not None:
                self.dyn.observe(di, asg.ops,
                                 dev.compute(asg.ops) * (1.0 + (noise * rng.standard_normal() if noise else 0.0)))
        tl = simulate_timeline(self.devices, ops_list, n, k,
                               topology=self.topology,
                               chunks=[max(1, len(a.chunk_rows))
                                       for a in gplan.assignments])
        standalone = {d.name: d.total_time(float(m) * n * k, n, k)
                      for d in self.devices}
        rep = ExecutionReport(
            plan=p, timeline=tl,
            predicted_makespan=p.schedule.timeline.makespan,
            simulated_makespan=max(tl.makespan,
                                   max(device_times.values(), default=0.0)),
            wall_seconds=wall, standalone=standalone,
            per_device_seconds=device_times,
            measured=measured)
        return c, rep

    # -- prediction accuracy experiment (paper §5.2) ------------------------

    def prediction_errors(self, m: int, n: int, k: int, *,
                          noise: float = 0.03, seed: int = 0) -> dict[str, dict[str, float]]:
        """Per-device compute/copy/global relative error vs a noisy 'measured'
        run — reproduces Table 4's structure on the simulated testbed."""
        from .predict import relative_error
        p = self.plan(m, n, k)
        gplan: GemmPlan = p.adapted
        rng = np.random.default_rng(seed)
        out: dict[str, dict[str, float]] = {}
        for dev, asg in zip(self.devices, gplan.assignments):
            if asg.m == 0:
                continue
            pred_c = dev.compute(asg.ops)
            pred_y = dev.copy(asg.ops, n, k)
            meas_c = pred_c * (1.0 + noise * rng.standard_normal())
            meas_y = pred_y * (1.0 + 0.3 * noise * rng.standard_normal())
            out[dev.name] = {
                "compute": relative_error(pred_c, meas_c),
                "copy": relative_error(pred_y, meas_y) if pred_y else 0.0,
                "global": relative_error(pred_c + pred_y, meas_c + meas_y),
            }
        return out
