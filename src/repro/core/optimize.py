"""POAS phase 2 — *Optimize*.

The paper formulates work division as a MILP (Eqs. 1–3): minimize the
makespan ``max_x(t_c(c_x) + t_y(c_x))`` subject to ``Σ c_x = N``, ``c_x ≥ 0``
and solves it with CPLEX.  CPLEX is unavailable here; the problem class is
small (a handful of devices) and the per-device time models are monotone
non-decreasing in ``c_x``, so we replace the external solver with:

* ``solve_bisection`` — exact for *any* monotone time model (subsumes the
  paper's linear MILP): bisect on the makespan T; feasibility is "can the
  devices jointly absorb N ops, each finishing by T?", which decomposes
  per-device on uncontended topologies.  On contended topologies (the
  paper's serialized shared bus, §3.4.3/Fig. 2) the greedy priority-ordered
  feasibility check prices every candidate against the *exact* unified
  timeline engine (``core.bus``) — including chunked pipelined copies — so
  the solver optimizes precisely what the simulator reports and the
  executor replays.
* ``solve_analytic`` — closed-form active-set LP for the linear,
  independent-bus case (for cross-checking, and it is what a CPLEX run of
  Eqs. 1–4 returns).
* ``solve_local_search`` — CSP fallback for arbitrary (non-convex) models,
  per the paper's §3.2 note that backtracking/local search handles models
  that are not linear/quadratic.
* ``solve_list_schedule`` — the task-graph solver (DESIGN.md §10): the
  divisible-workload MILP does not apply to precedence-constrained DAGs,
  so work division becomes *device selection per task* — a HEFT-style list
  scheduler (upward-rank priority, earliest-finish-time placement) whose
  every candidate is priced on the same unified timeline engine, refined
  by reassignment descent (the discrete analogue of ``_descend``) or, on
  small instances, replaced outright by exhaustive enumeration.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Mapping, Sequence

import numpy as np

from .bus import (BusTopology, ClockState, GraphSimContext, GraphSimState,
                  TaskSpec, ZERO_CLOCKS, _graph_topo_order,
                  engine_finish_times, graph_finish_times)
from .device_model import DeviceProfile, LinearTimeModel, priority_order

_EPS = 1e-12
_TINY = 1e-30   # probe op count: prices fixed costs (B panel, launch) only


@dataclasses.dataclass
class OptimizeResult:
    ops: list[float]                 # c_x per device (Σ = N)
    makespan: float                  # predicted total time
    finish_times: list[float]        # per-device predicted finish
    bus: str                         # "independent" | "serialized" | custom
    iterations: int = 0

    def shares(self) -> list[float]:
        n = sum(self.ops)
        return [c / n if n else 0.0 for c in self.ops]


# ---------------------------------------------------------------------------
# Feasibility: how many ops can each device absorb within makespan T?
# Both checks price candidates on the unified timeline engine, so the
# solver, the simulator, and the executor share one source of truth.
# ---------------------------------------------------------------------------


def _max_ops_single(devices: Sequence[DeviceProfile], i: int, T: float,
                    n: int, k: int, topo: BusTopology,
                    order: Sequence[int], N: float) -> float:
    """Largest c_i with device i's engine finish <= T, no contention."""
    c = [0.0] * len(devices)

    def fin(ci: float) -> float:
        c[i] = ci
        return engine_finish_times(devices, c, n, k, topology=topo,
                                   order=order)[i]

    if fin(_TINY) > T:      # fixed costs alone (B panel, launch) miss T
        return 0.0
    if fin(float(N)) <= T:  # the whole workload fits
        return float(N)
    lo, hi = 0.0, float(N)
    for _ in range(100):
        mid = 0.5 * (lo + hi)
        if fin(mid) <= T:
            lo = mid
        else:
            hi = mid
        if hi - lo <= max(1.0, lo) * 1e-9:
            break
    return lo


def _max_ops_serialized(devices: Sequence[DeviceProfile], order: Sequence[int],
                        T: float, n: int, k: int, topo: BusTopology,
                        N: float) -> list[float]:
    """Greedy priority-ordered assignment under a contended topology.

    Device i's candidate c_i is the largest value keeping the *whole*
    partial timeline's makespan within T — evaluated on the exact engine,
    so queueing on every link, compute overlap, no-copy devices starting at
    t = 0, and pipelined chunk boundaries are all priced exactly (the old
    linearized check both over-charged no-copy devices for bus time they
    never wait on and let output copies overlap input copies).  The engine
    makespan is monotone in every c_i, so greedy-max in priority order
    maximizes the total absorbed ops for a given T.
    """
    c = [0.0] * len(devices)
    for i in order:

        def span(ci: float) -> float:
            c[i] = ci
            return max(engine_finish_times(devices, c, n, k, topology=topo,
                                           order=order))

        if span(_TINY) > T:
            c[i] = 0.0
            continue
        if span(float(N)) <= T:
            c[i] = float(N)
            continue
        lo, hi = 0.0, float(N)
        for _ in range(100):
            mid = 0.5 * (lo + hi)
            if span(mid) <= T:
                lo = mid
            else:
                hi = mid
            if hi - lo <= max(1.0, lo) * 1e-9:
                break
        c[i] = lo
    return c


# ---------------------------------------------------------------------------
# Exact bisection solver
# ---------------------------------------------------------------------------


def solve_bisection(devices: Sequence[DeviceProfile], N: float, *,
                    n: int, k: int,
                    bus: str | BusTopology = "independent",
                    tol: float = 1e-9, polish: bool = True) -> OptimizeResult:
    """Minimize makespan by bisecting on T.

    ``bus`` is a legacy spec string ("independent" | "serialized") or a
    ``BusTopology``.  Feasibility prices every candidate on the exact
    unified timeline engine, so the check is exact for any topology and for
    chunked pipelined copies; the contended-topology result is additionally
    *polished* by coordinate descent on the same engine (the greedy
    priority-ordered assignment is not always the global optimum).
    """
    spec = bus.spec if isinstance(bus, BusTopology) else bus
    if N <= 0:
        z = [0.0] * len(devices)
        return OptimizeResult(z, 0.0, z, spec)
    topo = BusTopology.from_spec(bus, devices)
    order = priority_order(devices)
    contended = topo.is_contended()

    def capacity(T: float) -> list[float]:
        if contended:
            return _max_ops_serialized(devices, order, T, n, k, topo, N)
        return [_max_ops_single(devices, i, T, n, k, topo, order, N)
                for i in range(len(devices))]

    # bracket: every single-device assignment is feasible at its own engine
    # makespan; on a contended topology the greedy may interleave devices,
    # so the safe upper bound is the serial sum of those makespans.
    def single(i: int) -> float:
        one = [0.0] * len(devices)
        one[i] = N
        return max(engine_finish_times(devices, one, n, k, topology=topo,
                                       order=order))

    singles = [single(i) for i in range(len(devices))]
    t_lo = 0.0
    t_hi = sum(singles) if contended else min(singles)
    iters = 0
    for _ in range(200):
        iters += 1
        mid = 0.5 * (t_lo + t_hi)
        if sum(capacity(mid)) >= N:
            t_hi = mid
        else:
            t_lo = mid
        if t_hi - t_lo <= max(tol, t_hi * 1e-10):
            break
    caps = capacity(t_hi)
    total = sum(caps)
    # Scale back surplus so Σ c = N exactly, preferring to trim the devices
    # with the largest marginal cost (keeps the makespan at T*).
    if total > 0:
        scale = N / total
        ops = [c * scale for c in caps]
    else:  # pragma: no cover - degenerate
        ops = [N / len(devices)] * len(devices)
    if polish and contended and len(devices) > 1:
        ops = _descend(devices, ops, n, k, topo, order,
                       step0=N / 64.0, max_evals=1500)
    finish = _finish_times(devices, ops, n, k, topo, order)
    best = OptimizeResult(ops, max(finish), finish, spec, iterations=iters)
    # Degenerate single-device assignments are feasible points the split
    # can lose to on small workloads (copy overheads don't amortize — the
    # paper's §3.4.3 "significant amount of work" caveat).  Take the min.
    for i in range(len(devices)):
        one = [0.0] * len(devices)
        one[i] = N
        f1 = _finish_times(devices, one, n, k, topo, order)
        if max(f1) < best.makespan:
            best = OptimizeResult(one, max(f1), f1, spec, iterations=iters)
    return best


def _descend(devices: Sequence[DeviceProfile], ops0: Sequence[float],
             n: int, k: int, bus: str | BusTopology, order: Sequence[int], *,
             step0: float, max_evals: int) -> list[float]:
    """Pairwise-transfer coordinate descent on the exact timeline makespan."""
    ops = list(ops0)
    m = len(devices)

    def makespan(v):
        return max(_finish_times(devices, v, n, k, bus, order))

    best = makespan(ops)
    step = step0
    evals = 0
    while step > sum(ops0) * 1e-10 and evals < max_evals:
        improved = False
        for src in range(m):
            if ops[src] <= 0:
                continue
            for dst in range(m):
                if src == dst:
                    continue
                delta = min(step, ops[src])
                cand = list(ops)
                cand[src] -= delta
                cand[dst] += delta
                t = makespan(cand)
                evals += 1
                if t < best - _EPS:
                    ops, best, improved = cand, t, True
        if not improved:
            step *= 0.5
    return ops


def _finish_times(devices: Sequence[DeviceProfile], ops: Sequence[float],
                  n: int, k: int, bus: str | BusTopology,
                  order: Sequence[int] | None = None) -> list[float]:
    """Per-device finish times — the unified engine, nothing else.

    This used to be an independent re-implementation of the Fig. 2 timeline
    that (a) charged no-copy devices for bus queue time they never wait on
    and (b) reset the output-copy clock to 0, letting outputs overlap
    inputs on the supposedly serialized bus; both made the solver optimize
    a different objective than ``simulate_timeline`` measured.  Delegating
    to ``engine_finish_times`` makes solver/simulator agreement exact by
    construction."""
    return engine_finish_times(devices, ops, n, k, topology=bus, order=order)


# ---------------------------------------------------------------------------
# Analytic LP (linear models, independent bus)
# ---------------------------------------------------------------------------


def solve_analytic(devices: Sequence[DeviceProfile], N: float, *,
                   n: int, k: int) -> OptimizeResult:
    """Closed-form: at the optimum all devices with c_x>0 finish together.

    With linear t_x(c) = α_x c + β_x (α folds compute+copy slopes, β the
    intercepts), equalizing finish times gives
        T* = (N + Σ β_x/α_x) / (Σ 1/α_x)
    over the active set; devices whose β_x ≥ T* are dropped iteratively.

    Zero-slope devices (``LinearTimeModel(a=0, b=...)`` — constant time
    regardless of load) would divide by zero in the LP; they are held out
    of the active set and compared as "hand it everything" candidates
    (a zero-slope device finishes at β no matter how much it absorbs).
    """
    alphas, betas = [], []
    for d in devices:
        t0 = d.total_time(0.0, n, k)
        t1 = d.total_time(1e9, n, k)
        alphas.append((t1 - t0) / 1e9)
        betas.append(t0)
    zero = [i for i in range(len(devices)) if alphas[i] <= 0.0]
    active = [i for i in range(len(devices)) if alphas[i] > 0.0]
    T = math.inf
    if active:
        while True:
            num = N + sum(betas[i] / alphas[i] for i in active)
            den = sum(1.0 / alphas[i] for i in active)
            T = num / den
            drop = [i for i in active if betas[i] >= T - _EPS]
            if not drop:
                break
            active = [i for i in active if i not in drop]
            if not active:
                T = math.inf
                break
    if zero:
        j = min(zero, key=lambda i: betas[i])
        if betas[j] <= T:   # constant-time device beats (or is) the LP
            ops = [0.0] * len(devices)
            ops[j] = N
            finish = _finish_times(devices, ops, n, k, "independent")
            return OptimizeResult(ops, max(finish), finish, "independent")
    if not active:  # pragma: no cover
        raise RuntimeError("no device can make progress")
    ops = [0.0] * len(devices)
    for i in active:
        ops[i] = (T - betas[i]) / alphas[i]
    # normalize tiny numerical drift
    s = sum(ops)
    ops = [c * (N / s) for c in ops]
    finish = _finish_times(devices, ops, n, k, "independent")
    return OptimizeResult(ops, max(finish), finish, "independent")


# ---------------------------------------------------------------------------
# Local-search CSP fallback (paper §3.2: non-linear models)
# ---------------------------------------------------------------------------


def solve_local_search(devices: Sequence[DeviceProfile], N: float, *,
                       n: int, k: int, bus: str | BusTopology = "independent",
                       iters: int = 4000, seed: int = 0) -> OptimizeResult:
    """Coordinate-descent on op shares.  Works for arbitrary monotone models;
    used as a CSP-style fallback and as an independent check of bisection."""
    import numpy as np
    rng = np.random.default_rng(seed)
    m = len(devices)
    bus = BusTopology.from_spec(bus, devices)
    order = priority_order(devices)

    def makespan(ops):
        return max(_finish_times(devices, list(ops), n, k, bus, order))

    ops = np.full(m, N / m)
    best = makespan(ops)
    step = N / 4.0
    it = 0
    while step > N * 1e-9 and it < iters:
        improved = False
        for src in range(m):
            for dst in range(m):
                if src == dst or ops[src] <= 0:
                    continue
                delta = min(step, ops[src])
                cand = ops.copy()
                cand[src] -= delta
                cand[dst] += delta
                t = makespan(cand)
                it += 1
                if t < best - _EPS:
                    ops, best, improved = cand, t, True
        if not improved:
            step *= 0.5
    finish = _finish_times(devices, list(ops), n, k, bus, order)
    return OptimizeResult(list(ops), max(finish), finish, bus.spec,
                          iterations=it)


# ---------------------------------------------------------------------------
# HEFT-style list scheduler for task graphs (DESIGN.md §10)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class GraphScheduleResult(OptimizeResult):
    """``OptimizeResult`` plus the task-graph solution: which device each
    task runs on (``assign``), the topological priority list the links are
    serialized in (``order``), and per-task predicted finish times.  The
    inherited ``ops`` are per-device op totals, so share-based consumers
    (dynamic load shedding asserts, dashboards) work unchanged."""

    assign: list[int] = dataclasses.field(default_factory=list)
    order: list[int] = dataclasses.field(default_factory=list)
    task_finish: list[float] = dataclasses.field(default_factory=list)


def _upward_ranks(devices: Sequence[DeviceProfile],
                  tasks: Sequence[TaskSpec],
                  edges: Sequence[tuple[int, int]]) -> list[float]:
    """HEFT upward rank: mean compute cost plus the most expensive
    downstream chain, edges priced at the mean staged-transfer cost.
    Device-independent, so the priority list is fixed before placement.

    Vectorized: ``wbar``/``cbar`` are per-task numpy arrays accumulated
    device-by-device in the same order the scalar ``sum`` ran, and the
    downstream recurrence runs level-synchronously with per-level CSR
    child arrays and ``np.maximum.reduceat``.  Every float operation
    keeps the sequential version's order and grouping, so the ranks —
    and therefore the priority list — are bit-identical to it (max is
    exact, and ``max_c(cbar + rank_c) == cbar + max_c(rank_c)`` because
    IEEE addition is monotone)."""
    n = len(tasks)
    children: list[list[int]] = [[] for _ in range(n)]
    for u, v in edges:
        children[u].append(v)
    ops = np.array([float(t.ops) for t in tasks])
    out_b = np.array([float(t.out_bytes) for t in tasks])

    acc = np.zeros(n)
    for d in devices:
        tm = d.compute
        if isinstance(tm, LinearTimeModel):
            acc = acc + (tm.a * ops + tm.b)
        else:   # nonlinear model: per-task calls, same accumulation order
            acc = acc + np.array([tm(t.ops) for t in tasks])
    wbar = acc / len(devices)

    copiers = [d for d in devices
               if not math.isinf(d.copy.bandwidth_bytes_per_s)]
    if copiers:
        cacc = np.zeros(n)
        for d in copiers:
            cacc = cacc + (2.0 * out_b / d.copy.bandwidth_bytes_per_s
                           + d.copy.latency_s)
        cbar = np.where(out_b > 0.0, cacc / len(copiers), 0.0)
    else:
        cbar = np.zeros(n)

    # level-synchronous recurrence over the reversed topological order:
    # level 0 = leaves (tail 0), level L depends only on levels < L
    level = [0] * n
    for i in reversed(_graph_topo_order(n, edges)):
        if children[i]:
            level[i] = 1 + max(level[c] for c in children[i])
    rank = wbar.copy()   # leaves: rank = wbar
    by_level: dict[int, list[int]] = {}
    for i in range(n):
        if level[i] > 0:
            by_level.setdefault(level[i], []).append(i)
    for lv in sorted(by_level):
        nodes = by_level[lv]
        kids = [c for i in nodes for c in children[i]]
        offs = np.cumsum([0] + [len(children[i]) for i in nodes])[:-1]
        maxchild = np.maximum.reduceat(rank[kids], offs)
        nd = np.array(nodes)
        rank[nd] = wbar[nd] + (cbar[nd] + maxchild)
    return rank.tolist()


def _rank_order(devices: Sequence[DeviceProfile], tasks: Sequence[TaskSpec],
                edges: Sequence[tuple[int, int]]) -> list[int]:
    """Decreasing upward rank, ties broken by topological position (so the
    order is always a valid linearization even under zero-cost ties)."""
    topo_pos = {i: p for p, i in
                enumerate(_graph_topo_order(len(tasks), edges))}
    rank = _upward_ranks(devices, tasks, edges)
    return sorted(range(len(tasks)), key=lambda i: (-rank[i], topo_pos[i]))


# -- incremental EFT machinery (DESIGN.md §12) ------------------------------

_SNAP_EVERY = 24   # order positions between simulation-state snapshots


def _advance_snapped(st: GraphSimState, snaps: dict[int, GraphSimState],
                     stop: int, min_key: int = 0) -> None:
    """Advance ``st`` to order position ``stop``, dropping an O(n) clone
    into ``snaps`` at every ``_SNAP_EVERY`` boundary crossed (boundaries
    below ``min_key`` snapshots are skipped — descent never rewinds below
    the earliest movable task or movable-task parent)."""
    while st.pos < stop:
        nxt = (st.pos // _SNAP_EVERY + 1) * _SNAP_EVERY
        if nxt > stop:
            nxt = stop
        st.advance(nxt)
        if nxt % _SNAP_EVERY == 0 and nxt // _SNAP_EVERY >= min_key:
            snaps[nxt // _SNAP_EVERY] = st.clone()


def _rewind(st: GraphSimState, snaps: dict[int, GraphSimState],
            m: int) -> GraphSimState:
    """Resume from snapshot ``m`` carrying ``st``'s *live* assign/placed
    (the snapshot's own copies are stale), invalidating later snapshots."""
    for k in [k for k in snaps if k > m]:
        del snaps[k]
    base = snaps[m].clone()
    base.assign = st.assign
    base.placed = st.placed
    return base


def _commit_place(st: GraphSimState, snaps: dict[int, GraphSimState],
                  pos: int, i: int, j: int,
                  fp: int | None) -> GraphSimState:
    """Commit task ``i`` on device ``j`` at order position ``pos``: extend
    the checkpoint through ``pos`` when no earlier host-stage decision
    flips (``fp`` is None), else re-simulate from the nearest snapshot at
    or before the flip position."""
    st.assign[i] = j
    st.placed[i] = 1
    if fp is not None:
        st = _rewind(st, snaps, fp // _SNAP_EVERY)
    _advance_snapped(st, snaps, pos + 1)
    return st


def _price_flip(st: GraphSimState, snaps: dict[int, GraphSimState],
                pos: int, i: int, j: int, fp: int) -> float:
    """Price candidate ``(i, j)`` whose placement flips an earlier
    producer's host-stage decision: re-simulate positions [snapshot, pos]
    on a throwaway clone under the tentative assignment."""
    tmp = snaps[fp // _SNAP_EVERY].clone()
    old_a, old_p = st.assign[i], st.placed[i]
    st.assign[i] = j
    st.placed[i] = 1
    tmp.assign = st.assign
    tmp.placed = st.placed
    tmp.advance(pos + 1)
    st.assign[i] = old_a
    st.placed[i] = old_p
    return tmp.finish[i]


class _DeviceArrays:
    """Per-solve device constants for the vectorized EFT candidate batch —
    the context's per-(device, task) duration tables as (d, n) numpy
    arrays plus per-device masks, one lane per candidate device."""

    __slots__ = ("idx", "has_copy", "ext_in", "par_in", "stage_out", "comp",
                 "same_link")

    def __init__(self, ctx: GraphSimContext):
        self.idx = np.arange(len(ctx.devices))
        self.has_copy = np.array(ctx.has_copy, dtype=bool)
        self.ext_in = np.array(ctx.ext_in)
        self.par_in = np.array(ctx.par_in)
        self.stage_out = np.array(ctx.stage_out)
        self.comp = np.array(ctx.comp)
        self.same_link = np.array([a == b for a, b in
                                   zip(ctx.in_lid, ctx.out_lid)])


def _peek_batch(st: GraphSimState, da: _DeviceArrays, i: int) -> np.ndarray:
    """Vectorized ``GraphSimState.peek_finish`` over every device at once.

    Each numpy lane applies the identical float operations in the
    identical order to the scalar path (durations come from the shared
    per-(device, task) tables; elementwise IEEE double ops match Python
    floats exactly), so device selection sees bit-identical finish times —
    asserted transitively by the incremental-vs-from-scratch equality
    checks in the bench and the property tests."""
    ctx = st.ctx
    t = ctx.tasks[i]
    nd = len(ctx.devices)
    lc = np.array([st.link_clock_id(lid) for lid in ctx.in_lid])
    dev_clk = np.array([st.dev_clock_id(j) for j in range(nd)])
    touched = np.zeros(nd, dtype=bool)   # lanes whose in-link clock moved
    ready = np.zeros(nd)

    if t.in_bytes > 0.0:
        end = lc + da.ext_in[:, i]
        lc = np.where(da.has_copy, end, lc)
        touched = touched | da.has_copy
        ready = np.where(da.has_copy, end, ready)

    placed, assign = st.placed, st.assign
    for u in ctx.parents[i]:
        if not placed[u]:
            continue
        same = da.idx == assign[u]
        ce_u, av_u = st.compute_end[u], st.avail[u]
        if not ctx.has_out[u]:
            r = np.where(same, ce_u, av_u)
        else:
            s = np.maximum(lc, av_u)
            end = s + da.par_in[:, u]
            copy_lane = da.has_copy & ~same
            lc = np.where(copy_lane, end, lc)
            touched = touched | copy_lane
            r = np.where(same, ce_u, np.where(da.has_copy, end, av_u))
        ready = np.maximum(ready, r)

    s = np.maximum(dev_clk, ready)
    ce = s + da.comp[:, i]

    if not ctx.has_out[i]:
        return ce
    kids = [c for c in ctx.children[i] if placed[c]]
    if kids:
        ka = np.array([assign[c] for c in kids])
        need = da.has_copy & (ka[None, :] != da.idx[:, None]).any(axis=1)
    else:
        need = da.has_copy.copy()   # pseudo-sink: output returns to host
    out_clk = np.array([st.link_clock_id(lid) for lid in ctx.out_lid])
    out_clk = np.where(da.same_link & touched, lc, out_clk)
    s2 = np.maximum(out_clk, ce)
    return np.where(need, s2 + da.stage_out[:, i], ce)


def _eft_place(ctx: GraphSimContext, assign: Sequence[int],
               pinned: Mapping[int, int]) -> tuple[GraphSimState, int]:
    """Rank-priority EFT placement on the incremental engine: one
    ``GraphSimState`` swept along the priority order, each (task, device)
    candidate priced by the vectorized peek in O(deg·d) — falling back to
    a snapshot re-simulation only when the candidate flips an earlier
    producer's host-stage decision (DESIGN.md §12).  Selection and
    resulting assignments are bit-identical to pricing every prefix from
    scratch; returns the final state and the candidate-evaluation count.
    """
    ndev = len(ctx.devices)
    st = GraphSimState(ctx, assign, placed=list(ctx.ext))
    snaps = {0: st.clone()}
    da = _DeviceArrays(ctx)
    evals = 0
    for pos, i in enumerate(ctx.order):
        if i in pinned:
            if i not in ctx.ext:   # frozen assignment still gets simulated
                st = _commit_place(st, snaps, pos, i, st.assign[i],
                                   st.stage_flip_pos(i, st.assign[i]))
            continue
        if i in ctx.ext:
            # finish is fixed externally: every device prices identically,
            # so the ascending scan commits device 0 (the tie rule)
            evals += ndev
            st = _commit_place(st, snaps, pos, i, 0,
                               st.stage_flip_pos(i, 0))
            continue
        flips = [st.stage_flip_pos(i, j) for j in range(ndev)]
        fin = _peek_batch(st, da, i)
        best_j, best_t = 0, math.inf
        for j in range(ndev):
            t = (float(fin[j]) if flips[j] is None
                 else _price_flip(st, snaps, pos, i, j, flips[j]))
            evals += 1
            if t < best_t - _EPS:
                best_j, best_t = j, t
        st = _commit_place(st, snaps, pos, i, best_j, flips[best_j])
    return st, evals


def _descend_assign(ctx: GraphSimContext, assign: Sequence[int], *,
                    max_evals: int = 2000,
                    free: Sequence[int] | None = None
                    ) -> tuple[list[int], int, float]:
    """Reassignment descent on the exact graph makespan — ``_descend``'s
    pairwise-transfer loop in discrete per-task coordinates: move one task
    to another device, keep any strict improvement, repeat to a local
    optimum.  ``free`` restricts the moves to the given task indices
    (partial solves pin the frozen tasks).

    Each candidate move re-prices only the suffix of the priority order
    from the moved task's position (or from the earliest producer whose
    host-stage decision the move flips, if earlier), resumed from the
    nearest ``GraphSimState`` snapshot — positions before it are provably
    unaffected, so the makespans are exactly the from-scratch values.
    Returns ``(assign, evals, makespan)`` — the local optimum's makespan
    is the last accepted evaluation, so callers need no re-pricing."""
    movable = list(free) if free is not None else list(range(ctx.n))
    end = len(ctx.order)
    st = GraphSimState(ctx, assign)
    # descent never rewinds below the earliest movable task or simulated
    # parent of one — skip snapshots below that floor (a partial re-solve
    # freezes most of the order; this keeps its setup cost at O(free))
    floor = end
    for i in movable:
        floor = min(floor, ctx.pos_of[i])
        for u in ctx.parents[i]:
            if u not in ctx.ext:
                p = ctx.pos_of.get(u)
                if p is not None:
                    floor = min(floor, p)
    min_key = floor // _SNAP_EVERY
    snaps: dict[int, GraphSimState] = {}
    if min_key == 0:
        snaps[0] = st.clone()
    _advance_snapped(st, snaps, end, min_key)
    best = max(st.finish)
    evals = 1
    improved = True
    # the budget binds mid-sweep, not only between sweeps: a single sweep
    # is len(free)·(d-1) candidate moves, which at 10^3+ nodes dwarfs any
    # reasonable budget — checking only in the while-condition made
    # ``max_evals`` a dead letter exactly where it matters (the capped
    # re-solve on a straggler's worker thread, DESIGN.md §11/§12)
    while improved and evals < max_evals:
        improved = False
        for i in movable:
            if evals >= max_evals:
                break
            pi = ctx.pos_of[i]
            for j in range(len(ctx.devices)):
                if evals >= max_evals:
                    break
                old = st.assign[i]
                if j == old:
                    continue
                fp = st.stage_flip_pos(i, j)
                p0 = pi if fp is None or fp > pi else fp
                m = p0 // _SNAP_EVERY
                tmp = snaps[m].clone()
                st.assign[i] = j
                tmp.assign = st.assign
                tmp.placed = st.placed
                tmp.advance(end)
                t = max(tmp.finish)
                evals += 1
                if t < best - _EPS:
                    st = _rewind(st, snaps, m)
                    _advance_snapped(st, snaps, end, min_key)
                    best, improved = t, True
                else:
                    st.assign[i] = old
    return st.assign, evals, best


def solve_list_schedule(devices: Sequence[DeviceProfile],
                        tasks: Sequence[TaskSpec],
                        edges: Sequence[tuple[int, int]], *,
                        bus: str | BusTopology = "serialized",
                        priority: str = "rank",
                        refine: bool = True,
                        exhaustive_limit: int = 1024,
                        pinned: Mapping[int, int] | None = None,
                        ext: Mapping[int, tuple[float, float]] | None = None,
                        clocks: ClockState = ZERO_CLOCKS,
                        seed_assign: Sequence[int] | None = None,
                        max_evals: int = 2000) -> GraphScheduleResult:
    """Minimize a task graph's makespan by list scheduling on the engine.

    HEFT shape: tasks are placed in decreasing upward-rank order
    (``priority="rank"``); each is assigned the device giving it the
    earliest engine finish time over the partial schedule — so link
    queueing, host staging of cross-device edges, and carried clocks are
    priced exactly as the simulator reports and the executor replays.
    ``priority="topo"`` is the naive baseline: plain topological order
    with myopic device selection (each task alone on an empty timeline —
    ignores contention and edge locality), the benchmark's strawman.

    Refinement: when the free assignment space is small
    (``len(devices)**len(free) <= exhaustive_limit``) the solver
    enumerates every assignment under the same priority order and returns
    the exact optimum; otherwise reassignment descent polishes the HEFT
    placement to a local optimum on the same engine makespan.

    Partial solve (mid-graph re-planning, DESIGN.md §11): ``pinned`` maps
    task index -> device index for tasks whose assignment is *frozen*
    (completed or already running); only the remaining tasks are placed and
    refined.  ``ext`` prices the frozen tasks externally (their measured
    ``(compute_end, avail)`` — see ``build_graph_timeline``), ``clocks``
    carries the measured link/device clocks the frontier must queue behind,
    and ``seed_assign`` seeds the refinement from the currently-executing
    plan so the re-solve starts no worse than the lock-in it replaces.
    When a seed is given the degenerate all-one-device sweeps are skipped —
    the seed already provides the quality floor, and a partial solve runs
    inside a live splice where solver latency stalls the straggler's worker
    (``max_evals`` caps each descent for the same reason).
    """
    topo = BusTopology.from_spec(bus, devices)
    spec = bus.spec if isinstance(bus, BusTopology) else topo.spec
    n = len(tasks)
    if n == 0:
        z = [0.0] * len(devices)
        return GraphScheduleResult(z, 0.0, z, spec)
    pinned = dict(pinned) if pinned else {}
    free = [i for i in range(n) if i not in pinned]
    if priority == "rank":
        order = _rank_order(devices, tasks, edges)
    elif priority == "topo":
        order = _graph_topo_order(n, edges)
    else:
        raise ValueError(f"unknown priority {priority!r} "
                         "(expected 'rank' or 'topo')")

    def finish(a, o) -> list[float]:
        return graph_finish_times(devices, tasks, edges, a, topology=topo,
                                  order=o, clocks=clocks, ext=ext)

    assign = [-1] * n
    for i, j in pinned.items():
        assign[i] = j
    evals = 0
    ctx = GraphSimContext(devices, tasks, edges, topo, order, clocks, ext)
    if priority == "topo":
        solo = [-1] * n   # scratch assignment, reused across candidates
        for i in order:
            if i in pinned:
                continue
            best_j, best_t = 0, math.inf
            for j in range(len(devices)):
                # myopic: the task alone, an empty timeline
                solo[i] = j
                t = graph_finish_times(devices, tasks, edges, solo,
                                       topology=topo, order=[i])[i]
                evals += 1
                if t < best_t - _EPS:
                    best_j, best_t = j, t
            solo[i] = -1
            assign[i] = best_j
    else:
        st, e = _eft_place(ctx, assign, pinned)
        assign = st.assign
        evals += e

    def makespan(a) -> float:
        return max(finish(a, order))

    if refine and free:
        # the exhaustive branch honours max_evals too: a latency-capped
        # partial solve (mid-graph splice) must not sneak up to
        # exhaustive_limit full-graph simulations through a small free set
        if len(devices) ** len(free) <= min(exhaustive_limit, max_evals):
            best_a, best_t = list(assign), makespan(assign)
            evals += 1
            for combo in itertools.product(range(len(devices)),
                                           repeat=len(free)):
                cand = list(assign)
                for i, j in zip(free, combo):
                    cand[i] = j
                t = makespan(cand)
                evals += 1
                if t < best_t - _EPS:
                    best_a, best_t = list(cand), t
            assign = best_a
        else:
            # Descend from the EFT placement AND from every degenerate
            # all-one-device assignment (the §3.4.3 caveat, in DAG form):
            # EFT's greedy early finishes can strand the schedule in a
            # local optimum *worse* than the best single device, and
            # single-task moves cannot escape it (moving one task of a
            # chain adds edge copies before its neighbours follow).
            # Seeding from the degenerate points both restores the
            # never-worse-than-one-device floor and lets the descent peel
            # whole chains off the fastest device one improvement at a
            # time.  Partial solves additionally seed from the plan being
            # replaced (``seed_assign``), so a re-plan is never worse than
            # staying locked in — under the re-fitted models.
            seeds = [list(assign)]
            budget = max_evals
            if seed_assign is not None:
                seeds.append(list(seed_assign))
                # the straggler-rescue seed: every free task on the fastest
                # (re-fitted) device — the shape the re-plan usually wants
                # when one device just slowed down, and one the capped
                # descent cannot reliably reach from EFT local optima
                fastest = max(range(len(devices)),
                              key=lambda j: devices[j].effective_speed)
                rescue = list(assign)
                for i in free:
                    rescue[i] = fastest
                seeds.append(rescue)
                # a partial solve runs inside a live splice: split the eval
                # budget across the seeds instead of paying it per seed
                budget = max(40, max_evals // len(seeds))
            else:
                for j in range(len(devices)):
                    one = list(assign)
                    for i in free:
                        one[i] = j
                    seeds.append(one)
            best_a, best_t = None, math.inf
            for seed in seeds:
                cand, e, t = _descend_assign(ctx, seed, free=free,
                                             max_evals=budget)
                evals += e
                if t < best_t - _EPS:
                    best_a, best_t = cand, t
            assign = best_a

    task_finish = finish(assign, order)
    ops = [0.0] * len(devices)
    dev_finish = [0.0] * len(devices)
    for i, t in enumerate(tasks):
        if assign[i] < 0:
            continue
        ops[assign[i]] += float(t.ops)
        dev_finish[assign[i]] = max(dev_finish[assign[i]], task_finish[i])
    return GraphScheduleResult(ops=ops, makespan=max(task_finish),
                               finish_times=dev_finish, bus=spec,
                               iterations=evals, assign=list(assign),
                               order=list(order),
                               task_finish=list(task_finish))
