"""POAS phase 2 — *Optimize*.

The paper formulates work division as a MILP (Eqs. 1–3): minimize the
makespan ``max_x(t_c(c_x) + t_y(c_x))`` subject to ``Σ c_x = N``, ``c_x ≥ 0``
and solves it with CPLEX.  CPLEX is unavailable here; the problem class is
small (a handful of devices) and the per-device time models are monotone
non-decreasing in ``c_x``, so we replace the external solver with:

* ``solve_bisection`` — exact for *any* monotone time model (subsumes the
  paper's linear MILP): bisect on the makespan T; feasibility is "can the
  devices jointly absorb N ops, each finishing by T?", which decomposes
  per-device because the objective is a max.  Supports the serialized
  shared-bus model (paper §3.4.3/Fig. 2) via a greedy priority-ordered
  feasibility check.
* ``solve_analytic`` — closed-form active-set LP for the linear,
  independent-bus case (for cross-checking, and it is what a CPLEX run of
  Eqs. 1–4 returns).
* ``solve_local_search`` — CSP fallback for arbitrary (non-convex) models,
  per the paper's §3.2 note that backtracking/local search handles models
  that are not linear/quadratic.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from .device_model import DeviceProfile, priority_order

_EPS = 1e-12


@dataclasses.dataclass
class OptimizeResult:
    ops: list[float]                 # c_x per device (Σ = N)
    makespan: float                  # predicted total time
    finish_times: list[float]        # per-device predicted finish
    bus: str                         # "independent" | "serialized"
    iterations: int = 0

    def shares(self) -> list[float]:
        n = sum(self.ops)
        return [c / n if n else 0.0 for c in self.ops]


# ---------------------------------------------------------------------------
# Feasibility: how many ops can each device absorb within makespan T?
# ---------------------------------------------------------------------------


def _max_ops_independent(dev: DeviceProfile, T: float, n: int, k: int) -> float:
    """Largest c with compute(c) + copy(c) <= T, independent bus."""
    lo, hi = 0.0, 1.0
    if dev.total_time(0.0, n, k) > T:
        return 0.0
    # exponential search for an upper bound
    while dev.total_time(hi, n, k) <= T and hi < 1e24:
        hi *= 2.0
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        if dev.total_time(mid, n, k) <= T:
            lo = mid
        else:
            hi = mid
        if hi - lo <= max(1.0, lo) * 1e-12:
            break
    return lo


def _max_ops_serialized(devices: Sequence[DeviceProfile], order: Sequence[int],
                        T: float, n: int, k: int) -> list[float]:
    """Greedy priority-ordered assignment under the shared-bus model.

    Copies serialize on one bus in priority order (paper Fig. 2): device i's
    input copy starts when device i-1's finishes; compute overlaps other
    devices' copies; output copies are likewise serialized in priority order
    after compute.  We conservatively require, for each device,

        bus_in_end_i + compute_i + out_copy_i <= T

    and additionally that output copies, executed in priority order, all
    finish by T.  Monotone in every c_i, so greedy-max per device in priority
    order maximizes total absorbed ops for a given T.
    """
    c = [0.0] * len(devices)
    bus_t = 0.0
    # input copies serialized in priority order
    for i in order:
        dev = devices[i]
        # binary search largest c_i such that
        #   bus_t + in_time(c_i) + compute(c_i) + out_time(c_i) <= T
        def finish(ci: float) -> float:
            return (bus_t + dev.copy.in_time(ci, n, k) + dev.compute(ci)
                    + dev.copy.out_time(ci, n, k))
        if finish(0.0) > T:
            continue
        lo, hi = 0.0, 1.0
        while finish(hi) <= T and hi < 1e24:
            hi *= 2.0
        for _ in range(200):
            mid = 0.5 * (lo + hi)
            if finish(mid) <= T:
                lo = mid
            else:
                hi = mid
            if hi - lo <= max(1.0, lo) * 1e-12:
                break
        c[i] = lo
        bus_t += dev.copy.in_time(lo, n, k)
    return c


# ---------------------------------------------------------------------------
# Exact bisection solver
# ---------------------------------------------------------------------------


def solve_bisection(devices: Sequence[DeviceProfile], N: float, *,
                    n: int, k: int, bus: str = "independent",
                    tol: float = 1e-9, polish: bool = True) -> OptimizeResult:
    """Minimize makespan by bisecting on T.

    Exact for monotone time models on an independent bus.  For the serialized
    shared bus the feasibility check uses the paper's conservative linearized
    serialization (each device charged for the copies queued ahead of it);
    the result is then *polished* by coordinate descent under the exact
    Fig.-2 timeline, which closes the small gap the linearization leaves.
    """
    if N <= 0:
        z = [0.0] * len(devices)
        return OptimizeResult(z, 0.0, z, bus)
    order = priority_order(devices)

    def capacity(T: float) -> list[float]:
        if bus == "serialized":
            return _max_ops_serialized(devices, order, T, n, k)
        return [_max_ops_independent(d, T, n, k) for d in devices]

    # bracket: T_hi = fastest single device doing everything
    t_lo = 0.0
    t_hi = min(d.total_time(N, n, k) for d in devices)
    if bus == "serialized":
        t_hi = max(t_hi, sum(d.copy.in_time(N, n, k) for d in devices)
                   + max(d.compute(N) for d in devices)
                   + sum(d.copy.out_time(N, n, k) for d in devices))
    iters = 0
    for _ in range(200):
        iters += 1
        mid = 0.5 * (t_lo + t_hi)
        if sum(capacity(mid)) >= N:
            t_hi = mid
        else:
            t_lo = mid
        if t_hi - t_lo <= max(tol, t_hi * 1e-10):
            break
    caps = capacity(t_hi)
    total = sum(caps)
    # Scale back surplus so Σ c = N exactly, preferring to trim the devices
    # with the largest marginal cost (keeps the makespan at T*).
    if total > 0:
        scale = N / total
        ops = [c * scale for c in caps]
    else:  # pragma: no cover - degenerate
        ops = [N / len(devices)] * len(devices)
    if polish and bus == "serialized" and len(devices) > 1:
        ops = _descend(devices, ops, n, k, bus, order,
                       step0=N / 64.0, max_evals=1500)
    finish = _finish_times(devices, ops, n, k, bus, order)
    best = OptimizeResult(ops, max(finish), finish, bus, iterations=iters)
    # Degenerate single-device assignments are feasible points the split
    # can lose to on small workloads (copy overheads don't amortize — the
    # paper's §3.4.3 "significant amount of work" caveat).  Take the min.
    for i in range(len(devices)):
        one = [0.0] * len(devices)
        one[i] = N
        f1 = _finish_times(devices, one, n, k, bus, order)
        if max(f1) < best.makespan:
            best = OptimizeResult(one, max(f1), f1, bus, iterations=iters)
    return best


def _descend(devices: Sequence[DeviceProfile], ops0: Sequence[float],
             n: int, k: int, bus: str, order: Sequence[int], *,
             step0: float, max_evals: int) -> list[float]:
    """Pairwise-transfer coordinate descent on the exact timeline makespan."""
    ops = list(ops0)
    m = len(devices)

    def makespan(v):
        return max(_finish_times(devices, v, n, k, bus, order))

    best = makespan(ops)
    step = step0
    evals = 0
    while step > sum(ops0) * 1e-10 and evals < max_evals:
        improved = False
        for src in range(m):
            if ops[src] <= 0:
                continue
            for dst in range(m):
                if src == dst:
                    continue
                delta = min(step, ops[src])
                cand = list(ops)
                cand[src] -= delta
                cand[dst] += delta
                t = makespan(cand)
                evals += 1
                if t < best - _EPS:
                    ops, best, improved = cand, t, True
        if not improved:
            step *= 0.5
    return ops


def _finish_times(devices: Sequence[DeviceProfile], ops: Sequence[float],
                  n: int, k: int, bus: str,
                  order: Sequence[int] | None = None) -> list[float]:
    if bus == "independent":
        return [d.total_time(c, n, k) if c > 0 else 0.0
                for d, c in zip(devices, ops)]
    order = list(order if order is not None else priority_order(devices))
    finish = [0.0] * len(devices)
    bus_t = 0.0
    compute_end = {}
    for i in order:
        d, c = devices[i], ops[i]
        if c <= 0:
            continue
        bus_t += d.copy.in_time(c, n, k)
        compute_end[i] = bus_t + d.compute(c)
    out_t = 0.0
    for i in order:
        d, c = devices[i], ops[i]
        if c <= 0:
            continue
        out_start = max(out_t, compute_end[i])
        out_t = out_start + d.copy.out_time(c, n, k)
        finish[i] = out_t
    return finish


# ---------------------------------------------------------------------------
# Analytic LP (linear models, independent bus)
# ---------------------------------------------------------------------------


def solve_analytic(devices: Sequence[DeviceProfile], N: float, *,
                   n: int, k: int) -> OptimizeResult:
    """Closed-form: at the optimum all devices with c_x>0 finish together.

    With linear t_x(c) = α_x c + β_x (α folds compute+copy slopes, β the
    intercepts), equalizing finish times gives
        T* = (N + Σ β_x/α_x) / (Σ 1/α_x)
    over the active set; devices whose β_x ≥ T* are dropped iteratively.
    """
    alphas, betas = [], []
    for d in devices:
        t0 = d.total_time(0.0, n, k)
        t1 = d.total_time(1e9, n, k)
        alphas.append((t1 - t0) / 1e9)
        betas.append(t0)
    active = list(range(len(devices)))
    while True:
        num = N + sum(betas[i] / alphas[i] for i in active)
        den = sum(1.0 / alphas[i] for i in active)
        T = num / den
        drop = [i for i in active if betas[i] >= T - _EPS]
        if not drop:
            break
        active = [i for i in active if i not in drop]
        if not active:  # pragma: no cover
            raise RuntimeError("no device can make progress")
    ops = [0.0] * len(devices)
    for i in active:
        ops[i] = (T - betas[i]) / alphas[i]
    # normalize tiny numerical drift
    s = sum(ops)
    ops = [c * (N / s) for c in ops]
    finish = _finish_times(devices, ops, n, k, "independent")
    return OptimizeResult(ops, max(finish), finish, "independent")


# ---------------------------------------------------------------------------
# Local-search CSP fallback (paper §3.2: non-linear models)
# ---------------------------------------------------------------------------


def solve_local_search(devices: Sequence[DeviceProfile], N: float, *,
                       n: int, k: int, bus: str = "independent",
                       iters: int = 4000, seed: int = 0) -> OptimizeResult:
    """Coordinate-descent on op shares.  Works for arbitrary monotone models;
    used as a CSP-style fallback and as an independent check of bisection."""
    import numpy as np
    rng = np.random.default_rng(seed)
    m = len(devices)
    order = priority_order(devices)

    def makespan(ops):
        return max(_finish_times(devices, list(ops), n, k, bus, order))

    ops = np.full(m, N / m)
    best = makespan(ops)
    step = N / 4.0
    it = 0
    while step > N * 1e-9 and it < iters:
        improved = False
        for src in range(m):
            for dst in range(m):
                if src == dst or ops[src] <= 0:
                    continue
                delta = min(step, ops[src])
                cand = ops.copy()
                cand[src] -= delta
                cand[dst] += delta
                t = makespan(cand)
                it += 1
                if t < best - _EPS:
                    ops, best, improved = cand, t, True
        if not improved:
            step *= 0.5
    finish = _finish_times(devices, list(ops), n, k, bus, order)
    return OptimizeResult(list(ops), max(finish), finish, bus, iterations=it)
