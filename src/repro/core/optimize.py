"""POAS phase 2 — *Optimize*.

The paper formulates work division as a MILP (Eqs. 1–3): minimize the
makespan ``max_x(t_c(c_x) + t_y(c_x))`` subject to ``Σ c_x = N``, ``c_x ≥ 0``
and solves it with CPLEX.  CPLEX is unavailable here; the problem class is
small (a handful of devices) and the per-device time models are monotone
non-decreasing in ``c_x``, so we replace the external solver with:

* ``solve_bisection`` — exact for *any* monotone time model (subsumes the
  paper's linear MILP): bisect on the makespan T; feasibility is "can the
  devices jointly absorb N ops, each finishing by T?", which decomposes
  per-device on uncontended topologies.  On contended topologies (the
  paper's serialized shared bus, §3.4.3/Fig. 2) the greedy priority-ordered
  feasibility check prices every candidate against the *exact* unified
  timeline engine (``core.bus``) — including chunked pipelined copies — so
  the solver optimizes precisely what the simulator reports and the
  executor replays.
* ``solve_analytic`` — closed-form active-set LP for the linear,
  independent-bus case (for cross-checking, and it is what a CPLEX run of
  Eqs. 1–4 returns).
* ``solve_local_search`` — CSP fallback for arbitrary (non-convex) models,
  per the paper's §3.2 note that backtracking/local search handles models
  that are not linear/quadratic.
* ``solve_list_schedule`` — the task-graph solver (DESIGN.md §10): the
  divisible-workload MILP does not apply to precedence-constrained DAGs,
  so work division becomes *device selection per task* — a HEFT-style list
  scheduler (upward-rank priority, earliest-finish-time placement) whose
  every candidate is priced on the same unified timeline engine, refined
  by reassignment descent (the discrete analogue of ``_descend``) or, on
  small instances, replaced outright by exhaustive enumeration.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import threading
from collections import OrderedDict
from typing import Mapping, Sequence

import numpy as np

from .bus import (BusTopology, ClockState, GraphSimBatch, GraphSimContext,
                  GraphSimState, TaskSpec, ZERO_CLOCKS, _graph_topo_order,
                  engine_finish_times, graph_finish_times)
from .device_model import DeviceProfile, LinearTimeModel, priority_order

_EPS = 1e-12
_TINY = 1e-30   # probe op count: prices fixed costs (B panel, launch) only


@dataclasses.dataclass
class OptimizeResult:
    ops: list[float]                 # c_x per device (Σ = N)
    makespan: float                  # predicted total time
    finish_times: list[float]        # per-device predicted finish
    bus: str                         # "independent" | "serialized" | custom
    iterations: int = 0
    energy_j: float | None = None    # joules, when an Objective was given

    def shares(self) -> list[float]:
        n = sum(self.ops)
        return [c / n if n else 0.0 for c in self.ops]


@dataclasses.dataclass(frozen=True)
class Objective:
    """Pluggable solver objective (DESIGN.md §16).

    ``score = makespan + energy_weight * energy_joules`` — the knob
    ``energy_weight`` is *seconds per joule*: 0 recovers the paper's pure
    makespan objective (selection stays bit-identical to the pre-objective
    solvers, regression-tested), +inf-ward trades latency for energy.
    Energy is priced post-hoc from the device power models
    (``DeviceProfile.idle_watts`` / ``joules_per_op``) over the engine's
    per-device busy/idle split, so the timing hot paths never change.
    """

    energy_weight: float = 0.0

    @property
    def is_makespan(self) -> bool:
        return self.energy_weight <= 0.0

    def score(self, makespan: float, energy_j: float) -> float:
        if self.energy_weight <= 0.0:
            return makespan
        return makespan + self.energy_weight * energy_j


MAKESPAN_OBJECTIVE = Objective(0.0)


def divisible_energy(devices: Sequence[DeviceProfile],
                     ops: Sequence[float], makespan: float) -> float:
    """Energy of a divisible-workload split: per-device dynamic joules for
    the MACs executed plus idle watts over the schedule gap."""
    e = 0.0
    for d, c in zip(devices, ops):
        busy = d.compute(float(c)) if c > 0.0 else 0.0
        if busy > makespan:
            busy = makespan
        e += d.joules_per_op * float(c) + d.idle_watts * (makespan - busy)
    return e


def _graph_energy_parts(ctx: GraphSimContext, assign: Sequence[int]
                        ) -> tuple[list[float], float]:
    """``(per-device busy seconds, dynamic joules)`` of a (partial) graph
    assignment — from the same per-(device, task) compute table the engine
    prices, so energy and timing share one source of truth.  Frozen
    (``ext``) tasks ran outside this plan and are excluded."""
    devices, comp, tasks, ext = ctx.devices, ctx.comp, ctx.tasks, ctx.ext
    busy = [0.0] * len(devices)
    dyn = 0.0
    for i in range(ctx.n):
        j = assign[i]
        if j >= 0 and i not in ext:
            busy[j] += comp[j][i]
            dyn += devices[j].joules_per_op * float(tasks[i].ops)
    return busy, dyn


def graph_energy(ctx: GraphSimContext, assign: Sequence[int],
                 makespan: float) -> float:
    """Total joules of a graph schedule under the device power models."""
    busy, dyn = _graph_energy_parts(ctx, assign)
    idle = 0.0
    for d, b in zip(ctx.devices, busy):
        if d.idle_watts > 0.0:
            gap = makespan - b
            if gap > 0.0:
                idle += d.idle_watts * gap
    return dyn + idle


# ---------------------------------------------------------------------------
# Feasibility: how many ops can each device absorb within makespan T?
# Both checks price candidates on the unified timeline engine, so the
# solver, the simulator, and the executor share one source of truth.
# ---------------------------------------------------------------------------


def _max_ops_single(devices: Sequence[DeviceProfile], i: int, T: float,
                    n: int, k: int, topo: BusTopology,
                    order: Sequence[int], N: float) -> float:
    """Largest c_i with device i's engine finish <= T, no contention."""
    c = [0.0] * len(devices)

    def fin(ci: float) -> float:
        c[i] = ci
        return engine_finish_times(devices, c, n, k, topology=topo,
                                   order=order)[i]

    if fin(_TINY) > T:      # fixed costs alone (B panel, launch) miss T
        return 0.0
    if fin(float(N)) <= T:  # the whole workload fits
        return float(N)
    lo, hi = 0.0, float(N)
    for _ in range(100):
        mid = 0.5 * (lo + hi)
        if fin(mid) <= T:
            lo = mid
        else:
            hi = mid
        if hi - lo <= max(1.0, lo) * 1e-9:
            break
    return lo


def _max_ops_serialized(devices: Sequence[DeviceProfile], order: Sequence[int],
                        T: float, n: int, k: int, topo: BusTopology,
                        N: float) -> list[float]:
    """Greedy priority-ordered assignment under a contended topology.

    Device i's candidate c_i is the largest value keeping the *whole*
    partial timeline's makespan within T — evaluated on the exact engine,
    so queueing on every link, compute overlap, no-copy devices starting at
    t = 0, and pipelined chunk boundaries are all priced exactly (the old
    linearized check both over-charged no-copy devices for bus time they
    never wait on and let output copies overlap input copies).  The engine
    makespan is monotone in every c_i, so greedy-max in priority order
    maximizes the total absorbed ops for a given T.
    """
    c = [0.0] * len(devices)
    for i in order:

        def span(ci: float) -> float:
            c[i] = ci
            return max(engine_finish_times(devices, c, n, k, topology=topo,
                                           order=order))

        if span(_TINY) > T:
            c[i] = 0.0
            continue
        if span(float(N)) <= T:
            c[i] = float(N)
            continue
        lo, hi = 0.0, float(N)
        for _ in range(100):
            mid = 0.5 * (lo + hi)
            if span(mid) <= T:
                lo = mid
            else:
                hi = mid
            if hi - lo <= max(1.0, lo) * 1e-9:
                break
        c[i] = lo
    return c


# ---------------------------------------------------------------------------
# Exact bisection solver
# ---------------------------------------------------------------------------


def solve_bisection(devices: Sequence[DeviceProfile], N: float, *,
                    n: int, k: int,
                    bus: str | BusTopology = "independent",
                    tol: float = 1e-9, polish: bool = True,
                    objective: Objective | None = None) -> OptimizeResult:
    """Minimize makespan by bisecting on T.

    ``bus`` is a legacy spec string ("independent" | "serialized") or a
    ``BusTopology``.  Feasibility prices every candidate on the exact
    unified timeline engine, so the check is exact for any topology and for
    chunked pipelined copies; the contended-topology result is additionally
    *polished* by coordinate descent on the same engine (the greedy
    priority-ordered assignment is not always the global optimum).

    ``objective``: with a pure-makespan objective (None / weight 0) the
    selection is exactly the historical one; an energy-weighted objective
    re-scores the makespan-optimal split against every device-*subset*
    split (spreading work burns idle+dynamic joules on every device it
    touches — the energy optimum often parks the workload on fewer,
    more efficient devices) and returns the best ``score``.
    """
    spec = bus.spec if isinstance(bus, BusTopology) else bus
    if N <= 0:
        z = [0.0] * len(devices)
        return OptimizeResult(z, 0.0, z, spec)
    topo = BusTopology.from_spec(bus, devices)
    order = priority_order(devices)
    contended = topo.is_contended()

    def capacity(T: float) -> list[float]:
        if contended:
            return _max_ops_serialized(devices, order, T, n, k, topo, N)
        return [_max_ops_single(devices, i, T, n, k, topo, order, N)
                for i in range(len(devices))]

    # bracket: every single-device assignment is feasible at its own engine
    # makespan; on a contended topology the greedy may interleave devices,
    # so the safe upper bound is the serial sum of those makespans.
    def single(i: int) -> float:
        one = [0.0] * len(devices)
        one[i] = N
        return max(engine_finish_times(devices, one, n, k, topology=topo,
                                       order=order))

    singles = [single(i) for i in range(len(devices))]
    t_lo = 0.0
    t_hi = sum(singles) if contended else min(singles)
    iters = 0
    for _ in range(200):
        iters += 1
        mid = 0.5 * (t_lo + t_hi)
        if sum(capacity(mid)) >= N:
            t_hi = mid
        else:
            t_lo = mid
        if t_hi - t_lo <= max(tol, t_hi * 1e-10):
            break
    caps = capacity(t_hi)
    total = sum(caps)
    # Scale back surplus so Σ c = N exactly, preferring to trim the devices
    # with the largest marginal cost (keeps the makespan at T*).
    if total > 0:
        scale = N / total
        ops = [c * scale for c in caps]
    else:  # pragma: no cover - degenerate
        ops = [N / len(devices)] * len(devices)
    if polish and contended and len(devices) > 1:
        ops = _descend(devices, ops, n, k, topo, order,
                       step0=N / 64.0, max_evals=1500)
    finish = _finish_times(devices, ops, n, k, topo, order)
    best = OptimizeResult(ops, max(finish), finish, spec, iterations=iters)
    # Degenerate single-device assignments are feasible points the split
    # can lose to on small workloads (copy overheads don't amortize — the
    # paper's §3.4.3 "significant amount of work" caveat).  Take the min.
    for i in range(len(devices)):
        one = [0.0] * len(devices)
        one[i] = N
        f1 = _finish_times(devices, one, n, k, topo, order)
        if max(f1) < best.makespan:
            best = OptimizeResult(one, max(f1), f1, spec, iterations=iters)
    if objective is None:
        return best
    best.energy_j = divisible_energy(devices, best.ops, best.makespan)
    if objective.is_makespan or len(devices) <= 1:
        return best
    # energy mode: re-score against every proper device-subset split —
    # each subset solved makespan-optimally by the exact machinery above,
    # then priced with the idle watts of the devices it left out
    best_score = objective.score(best.makespan, best.energy_j)
    m = len(devices)
    for mask in range(1, (1 << m) - 1):
        idxs = [i for i in range(m) if mask >> i & 1]
        sub = [devices[i] for i in idxs]
        r = solve_bisection(sub, N, n=n, k=k,
                            bus=bus if isinstance(bus, BusTopology)
                            else spec, tol=tol, polish=polish)
        ops_full = [0.0] * m
        for i, c in zip(idxs, r.ops):
            ops_full[i] = c
        e = divisible_energy(devices, ops_full, r.makespan)
        s = objective.score(r.makespan, e)
        if s < best_score - _EPS:
            fin_full = [0.0] * m
            for i, f in zip(idxs, r.finish_times):
                fin_full[i] = f
            best = OptimizeResult(ops_full, r.makespan, fin_full, spec,
                                  iterations=iters + r.iterations,
                                  energy_j=e)
            best_score = s
    return best


def _descend(devices: Sequence[DeviceProfile], ops0: Sequence[float],
             n: int, k: int, bus: str | BusTopology, order: Sequence[int], *,
             step0: float, max_evals: int) -> list[float]:
    """Pairwise-transfer coordinate descent on the exact timeline makespan."""
    ops = list(ops0)
    m = len(devices)

    def makespan(v):
        return max(_finish_times(devices, v, n, k, bus, order))

    best = makespan(ops)
    step = step0
    evals = 0
    while step > sum(ops0) * 1e-10 and evals < max_evals:
        improved = False
        for src in range(m):
            if ops[src] <= 0:
                continue
            for dst in range(m):
                if src == dst:
                    continue
                delta = min(step, ops[src])
                cand = list(ops)
                cand[src] -= delta
                cand[dst] += delta
                t = makespan(cand)
                evals += 1
                if t < best - _EPS:
                    ops, best, improved = cand, t, True
        if not improved:
            step *= 0.5
    return ops


def _finish_times(devices: Sequence[DeviceProfile], ops: Sequence[float],
                  n: int, k: int, bus: str | BusTopology,
                  order: Sequence[int] | None = None) -> list[float]:
    """Per-device finish times — the unified engine, nothing else.

    This used to be an independent re-implementation of the Fig. 2 timeline
    that (a) charged no-copy devices for bus queue time they never wait on
    and (b) reset the output-copy clock to 0, letting outputs overlap
    inputs on the supposedly serialized bus; both made the solver optimize
    a different objective than ``simulate_timeline`` measured.  Delegating
    to ``engine_finish_times`` makes solver/simulator agreement exact by
    construction."""
    return engine_finish_times(devices, ops, n, k, topology=bus, order=order)


# ---------------------------------------------------------------------------
# Analytic LP (linear models, independent bus)
# ---------------------------------------------------------------------------


def solve_analytic(devices: Sequence[DeviceProfile], N: float, *,
                   n: int, k: int) -> OptimizeResult:
    """Closed-form: at the optimum all devices with c_x>0 finish together.

    With linear t_x(c) = α_x c + β_x (α folds compute+copy slopes, β the
    intercepts), equalizing finish times gives
        T* = (N + Σ β_x/α_x) / (Σ 1/α_x)
    over the active set; devices whose β_x ≥ T* are dropped iteratively.

    Zero-slope devices (``LinearTimeModel(a=0, b=...)`` — constant time
    regardless of load) would divide by zero in the LP; they are held out
    of the active set and compared as "hand it everything" candidates
    (a zero-slope device finishes at β no matter how much it absorbs).
    """
    alphas, betas = [], []
    for d in devices:
        t0 = d.total_time(0.0, n, k)
        t1 = d.total_time(1e9, n, k)
        alphas.append((t1 - t0) / 1e9)
        betas.append(t0)
    zero = [i for i in range(len(devices)) if alphas[i] <= 0.0]
    active = [i for i in range(len(devices)) if alphas[i] > 0.0]
    T = math.inf
    if active:
        while True:
            num = N + sum(betas[i] / alphas[i] for i in active)
            den = sum(1.0 / alphas[i] for i in active)
            T = num / den
            drop = [i for i in active if betas[i] >= T - _EPS]
            if not drop:
                break
            active = [i for i in active if i not in drop]
            if not active:
                T = math.inf
                break
    if zero:
        j = min(zero, key=lambda i: betas[i])
        if betas[j] <= T:   # constant-time device beats (or is) the LP
            ops = [0.0] * len(devices)
            ops[j] = N
            finish = _finish_times(devices, ops, n, k, "independent")
            return OptimizeResult(ops, max(finish), finish, "independent")
    if not active:  # pragma: no cover
        raise RuntimeError("no device can make progress")
    ops = [0.0] * len(devices)
    for i in active:
        ops[i] = (T - betas[i]) / alphas[i]
    # normalize tiny numerical drift
    s = sum(ops)
    ops = [c * (N / s) for c in ops]
    finish = _finish_times(devices, ops, n, k, "independent")
    return OptimizeResult(ops, max(finish), finish, "independent")


# ---------------------------------------------------------------------------
# Local-search CSP fallback (paper §3.2: non-linear models)
# ---------------------------------------------------------------------------


def solve_local_search(devices: Sequence[DeviceProfile], N: float, *,
                       n: int, k: int, bus: str | BusTopology = "independent",
                       iters: int = 4000, seed: int = 0) -> OptimizeResult:
    """Coordinate-descent on op shares.  Works for arbitrary monotone models;
    used as a CSP-style fallback and as an independent check of bisection."""
    import numpy as np
    rng = np.random.default_rng(seed)
    m = len(devices)
    bus = BusTopology.from_spec(bus, devices)
    order = priority_order(devices)

    def makespan(ops):
        return max(_finish_times(devices, list(ops), n, k, bus, order))

    ops = np.full(m, N / m)
    best = makespan(ops)
    step = N / 4.0
    it = 0
    while step > N * 1e-9 and it < iters:
        improved = False
        for src in range(m):
            for dst in range(m):
                if src == dst or ops[src] <= 0:
                    continue
                delta = min(step, ops[src])
                cand = ops.copy()
                cand[src] -= delta
                cand[dst] += delta
                t = makespan(cand)
                it += 1
                if t < best - _EPS:
                    ops, best, improved = cand, t, True
        if not improved:
            step *= 0.5
    finish = _finish_times(devices, list(ops), n, k, bus, order)
    return OptimizeResult(list(ops), max(finish), finish, bus.spec,
                          iterations=it)


# ---------------------------------------------------------------------------
# HEFT-style list scheduler for task graphs (DESIGN.md §10)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class GraphScheduleResult(OptimizeResult):
    """``OptimizeResult`` plus the task-graph solution: which device each
    task runs on (``assign``), the topological priority list the links are
    serialized in (``order``), and per-task predicted finish times.  The
    inherited ``ops`` are per-device op totals, so share-based consumers
    (dynamic load shedding asserts, dashboards) work unchanged."""

    assign: list[int] = dataclasses.field(default_factory=list)
    order: list[int] = dataclasses.field(default_factory=list)
    task_finish: list[float] = dataclasses.field(default_factory=list)


def _upward_ranks(devices: Sequence[DeviceProfile],
                  tasks: Sequence[TaskSpec],
                  edges: Sequence[tuple[int, int]]) -> list[float]:
    """HEFT upward rank: mean compute cost plus the most expensive
    downstream chain, edges priced at the mean staged-transfer cost.
    Device-independent, so the priority list is fixed before placement.

    Vectorized: ``wbar``/``cbar`` are per-task numpy arrays accumulated
    device-by-device in the same order the scalar ``sum`` ran, and the
    downstream recurrence runs level-synchronously with per-level CSR
    child arrays and ``np.maximum.reduceat``.  Every float operation
    keeps the sequential version's order and grouping, so the ranks —
    and therefore the priority list — are bit-identical to it (max is
    exact, and ``max_c(cbar + rank_c) == cbar + max_c(rank_c)`` because
    IEEE addition is monotone)."""
    n = len(tasks)
    children: list[list[int]] = [[] for _ in range(n)]
    for u, v in edges:
        children[u].append(v)
    ops = np.array([float(t.ops) for t in tasks])
    out_b = np.array([float(t.out_bytes) for t in tasks])

    acc = np.zeros(n)
    for d in devices:
        tm = d.compute
        if isinstance(tm, LinearTimeModel):
            acc = acc + (tm.a * ops + tm.b)
        else:   # nonlinear model: per-task calls, same accumulation order
            acc = acc + np.array([tm(t.ops) for t in tasks])
    wbar = acc / len(devices)

    copiers = [d for d in devices
               if not math.isinf(d.copy.bandwidth_bytes_per_s)]
    if copiers:
        cacc = np.zeros(n)
        for d in copiers:
            cacc = cacc + (2.0 * out_b / d.copy.bandwidth_bytes_per_s
                           + d.copy.latency_s)
        cbar = np.where(out_b > 0.0, cacc / len(copiers), 0.0)
    else:
        cbar = np.zeros(n)

    # level-synchronous recurrence over the reversed topological order:
    # level 0 = leaves (tail 0), level L depends only on levels < L
    level = [0] * n
    for i in reversed(_graph_topo_order(n, edges)):
        if children[i]:
            level[i] = 1 + max(level[c] for c in children[i])
    rank = wbar.copy()   # leaves: rank = wbar
    by_level: dict[int, list[int]] = {}
    for i in range(n):
        if level[i] > 0:
            by_level.setdefault(level[i], []).append(i)
    for lv in sorted(by_level):
        nodes = by_level[lv]
        kids = [c for i in nodes for c in children[i]]
        offs = np.cumsum([0] + [len(children[i]) for i in nodes])[:-1]
        maxchild = np.maximum.reduceat(rank[kids], offs)
        nd = np.array(nodes)
        rank[nd] = wbar[nd] + (cbar[nd] + maxchild)
    return rank.tolist()


def _rank_order(devices: Sequence[DeviceProfile], tasks: Sequence[TaskSpec],
                edges: Sequence[tuple[int, int]]) -> list[int]:
    """Decreasing upward rank, ties broken by topological position (so the
    order is always a valid linearization even under zero-cost ties)."""
    topo_pos = {i: p for p, i in
                enumerate(_graph_topo_order(len(tasks), edges))}
    rank = _upward_ranks(devices, tasks, edges)
    return sorted(range(len(tasks)), key=lambda i: (-rank[i], topo_pos[i]))


# -- incremental EFT machinery (DESIGN.md §12, §14) -------------------------

_SNAP_EVERY = 24   # order positions between simulation-state snapshots
_PEEK_BATCH_MIN_DEVS = 6    # below this, d scalar peeks beat the numpy lanes
_BATCH_MIN_LANES = 4        # GraphSimBatch lanes needed to beat scalar walks
_PRUNE_MIN_MOVABLE = 48     # full descent sweeps below this many movables
_PRUNE_TAIL = 24            # latest-finishing movables kept by the pruner


class _SnapChain:
    """Block-keyed snapshot chain under a moving head state (DESIGN.md §14).

    Snapshots are ``GraphSimState`` clones keyed by ``pos // _SNAP_EVERY``,
    recorded as the head advances (``advance_snapped``) and invalidated
    above a flip/move position when an accepted candidate rewrites history
    (``invalidate_above``).  ``state_at(m)`` resumes from the nearest
    recorded block at or below ``m``: *adoption* — a priced re-simulation
    becoming the new head instead of being re-simulated a second time —
    leaves gaps in the chain, and the engine's ``sim_positions`` bisect
    makes re-advancing across a gap cost only the simulated (non-frozen)
    tasks inside it, so tolerating gaps is cheaper than eagerly re-recording
    clones (an O(n) copy each) ever was."""

    __slots__ = ("snaps", "min_key")

    def __init__(self, min_key: int = 0):
        self.snaps: dict[int, GraphSimState] = {}
        self.min_key = min_key

    def advance_snapped(self, st: GraphSimState, stop: int) -> None:
        """Advance the head to ``stop``, recording a clone at every
        ``_SNAP_EVERY`` boundary crossed at or above ``min_key`` (descent
        never rewinds below the earliest movable task or movable-task
        parent, so snapshots under that floor would be dead weight)."""
        while st.pos < stop:
            nxt = (st.pos // _SNAP_EVERY + 1) * _SNAP_EVERY
            if nxt > stop:
                nxt = stop
            st.advance(nxt)
            if nxt % _SNAP_EVERY == 0 and nxt // _SNAP_EVERY >= self.min_key:
                self.snaps[nxt // _SNAP_EVERY] = st.snap_clone()

    def state_at(self, m: int, assign: list[int],
                 placed: bytearray) -> GraphSimState:
        """A throwaway state resumed from the nearest block <= ``m``,
        carrying the caller's *live* assign/placed lists (the snapshots'
        own copies are stale by design).

        When adoption has left a gap below ``m``, the catch-up advance
        repairs the chain by recording the missing boundary clones.
        Every caller's candidate world diverges from the committed
        trajectory only at or after ``m * _SNAP_EVERY`` (``m`` is the
        block of the earliest flip/move position), so the blocks crossed
        here simulate identically in both worlds and are valid committed
        snapshots — without this, one far-back adoption wipes the chain
        and every later resume replays the same gap again."""
        k = m if m in self.snaps else max(k for k in self.snaps if k <= m)
        tmp = self.snaps[k].snap_clone()
        tmp.assign = assign
        tmp.placed = placed
        while k < m:
            k += 1
            tmp.advance(k * _SNAP_EVERY)
            self.snaps[k] = tmp.snap_clone()
        return tmp

    def invalidate_above(self, m: int) -> None:
        """Drop blocks simulated past the rewrite point — block ``b`` is
        still valid iff its boundary ``b * _SNAP_EVERY`` <= the rewrite
        position, i.e. ``b <= m``."""
        for k in [k for k in self.snaps if k > m]:
            del self.snaps[k]


def _resim_place(st: GraphSimState, chain: _SnapChain, pos: int, i: int,
                 j: int, fp: int) -> tuple[GraphSimState, float]:
    """Exact price of candidate ``(i, j)`` whose placement flips an earlier
    producer's host-stage decision: re-simulate positions [snapshot, pos]
    on a throwaway state under the tentative assignment.  Returns the
    re-simulated state too — if the lane wins, the caller *adopts* it as
    the new head instead of re-simulating the same span a second time
    (the old rewind-and-re-advance commit)."""
    old_a, old_p = st.assign[i], st.placed[i]
    st.assign[i] = j
    st.placed[i] = 1
    tmp = chain.state_at(fp // _SNAP_EVERY, st.assign, st.placed)
    tmp.advance(pos + 1)
    st.assign[i] = old_a
    st.placed[i] = old_p
    return tmp, tmp.finish[i]


class _DeviceArrays:
    """Per-solve device constants for the vectorized EFT candidate batch —
    the context's per-(device, task) duration tables as (d, n) numpy
    arrays plus per-device masks, one lane per candidate device."""

    __slots__ = ("idx", "has_copy", "ext_in", "par_in", "stage_out", "comp",
                 "same_link", "hier", "host", "nic_dur")

    def __init__(self, ctx: GraphSimContext):
        npt = ctx.np_tables()   # built once per graph, shared by rebind
        self.idx = npt.idx
        self.has_copy = npt.has_copy
        self.ext_in = npt.ext_in
        self.par_in = npt.par_in
        self.stage_out = npt.stage_out
        self.comp = npt.comp
        self.same_link = npt.same_link
        self.hier = npt.hier
        self.host = npt.host
        self.nic_dur = npt.nic_dur


def _peek_batch(st: GraphSimState, da: _DeviceArrays, i: int) -> np.ndarray:
    """Vectorized ``GraphSimState.peek_finish`` over every device at once.

    Each numpy lane applies the identical float operations in the
    identical order to the scalar path (durations come from the shared
    per-(device, task) tables; elementwise IEEE double ops match Python
    floats exactly), so device selection sees bit-identical finish times —
    asserted transitively by the incremental-vs-from-scratch equality
    checks in the bench and the property tests."""
    ctx = st.ctx
    t = ctx.tasks[i]
    nd = len(ctx.devices)
    lc = np.array([st.link_clock_id(lid) for lid in ctx.in_lid])
    dev_clk = np.array([st.dev_clock_id(j) for j in range(nd)])
    touched = np.zeros(nd, dtype=bool)   # lanes whose in-link clock moved
    ready = np.zeros(nd)

    if t.in_bytes > 0.0:
        end = lc + da.ext_in[:, i]
        lc = np.where(da.has_copy, end, lc)
        touched = touched | da.has_copy
        ready = np.where(da.has_copy, end, ready)

    placed, assign = st.placed, st.assign
    hier, host_t = ctx.hier, ctx.host_id
    for u in ctx.parents[i]:
        if not placed[u]:
            continue
        same = da.idx == assign[u]
        ce_u, av_u = st.compute_end[u], st.avail[u]
        if hier:
            # cross-host lanes read the producer's staged output one NIC
            # hop late (mirrors the scalar peek_finish)
            q = assign[u]
            if q >= 0 and host_t[q] >= 0:
                crossm = (da.host >= 0) & (da.host != host_t[q])
                if crossm.any():
                    av_u = np.where(crossm, av_u + da.nic_dur[u], av_u)
        if not ctx.has_out[u]:
            r = np.where(same, ce_u, av_u)
        else:
            s = np.maximum(lc, av_u)
            end = s + da.par_in[:, u]
            copy_lane = da.has_copy & ~same
            lc = np.where(copy_lane, end, lc)
            touched = touched | copy_lane
            r = np.where(same, ce_u, np.where(da.has_copy, end, av_u))
        ready = np.maximum(ready, r)

    s = np.maximum(dev_clk, ready)
    ce = s + da.comp[:, i]

    if not ctx.has_out[i]:
        return ce
    kids = [c for c in ctx.children[i] if placed[c]]
    if kids:
        ka = np.array([assign[c] for c in kids])
        need = da.has_copy & (ka[None, :] != da.idx[:, None]).any(axis=1)
    else:
        need = da.has_copy.copy()   # pseudo-sink: output returns to host
    out_clk = np.array([st.link_clock_id(lid) for lid in ctx.out_lid])
    out_clk = np.where(da.same_link & touched, lc, out_clk)
    s2 = np.maximum(out_clk, ce)
    return np.where(need, s2 + da.stage_out[:, i], ce)


def _eft_place(ctx: GraphSimContext, assign: Sequence[int],
               pinned: Mapping[int, int],
               banned: frozenset[int] | None = None
               ) -> tuple[GraphSimState, int]:
    """Rank-priority EFT placement on the incremental engine: one
    ``GraphSimState`` swept along the priority order, each (task, device)
    candidate priced by the vectorized peek in O(deg·d) — falling back to
    a snapshot re-simulation only when the candidate flips an earlier
    producer's host-stage decision (DESIGN.md §12).  Selection and
    resulting assignments are bit-identical to pricing every prefix from
    scratch; returns the final state, the candidate-evaluation count, and
    the snapshot chain (which a following descent can adopt via ``init``
    instead of rebuilding state and snapshots from scratch).
    """
    ndev = len(ctx.devices)
    st = GraphSimState(ctx, assign, placed=list(ctx.ext))
    sp = ctx.sim_positions
    chain = _SnapChain(sp[0] // _SNAP_EVERY if sp else 0)
    if chain.min_key == 0:
        chain.snaps[0] = st.snap_clone()
    use_batch = ndev >= _PEEK_BATCH_MIN_DEVS
    da = _DeviceArrays(ctx) if use_batch else None
    evals = 0

    def commit(stc: GraphSimState, pos: int, i: int, j: int,
               fp: int | None) -> GraphSimState:
        stc.assign[i] = j
        stc.placed[i] = 1
        if fp is not None:
            stc = chain.state_at(fp // _SNAP_EVERY, stc.assign, stc.placed)
            chain.invalidate_above(fp // _SNAP_EVERY)
        chain.advance_snapped(stc, pos + 1)
        return stc

    # a partial solve's order is mostly pinned∩ext positions — pure no-ops
    # (frozen AND externally priced); enumerate only the ones with work
    ext = ctx.ext
    if pinned:
        work = [(pos, i) for pos, i in enumerate(ctx.order)
                if i not in pinned or i not in ext]
    else:
        work = enumerate(ctx.order)
    for pos, i in work:
        if i in pinned:
            if i not in ctx.ext:   # frozen assignment still gets simulated
                st = commit(st, pos, i, st.assign[i],
                            st.stage_flip_pos(i, st.assign[i]))
            continue
        if i in ctx.ext:
            # finish is fixed externally: every device prices identically,
            # so the ascending scan commits device 0 (the tie rule)
            evals += ndev
            st = commit(st, pos, i, 0, st.stage_flip_pos(i, 0))
            continue
        if use_batch:
            fin = _peek_batch(st, da, i)
            peeks = None
            flips = slacks = None
        else:
            # one fused neighborhood walk prices every lane: all-device
            # peeks plus each lane's earliest flip position and vanish
            # slack (replaces d peeks + d per-lane flip scans)
            peeks, flips, slacks = st.price_lanes(i, ndev)
        best_j, best_t = 0, math.inf
        best_tmp: GraphSimState | None = None
        best_fp: int | None = None
        for j in range(ndev):
            if banned is not None and j in banned:
                continue   # departed device: the solver cannot place here
            evals += 1
            if use_batch:
                fp, _, _, slack = st._stage_flip_info(i, j)
            else:
                fp, slack = flips[j], slacks[j]
            if fp is None:
                t = float(fin[j]) if use_batch else peeks[j]
                tmp = None
            else:
                # the stale peek minus the vanishing stages' reclaimable
                # link time LOWER-bounds the exact price (appears only
                # insert occupancy; a vanish pulls events earlier by at
                # most the span it returns to the link — the clocks are
                # (max, +) so perturbations never amplify): a lane whose
                # bound already loses provably cannot win, and skipping
                # it leaves the selection exactly the all-lanes argmin
                peek = float(fin[j]) if use_batch else peeks[j]
                if peek - slack >= best_t - _EPS:
                    continue
                tmp, t = _resim_place(st, chain, pos, i, j, fp)
            if t < best_t - _EPS:
                best_j, best_t, best_tmp, best_fp = j, t, tmp, fp
        st.assign[i] = best_j
        st.placed[i] = 1
        if best_tmp is not None:
            # adopt the winning lane's re-simulation as the new head —
            # it IS the committed state (advanced through pos), so the
            # old rewind-and-re-advance second pass is gone
            chain.invalidate_above(best_fp // _SNAP_EVERY)
            st = best_tmp
        else:
            chain.advance_snapped(st, pos + 1)
    return st, evals, chain


def _prune_movable(ctx: GraphSimContext, st: GraphSimState,
                   movable: Sequence[int]) -> list[int]:
    """The pruned candidate set (DESIGN.md §14): movable tasks on or
    adjacent to the data-critical chain — walked backwards from the
    makespan task through each task's latest-finishing placed producer —
    plus the ``_PRUNE_TAIL`` latest-finishing movable tasks (the
    neighborhood of whatever straggled).  Moves of other tasks rarely
    shift the makespan; the descent only falls back to the full sweep
    when this set goes dry and budget remains."""
    finish = st.finish
    placed = st.placed
    keep: set[int] = set()
    c = max(range(ctx.n), key=lambda i: finish[i])
    while c not in keep:
        keep.add(c)
        best_u, best_f = c, -1.0
        for u in ctx.parents[c]:
            if placed[u] and finish[u] > best_f:
                best_u, best_f = u, finish[u]
        c = best_u
    for c in list(keep):
        keep.update(ctx.parents[c])
        keep.update(ctx.children[c])
    keep.update(sorted(movable, key=lambda i: finish[i],
                       reverse=True)[:_PRUNE_TAIL])
    # tail-first: later order positions first — their candidate walks
    # re-simulate the shortest suffixes (cheapest evals), they neighbor
    # the straggler (likeliest improvements), and each early accept
    # tightens the incumbent bound for the longer walks that follow.
    # Matters because a capped budget usually binds mid-sweep.
    return sorted((i for i in movable if i in keep),
                  key=ctx.pos_of.__getitem__, reverse=True)


def _descend_assign(ctx: GraphSimContext, assign: Sequence[int], *,
                    max_evals: int = 2000,
                    free: Sequence[int] | None = None,
                    prune: bool = True,
                    init: tuple[GraphSimState, _SnapChain] | None = None,
                    objective: Objective | None = None,
                    banned: frozenset[int] | None = None
                    ) -> tuple[list[int], int, float, list[float]]:
    """Reassignment descent on the exact graph makespan — ``_descend``'s
    pairwise-transfer loop in discrete per-task coordinates: move one task
    to another device, keep any strict improvement, repeat to a local
    optimum.  ``free`` restricts the moves to the given task indices
    (partial solves pin the frozen tasks).

    Each candidate move re-prices only the suffix of the priority order
    from the moved task's position (or from the earliest producer whose
    host-stage decision the move flips, if earlier), resumed from the
    nearest ``GraphSimState`` snapshot — positions before it are provably
    unaffected, so the makespans are exactly the from-scratch values.
    Returns ``(assign, evals, makespan, finish)`` — the local optimum's
    makespan and per-task finish times come from the last accepted head,
    so callers need no re-pricing replay.

    ``init`` hands over an already-advanced ``(state, chain)`` whose
    assignment equals ``assign`` — the EFT placement's final head — so the
    seed-pricing advance (a full suffix walk plus state construction) is
    skipped; its makespan was already computed by the placement."""
    movable = list(free) if free is not None else list(range(ctx.n))
    end = len(ctx.order)
    ndev = len(ctx.devices)
    if init is not None:
        st, chain = init
    else:
        st = GraphSimState(ctx, assign)
        # descent never rewinds below the earliest movable task or simulated
        # parent of one — skip snapshots below that floor (a partial
        # re-solve freezes most of the order; this keeps its setup cost at
        # O(free))
        floor = end
        for i in movable:
            floor = min(floor, ctx.pos_of[i])
            for u in ctx.parents[i]:
                if u not in ctx.ext:
                    p = ctx.pos_of.get(u)
                    if p is not None:
                        floor = min(floor, p)
        chain = _SnapChain(floor // _SNAP_EVERY)
        if chain.min_key == 0:
            chain.snaps[0] = st.snap_clone()
        chain.advance_snapped(st, end)
    # energy-weighted objective (DESIGN.md §16): candidates are accepted on
    # score = makespan + lam * energy.  The energy terms of a candidate
    # assignment are known BEFORE simulation (busy time is the sum of the
    # per-(device, task) compute table over the assignment), so the engine's
    # branch-and-bound stays exact: a candidate is prunable once its
    # makespan alone pushes the (linear, clamp-free lower bound of the)
    # score past the incumbent.  lam == 0 keeps the historical makespan
    # path byte-identical.
    lam = (objective.energy_weight
           if objective is not None and not objective.is_makespan else 0.0)
    if lam > 0.0:
        devs = ctx.devices
        iw = [d.idle_watts for d in devs]
        jpo = [d.joules_per_op for d in devs]
        opsv = [float(t.ops) for t in ctx.tasks]
        comp = ctx.comp
        si = sum(iw)
        busy, dyn = _graph_energy_parts(ctx, st.assign)
        wb = sum(w * b for w, b in zip(iw, busy))
        ms0 = max(st.finish)
        idle0 = sum(w * (ms0 - b) for w, b in zip(iw, busy)
                    if ms0 > b and w > 0.0)
        best = ms0 + lam * (dyn + idle0)
    else:
        best = max(st.finish)
    evals = 1
    # candidate-move pruning: sweep the critical-path neighborhood first,
    # falling back to the full sweep only when the pruned sweep goes dry
    # with budget remaining (and re-pruning when the full sweep improves)
    # (energy mode sweeps everything: a move off the critical path can
    # still cut joules)
    do_prune = prune and lam == 0.0 and ndev > 1 \
        and len(movable) >= _PRUNE_MIN_MOVABLE
    cands = _prune_movable(ctx, st, movable) if do_prune else movable
    pruned_now = do_prune
    nbanned = len(banned) if banned else 0
    use_batch = ndev - 1 - nbanned >= _BATCH_MIN_LANES and lam == 0.0
    # the budget binds mid-sweep, not only between sweeps: a single sweep
    # is len(free)·(d-1) candidate moves, which at 10^3+ nodes dwarfs any
    # reasonable budget — checking only in the while-condition made
    # ``max_evals`` a dead letter exactly where it matters (the capped
    # re-solve on a straggler's worker thread, DESIGN.md §11/§12)
    while evals < max_evals:
        improved = False
        for i in cands:
            if evals >= max_evals:
                break
            pi = ctx.pos_of[i]
            old = st.assign[i]
            if use_batch and max_evals - evals >= _BATCH_MIN_LANES:
                # batched move pricing: every alternative device of task i
                # in one GraphSimBatch sharing a single snapshot resume
                cand_devs = [j for j in range(ndev) if j != old
                             and (banned is None or j not in banned)]
                if not cand_devs:
                    continue
                p0 = pi
                for j in cand_devs:
                    fp = st.stage_flip_pos(i, j)
                    if fp is not None and fp < p0:
                        p0 = fp
                m = p0 // _SNAP_EVERY
                base = chain.state_at(m, st.assign, st.placed)
                batch = GraphSimBatch(base, i, cand_devs)
                batch.run(end, bound=best - _EPS)
                evals += len(cand_devs)
                ms = batch.makespans()
                l = int(ms.argmin())
                t = float(ms[l])
                if t < best - _EPS:
                    st.assign[i] = cand_devs[l]
                    new_st = batch.extract(l)
                    new_st.assign = st.assign
                    new_st.placed = st.placed
                    chain.invalidate_above(m)
                    st = new_st
                    best, improved = t, True
                continue
            for j in range(ndev):
                if evals >= max_evals:
                    break
                if j == old or (banned is not None and j in banned):
                    continue
                fp = st.stage_flip_pos(i, j)
                p0 = pi if fp is None or fp > pi else fp
                m = p0 // _SNAP_EVERY
                st.assign[i] = j
                tmp = chain.state_at(m, st.assign, st.placed)
                # bound-aware early exit: every simulated finish lower-
                # bounds the candidate's makespan, so the walk aborts the
                # moment one exceeds the incumbent; a completed walk is
                # byte-identical to an unbounded one, so accepted heads
                # (and the unpruned trajectory) are unchanged
                if lam > 0.0:
                    # candidate energy constants, pre-simulation: the
                    # makespan cap where even zero idle clamping cannot
                    # bring the score under the incumbent
                    dwb = iw[j] * comp[j][i] - iw[old] * comp[old][i]
                    ddyn = (jpo[j] - jpo[old]) * opsv[i]
                    cap = (best - lam * (dyn + ddyn - wb - dwb)) \
                        / (1.0 + lam * si)
                    done = tmp.advance(end, bound=cap - _EPS)
                else:
                    done = tmp.advance(end, bound=best - _EPS)
                evals += 1
                if lam > 0.0:
                    if done:
                        ms = max(tmp.finish)
                        busy[old] -= comp[old][i]
                        busy[j] += comp[j][i]
                        idle = sum(w * (ms - b)
                                   for w, b in zip(iw, busy)
                                   if ms > b and w > 0.0)
                        busy[old] += comp[old][i]
                        busy[j] -= comp[j][i]
                        t = ms + lam * (dyn + ddyn + idle)
                    else:
                        t = math.inf
                else:
                    t = max(tmp.finish) if done else math.inf
                if done and t < best - _EPS:
                    # adopt: the candidate walk already IS the new head
                    chain.invalidate_above(m)
                    st = tmp
                    best, improved = t, True
                    if lam > 0.0:
                        busy[old] -= comp[old][i]
                        busy[j] += comp[j][i]
                        wb += dwb
                        dyn += ddyn
                    old = j
                else:
                    st.assign[i] = old
        if improved:
            if do_prune and not pruned_now:
                cands = _prune_movable(ctx, st, movable)  # re-center
                pruned_now = True
        else:
            if pruned_now and evals < max_evals:
                # pruned sweep dry: one full sweep, same tail-first order
                cands = sorted(movable, key=ctx.pos_of.__getitem__,
                               reverse=True)
                pruned_now = False
            else:
                break
    return st.assign, evals, best, st.finish


class SolveContextCache:
    """Single-entry cache of (priority order, simulation context) for
    repeated re-solves of ONE task graph (DESIGN.md §14).

    The straggler-rescue path re-plans the same DAG every few milliseconds;
    the upward-rank order and the context's per-(device, task) duration
    tables depend only on (devices, tasks, edges, topology), while
    everything a re-plan changes — carried clocks, the frozen ``ext`` set,
    pins, seeds — is re-keyed per call via ``GraphSimContext.rebind`` in
    O(n).  The owner must dedicate one instance per graph (per
    ``StreamJob`` in the runtime); the entry is verified against
    (devices tuple, priority, topology spec), which covers model re-fits:
    a re-fit builds new frozen ``DeviceProfile``s, misses, and forces a
    rebuild against the fresh cost tables."""

    __slots__ = ("_entry",)

    def __init__(self):
        self._entry: tuple | None = None

    def lookup(self, key) -> tuple[list[int], GraphSimContext] | None:
        e = self._entry
        if e is not None and e[0] == key:
            return e[1], e[2]
        return None

    def store(self, key, order: list[int], ctx: GraphSimContext) -> None:
        self._entry = (key, order, ctx)


def solve_list_schedule(devices: Sequence[DeviceProfile],
                        tasks: Sequence[TaskSpec],
                        edges: Sequence[tuple[int, int]], *,
                        bus: str | BusTopology = "serialized",
                        priority: str = "rank",
                        refine: bool = True,
                        exhaustive_limit: int = 1024,
                        pinned: Mapping[int, int] | None = None,
                        ext: Mapping[int, tuple[float, float]] | None = None,
                        clocks: ClockState = ZERO_CLOCKS,
                        seed_assign: Sequence[int] | None = None,
                        max_evals: int = 2000,
                        prune: bool = True,
                        cache: SolveContextCache | None = None,
                        objective: Objective | None = None,
                        banned: Sequence[int] | frozenset[int] | None = None
                        ) -> GraphScheduleResult:
    """Minimize a task graph's makespan by list scheduling on the engine.

    HEFT shape: tasks are placed in decreasing upward-rank order
    (``priority="rank"``); each is assigned the device giving it the
    earliest engine finish time over the partial schedule — so link
    queueing, host staging of cross-device edges, and carried clocks are
    priced exactly as the simulator reports and the executor replays.
    ``priority="topo"`` is the naive baseline: plain topological order
    with myopic device selection (each task alone on an empty timeline —
    ignores contention and edge locality), the benchmark's strawman.

    Refinement: when the free assignment space is small
    (``len(devices)**len(free) <= exhaustive_limit``) the solver
    enumerates every assignment under the same priority order and returns
    the exact optimum; otherwise reassignment descent polishes the HEFT
    placement to a local optimum on the same engine makespan.

    Partial solve (mid-graph re-planning, DESIGN.md §11): ``pinned`` maps
    task index -> device index for tasks whose assignment is *frozen*
    (completed or already running); only the remaining tasks are placed and
    refined.  ``ext`` prices the frozen tasks externally (their measured
    ``(compute_end, avail)`` — see ``build_graph_timeline``), ``clocks``
    carries the measured link/device clocks the frontier must queue behind,
    and ``seed_assign`` seeds the refinement from the currently-executing
    plan so the re-solve starts no worse than the lock-in it replaces.
    When a seed is given the degenerate all-one-device sweeps are skipped —
    the seed already provides the quality floor, and a partial solve runs
    inside a live splice where solver latency stalls the straggler's worker
    (``max_evals`` caps each descent for the same reason).

    ``objective``: pure makespan (None / weight 0) keeps the selection
    bit-identical to the historical solver and just reports ``energy_j``;
    an energy-weighted objective scores candidates by
    ``makespan + weight * joules`` (DESIGN.md §16).  ``banned`` names
    device *indices* the solver must not place free tasks on — the elastic
    membership path (device loss) re-solves with the departed device
    banned so spec device tuples and clock names stay aligned while the
    shrunken cluster is genuinely enforced.
    """
    topo = BusTopology.from_spec(bus, devices)
    spec = bus.spec if isinstance(bus, BusTopology) else topo.spec
    n = len(tasks)
    if n == 0:
        z = [0.0] * len(devices)
        return GraphScheduleResult(z, 0.0, z, spec)
    banned = frozenset(banned) if banned else None
    pinned = dict(pinned) if pinned else {}
    free = [i for i in range(n) if i not in pinned]
    ckey = (tuple(devices), priority, spec) if cache is not None else None
    hit = cache.lookup(ckey) if cache is not None else None
    if hit is not None:
        order, tmpl = hit
        ctx = tmpl.rebind(clocks, ext)
    else:
        if priority == "rank":
            order = _rank_order(devices, tasks, edges)
        elif priority == "topo":
            order = _graph_topo_order(n, edges)
        else:
            raise ValueError(f"unknown priority {priority!r} "
                             "(expected 'rank' or 'topo')")
        ctx = GraphSimContext(devices, tasks, edges, topo, order, clocks,
                              ext)
        if cache is not None:
            cache.store(ckey, order, ctx)

    def finish(a) -> list[float]:
        # the engine replay on the (possibly cached) context — the same
        # single simulation loop ``graph_finish_times`` wraps, minus its
        # per-call context construction
        stf = GraphSimState(ctx, list(a))
        stf.advance(len(order))
        return stf.finish

    allowed = [j for j in range(len(devices))
               if banned is None or j not in banned]
    assign = [-1] * n
    for i, j in pinned.items():
        assign[i] = j
    evals = 0
    # the final head state's finish times, when a path produces them —
    # saves the closing ``finish(assign)`` replay (an extra full state
    # construction + suffix walk per solve on the re-plan hot path)
    task_fin: list[float] | None = None
    eft_init: tuple[GraphSimState, _SnapChain] | None = None
    if priority == "topo":
        solo = [-1] * n   # scratch assignment, reused across candidates
        for i in order:
            if i in pinned:
                continue
            best_j, best_t = allowed[0], math.inf
            for j in allowed:
                # myopic: the task alone, an empty timeline
                solo[i] = j
                t = graph_finish_times(devices, tasks, edges, solo,
                                       topology=topo, order=[i])[i]
                evals += 1
                if t < best_t - _EPS:
                    best_j, best_t = j, t
            solo[i] = -1
            assign[i] = best_j
    else:
        st, e, eft_chain = _eft_place(ctx, assign, pinned, banned)
        assign = st.assign
        evals += e
        task_fin = st.finish
        eft_init = (st, eft_chain)

    def makespan(a) -> float:
        return max(finish(a))

    energy_mode = objective is not None and not objective.is_makespan

    def score_of(a, fin) -> float:
        ms = max(fin)
        if not energy_mode:
            return ms
        return objective.score(ms, graph_energy(ctx, a, ms))

    if refine and free:
        # the exhaustive branch honours max_evals too: a latency-capped
        # partial solve (mid-graph splice) must not sneak up to
        # exhaustive_limit full-graph simulations through a small free set
        if len(allowed) ** len(free) <= min(exhaustive_limit, max_evals):
            fin0 = finish(assign)
            best_a, best_t = list(assign), score_of(assign, fin0)
            evals += 1
            for combo in itertools.product(allowed, repeat=len(free)):
                cand = list(assign)
                for i, j in zip(free, combo):
                    cand[i] = j
                t = score_of(cand, finish(cand))
                evals += 1
                if t < best_t - _EPS:
                    best_a, best_t = list(cand), t
            assign = best_a
            task_fin = None   # enumerate picked a new assignment; replay
        else:
            # Descend from the EFT placement AND from every degenerate
            # all-one-device assignment (the §3.4.3 caveat, in DAG form):
            # EFT's greedy early finishes can strand the schedule in a
            # local optimum *worse* than the best single device, and
            # single-task moves cannot escape it (moving one task of a
            # chain adds edge copies before its neighbours follow).
            # Seeding from the degenerate points both restores the
            # never-worse-than-one-device floor and lets the descent peel
            # whole chains off the fastest device one improvement at a
            # time.  Partial solves additionally seed from the plan being
            # replaced (``seed_assign``), so a re-plan is never worse than
            # staying locked in — under the re-fitted models.
            seeds = [list(assign)]
            best_a, best_t = None, math.inf
            best_fin: list[float] | None = None
            if seed_assign is not None:
                sa = list(seed_assign)
                if sa != seeds[0]:   # identical seed: don't split the pool
                    seeds.append(sa)
                # the straggler-rescue seed: every free task on the fastest
                # (re-fitted) device — the shape the re-plan usually wants
                # when one device just slowed down, and one the capped
                # descent cannot reliably reach from EFT local optima
                fastest = max(allowed,
                              key=lambda j: devices[j].effective_speed)
                rescue = list(assign)
                for i in free:
                    rescue[i] = fastest
                if rescue not in seeds:
                    seeds.append(rescue)
                # a partial solve runs inside a live splice: the eval
                # budget is one shared pool the seeds draw down in turn —
                # the old per-seed split (``max_evals // len(seeds)`` with
                # a floor of 40) let the *sum* overshoot the cap whenever
                # it was small (3 seeds x 40 at max_evals=60 spent double
                # the latency the splice asked for).  Every seed still
                # gets >= 1 eval — pricing the seed assignment itself —
                # preserving the never-worse-than-any-seed floor.
                remaining = max_evals
                for k, seed in enumerate(seeds):
                    share = max(1, remaining // (len(seeds) - k))
                    cand, e, t, fin = _descend_assign(
                        ctx, seed, free=free, max_evals=share, prune=prune,
                        init=eft_init if k == 0 else None,
                        objective=objective, banned=banned)
                    remaining = max(0, remaining - e)
                    evals += e
                    if best_a is None or t < best_t - _EPS:
                        best_a, best_t, best_fin = cand, t, fin
            else:
                for j in allowed:
                    one = list(assign)
                    for i in free:
                        one[i] = j
                    seeds.append(one)
                for k, seed in enumerate(seeds):
                    cand, e, t, fin = _descend_assign(
                        ctx, seed, free=free, max_evals=max_evals,
                        prune=prune, init=eft_init if k == 0 else None,
                        objective=objective, banned=banned)
                    evals += e
                    if best_a is None or t < best_t - _EPS:
                        best_a, best_t, best_fin = cand, t, fin
            assign = best_a
            task_fin = best_fin

    task_finish = task_fin if task_fin is not None else finish(assign)
    ops = [0.0] * len(devices)
    dev_finish = [0.0] * len(devices)
    for i, t in enumerate(tasks):
        if assign[i] < 0:
            continue
        ops[assign[i]] += float(t.ops)
        dev_finish[assign[i]] = max(dev_finish[assign[i]], task_finish[i])
    ms = max(task_finish)
    return GraphScheduleResult(ops=ops, makespan=ms,
                               finish_times=dev_finish, bus=spec,
                               iterations=evals, assign=list(assign),
                               order=list(order),
                               task_finish=list(task_finish),
                               energy_j=(graph_energy(ctx, assign, ms)
                                         if objective is not None else None))

# ---------------------------------------------------------------------------
# Template-tiled hierarchical solves (DESIGN.md §15)
# ---------------------------------------------------------------------------


class TemplatePlanCache:
    """Process-wide LRU of representative template placements.

    Keyed by ``(template signature, devices, topology spec, refine)``.
    The signature (``TemplatePartition.signatures[t]``) *is* the
    representative solve's entire input — per-slot costs, internal edges
    in slot coordinates, boundary arity — so a hit is exact no matter
    which graph produced it: structurally-equal stacks of different
    depths, different jobs, and different tenants share one entry (the
    module-level default instance is what ``solve_hierarchical`` uses
    when no cache is passed).  Thread-safe: the multi-tenant runtime
    plans from per-job worker threads."""

    def __init__(self, capacity: int = 256):
        self.capacity = int(capacity)
        self._lock = threading.Lock()
        self._entries: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def get(self, key) -> tuple[int, ...] | None:
        with self._lock:
            e = self._entries.get(key)
            if e is None:
                self.misses += 1
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            return e

    def put(self, key, assign: Sequence[int]) -> None:
        with self._lock:
            self._entries[key] = tuple(assign)
            self._entries.move_to_end(key)
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self.hits = 0
            self.misses = 0


#: the default cross-job, cross-tenant share point
SHARED_TEMPLATE_CACHE = TemplatePlanCache()

_POLISH_EVALS = 64        # seam-descent budget (see solve_hierarchical)
_POLISH_MAX_NODES = 4096  # snapshot-chain clones are O(n) each; above this
                          # the descent setup alone would eat the latency win


def solve_hierarchical(devices: Sequence[DeviceProfile],
                       tasks: Sequence[TaskSpec],
                       edges: Sequence[tuple[int, int]], *,
                       partition,
                       bus: str | BusTopology = "serialized",
                       refine: bool = True,
                       template_cache: TemplatePlanCache | None = None,
                       rep_max_evals: int = 800,
                       polish_evals: int = _POLISH_EVALS,
                       polish_max_nodes: int = _POLISH_MAX_NODES,
                       objective: Objective | None = None
                       ) -> GraphScheduleResult:
    """Template-tiled list scheduling for repetitive DAGs (DESIGN.md §15).

    ``partition`` is a ``TemplatePartition`` (``detect_templates`` /
    ``TaskGraph.template_partition``).  Instead of EFT-placing all ``n``
    tasks — superlinear in ``n`` through the per-candidate engine walks —
    the solver (1) list-schedules ONE representative instance per
    template (boundary in-bytes folded into the entry slots; memoized in
    the shared ``TemplatePlanCache``), (2) stitches that placement across
    every instance by slot, and (3) prices the stitched whole-graph
    assignment with a single exact engine simulation — the same
    single-loop ground truth every other path uses, so the reported
    makespan/finish times are byte-identical to a from-scratch simulation
    of the same assignment.

    Quality contract (the §14 shape): the result is never worse than the
    best all-one-device assignment — every degenerate placement is priced
    with a bound-aware early-exit walk and adopted if it wins — and on
    graphs small enough for the snapshot machinery (``polish_max_nodes``)
    PR-8's pruned descent additionally polishes the *seam* tasks (those
    with cross-instance edges), the only places where tiling can disagree
    with flat placement.  Cost: near-linear in instance count — templates
    are solved once each, stitching is O(n), and the engine walks are the
    O(n log n) simulation itself."""
    topo = BusTopology.from_spec(bus, devices)
    spec = bus.spec if isinstance(bus, BusTopology) else topo.spec
    n = len(tasks)
    if n == 0:
        z = [0.0] * len(devices)
        return GraphScheduleResult(z, 0.0, z, spec)
    cache = template_cache if template_cache is not None \
        else SHARED_TEMPLATE_CACHE
    dev_key = tuple(devices)
    evals = 0
    energy_mode = objective is not None and not objective.is_makespan

    # 1. one representative solve per template, cached by signature.  An
    # energy-weighted objective picks different representative placements,
    # so it gets its own cache entries; pure makespan keeps the historical
    # 4-tuple key (and therefore its warm entries).
    placements: list[tuple[int, ...]] = []
    for sig in partition.signatures:
        key = (sig, dev_key, spec, bool(refine))
        if energy_mode:
            key = key + (objective.energy_weight,)
        hit = cache.get(key)
        if hit is None:
            costs, internal, inb, _outb = sig
            extra_in: dict[int, float] = {}
            for slot, b in inb:
                extra_in[slot] = extra_in.get(slot, 0.0) + float(b)
            rep = [TaskSpec(f"t{k}", float(ops_k),
                            float(in_b) + extra_in.get(k, 0.0),
                            float(out_b))
                   for k, (ops_k, in_b, out_b) in enumerate(costs)]
            r = solve_list_schedule(devices, rep, internal, bus=topo,
                                    refine=refine,
                                    max_evals=rep_max_evals,
                                    objective=objective)
            evals += r.iterations
            hit = tuple(r.assign)
            cache.put(key, hit)
        placements.append(hit)

    # 2. stitch the template placements across every instance by slot
    assign = [0] * n
    for inst, t in zip(partition.instances, partition.template_of):
        pl = placements[t]
        for k, i in enumerate(inst):
            assign[i] = pl[k]

    # 3. exact pricing: one engine simulation of the stitched assignment
    order = _graph_topo_order(n, edges)
    ctx = GraphSimContext(devices, tasks, edges, topo, order)
    st = GraphSimState(ctx, assign)
    st.advance(len(order))
    evals += 1
    best_ms = max(st.finish)
    # ``best`` is the objective score (== makespan in pure-makespan mode).
    # Score >= makespan always (energy >= 0), so the makespan lower bounds
    # and bound-aware engine walks below stay valid prunes under a score.
    best = (objective.score(best_ms, graph_energy(ctx, assign, best_ms))
            if energy_mode else best_ms)
    task_fin = st.finish

    # 4. the all-one-device floor.  An all-on-j schedule serializes every
    # task's compute on j, so Σ compute is an exact lower bound on its
    # makespan — O(1) under a linear model.  Only devices that could
    # actually beat the stitched placement pay for the full bound-aware
    # engine walk; the rest are pruned analytically (at 10^4+ nodes the
    # three losing walks would otherwise dominate the whole solve).
    total_ops = sum(float(tk.ops) for tk in tasks)
    for j, dev in enumerate(devices):
        tm = dev.compute
        if isinstance(tm, LinearTimeModel):
            lower = tm.a * total_ops + tm.b * n
        else:
            lower = sum(tm(tk.ops) for tk in tasks)
        if lower >= best - _EPS:
            continue
        onej = [j] * n
        if onej == assign:
            continue
        tmp = GraphSimState(ctx, onej)
        done = tmp.advance(len(order), bound=best - _EPS)
        evals += 1
        if done:
            ms1 = max(tmp.finish)
            t1 = (objective.score(ms1, graph_energy(ctx, onej, ms1))
                  if energy_mode else ms1)
            if t1 < best - _EPS:
                assign, best, task_fin = onej, t1, tmp.finish
                best_ms = ms1

    # 5. seam polish: pruned descent over cross-instance tasks only
    if refine and polish_evals > 0 and n <= polish_max_nodes:
        inst_of = [-1] * n
        for a, inst in enumerate(partition.instances):
            for i in inst:
                inst_of[i] = a
        seams = sorted({x for u, v in edges
                        if inst_of[u] != inst_of[v] for x in (u, v)})
        if seams:
            cand, e, t2, fin = _descend_assign(ctx, list(assign),
                                               free=seams,
                                               max_evals=polish_evals,
                                               prune=True,
                                               objective=objective)
            evals += e
            if t2 < best - _EPS:
                assign, best, task_fin = cand, t2, fin
                best_ms = max(fin)

    ops = [0.0] * len(devices)
    dev_finish = [0.0] * len(devices)
    for i, tk in enumerate(tasks):
        ops[assign[i]] += float(tk.ops)
        dev_finish[assign[i]] = max(dev_finish[assign[i]], task_fin[i])
    return GraphScheduleResult(ops=ops, makespan=best_ms,
                               finish_times=dev_finish, bus=spec,
                               iterations=evals, assign=list(assign),
                               order=list(order),
                               task_finish=list(task_fin),
                               energy_j=(graph_energy(ctx, assign, best_ms)
                                         if objective is not None else None))
