"""POAS phase 2 — *Optimize*.

The paper formulates work division as a MILP (Eqs. 1–3): minimize the
makespan ``max_x(t_c(c_x) + t_y(c_x))`` subject to ``Σ c_x = N``, ``c_x ≥ 0``
and solves it with CPLEX.  CPLEX is unavailable here; the problem class is
small (a handful of devices) and the per-device time models are monotone
non-decreasing in ``c_x``, so we replace the external solver with:

* ``solve_bisection`` — exact for *any* monotone time model (subsumes the
  paper's linear MILP): bisect on the makespan T; feasibility is "can the
  devices jointly absorb N ops, each finishing by T?", which decomposes
  per-device on uncontended topologies.  On contended topologies (the
  paper's serialized shared bus, §3.4.3/Fig. 2) the greedy priority-ordered
  feasibility check prices every candidate against the *exact* unified
  timeline engine (``core.bus``) — including chunked pipelined copies — so
  the solver optimizes precisely what the simulator reports and the
  executor replays.
* ``solve_analytic`` — closed-form active-set LP for the linear,
  independent-bus case (for cross-checking, and it is what a CPLEX run of
  Eqs. 1–4 returns).
* ``solve_local_search`` — CSP fallback for arbitrary (non-convex) models,
  per the paper's §3.2 note that backtracking/local search handles models
  that are not linear/quadratic.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from .bus import BusTopology, engine_finish_times
from .device_model import DeviceProfile, priority_order

_EPS = 1e-12
_TINY = 1e-30   # probe op count: prices fixed costs (B panel, launch) only


@dataclasses.dataclass
class OptimizeResult:
    ops: list[float]                 # c_x per device (Σ = N)
    makespan: float                  # predicted total time
    finish_times: list[float]        # per-device predicted finish
    bus: str                         # "independent" | "serialized" | custom
    iterations: int = 0

    def shares(self) -> list[float]:
        n = sum(self.ops)
        return [c / n if n else 0.0 for c in self.ops]


# ---------------------------------------------------------------------------
# Feasibility: how many ops can each device absorb within makespan T?
# Both checks price candidates on the unified timeline engine, so the
# solver, the simulator, and the executor share one source of truth.
# ---------------------------------------------------------------------------


def _max_ops_single(devices: Sequence[DeviceProfile], i: int, T: float,
                    n: int, k: int, topo: BusTopology,
                    order: Sequence[int], N: float) -> float:
    """Largest c_i with device i's engine finish <= T, no contention."""
    c = [0.0] * len(devices)

    def fin(ci: float) -> float:
        c[i] = ci
        return engine_finish_times(devices, c, n, k, topology=topo,
                                   order=order)[i]

    if fin(_TINY) > T:      # fixed costs alone (B panel, launch) miss T
        return 0.0
    if fin(float(N)) <= T:  # the whole workload fits
        return float(N)
    lo, hi = 0.0, float(N)
    for _ in range(100):
        mid = 0.5 * (lo + hi)
        if fin(mid) <= T:
            lo = mid
        else:
            hi = mid
        if hi - lo <= max(1.0, lo) * 1e-9:
            break
    return lo


def _max_ops_serialized(devices: Sequence[DeviceProfile], order: Sequence[int],
                        T: float, n: int, k: int, topo: BusTopology,
                        N: float) -> list[float]:
    """Greedy priority-ordered assignment under a contended topology.

    Device i's candidate c_i is the largest value keeping the *whole*
    partial timeline's makespan within T — evaluated on the exact engine,
    so queueing on every link, compute overlap, no-copy devices starting at
    t = 0, and pipelined chunk boundaries are all priced exactly (the old
    linearized check both over-charged no-copy devices for bus time they
    never wait on and let output copies overlap input copies).  The engine
    makespan is monotone in every c_i, so greedy-max in priority order
    maximizes the total absorbed ops for a given T.
    """
    c = [0.0] * len(devices)
    for i in order:

        def span(ci: float) -> float:
            c[i] = ci
            return max(engine_finish_times(devices, c, n, k, topology=topo,
                                           order=order))

        if span(_TINY) > T:
            c[i] = 0.0
            continue
        if span(float(N)) <= T:
            c[i] = float(N)
            continue
        lo, hi = 0.0, float(N)
        for _ in range(100):
            mid = 0.5 * (lo + hi)
            if span(mid) <= T:
                lo = mid
            else:
                hi = mid
            if hi - lo <= max(1.0, lo) * 1e-9:
                break
        c[i] = lo
    return c


# ---------------------------------------------------------------------------
# Exact bisection solver
# ---------------------------------------------------------------------------


def solve_bisection(devices: Sequence[DeviceProfile], N: float, *,
                    n: int, k: int,
                    bus: str | BusTopology = "independent",
                    tol: float = 1e-9, polish: bool = True) -> OptimizeResult:
    """Minimize makespan by bisecting on T.

    ``bus`` is a legacy spec string ("independent" | "serialized") or a
    ``BusTopology``.  Feasibility prices every candidate on the exact
    unified timeline engine, so the check is exact for any topology and for
    chunked pipelined copies; the contended-topology result is additionally
    *polished* by coordinate descent on the same engine (the greedy
    priority-ordered assignment is not always the global optimum).
    """
    spec = bus.spec if isinstance(bus, BusTopology) else bus
    if N <= 0:
        z = [0.0] * len(devices)
        return OptimizeResult(z, 0.0, z, spec)
    topo = BusTopology.from_spec(bus, devices)
    order = priority_order(devices)
    contended = topo.is_contended()

    def capacity(T: float) -> list[float]:
        if contended:
            return _max_ops_serialized(devices, order, T, n, k, topo, N)
        return [_max_ops_single(devices, i, T, n, k, topo, order, N)
                for i in range(len(devices))]

    # bracket: every single-device assignment is feasible at its own engine
    # makespan; on a contended topology the greedy may interleave devices,
    # so the safe upper bound is the serial sum of those makespans.
    def single(i: int) -> float:
        one = [0.0] * len(devices)
        one[i] = N
        return max(engine_finish_times(devices, one, n, k, topology=topo,
                                       order=order))

    singles = [single(i) for i in range(len(devices))]
    t_lo = 0.0
    t_hi = sum(singles) if contended else min(singles)
    iters = 0
    for _ in range(200):
        iters += 1
        mid = 0.5 * (t_lo + t_hi)
        if sum(capacity(mid)) >= N:
            t_hi = mid
        else:
            t_lo = mid
        if t_hi - t_lo <= max(tol, t_hi * 1e-10):
            break
    caps = capacity(t_hi)
    total = sum(caps)
    # Scale back surplus so Σ c = N exactly, preferring to trim the devices
    # with the largest marginal cost (keeps the makespan at T*).
    if total > 0:
        scale = N / total
        ops = [c * scale for c in caps]
    else:  # pragma: no cover - degenerate
        ops = [N / len(devices)] * len(devices)
    if polish and contended and len(devices) > 1:
        ops = _descend(devices, ops, n, k, topo, order,
                       step0=N / 64.0, max_evals=1500)
    finish = _finish_times(devices, ops, n, k, topo, order)
    best = OptimizeResult(ops, max(finish), finish, spec, iterations=iters)
    # Degenerate single-device assignments are feasible points the split
    # can lose to on small workloads (copy overheads don't amortize — the
    # paper's §3.4.3 "significant amount of work" caveat).  Take the min.
    for i in range(len(devices)):
        one = [0.0] * len(devices)
        one[i] = N
        f1 = _finish_times(devices, one, n, k, topo, order)
        if max(f1) < best.makespan:
            best = OptimizeResult(one, max(f1), f1, spec, iterations=iters)
    return best


def _descend(devices: Sequence[DeviceProfile], ops0: Sequence[float],
             n: int, k: int, bus: str | BusTopology, order: Sequence[int], *,
             step0: float, max_evals: int) -> list[float]:
    """Pairwise-transfer coordinate descent on the exact timeline makespan."""
    ops = list(ops0)
    m = len(devices)

    def makespan(v):
        return max(_finish_times(devices, v, n, k, bus, order))

    best = makespan(ops)
    step = step0
    evals = 0
    while step > sum(ops0) * 1e-10 and evals < max_evals:
        improved = False
        for src in range(m):
            if ops[src] <= 0:
                continue
            for dst in range(m):
                if src == dst:
                    continue
                delta = min(step, ops[src])
                cand = list(ops)
                cand[src] -= delta
                cand[dst] += delta
                t = makespan(cand)
                evals += 1
                if t < best - _EPS:
                    ops, best, improved = cand, t, True
        if not improved:
            step *= 0.5
    return ops


def _finish_times(devices: Sequence[DeviceProfile], ops: Sequence[float],
                  n: int, k: int, bus: str | BusTopology,
                  order: Sequence[int] | None = None) -> list[float]:
    """Per-device finish times — the unified engine, nothing else.

    This used to be an independent re-implementation of the Fig. 2 timeline
    that (a) charged no-copy devices for bus queue time they never wait on
    and (b) reset the output-copy clock to 0, letting outputs overlap
    inputs on the supposedly serialized bus; both made the solver optimize
    a different objective than ``simulate_timeline`` measured.  Delegating
    to ``engine_finish_times`` makes solver/simulator agreement exact by
    construction."""
    return engine_finish_times(devices, ops, n, k, topology=bus, order=order)


# ---------------------------------------------------------------------------
# Analytic LP (linear models, independent bus)
# ---------------------------------------------------------------------------


def solve_analytic(devices: Sequence[DeviceProfile], N: float, *,
                   n: int, k: int) -> OptimizeResult:
    """Closed-form: at the optimum all devices with c_x>0 finish together.

    With linear t_x(c) = α_x c + β_x (α folds compute+copy slopes, β the
    intercepts), equalizing finish times gives
        T* = (N + Σ β_x/α_x) / (Σ 1/α_x)
    over the active set; devices whose β_x ≥ T* are dropped iteratively.

    Zero-slope devices (``LinearTimeModel(a=0, b=...)`` — constant time
    regardless of load) would divide by zero in the LP; they are held out
    of the active set and compared as "hand it everything" candidates
    (a zero-slope device finishes at β no matter how much it absorbs).
    """
    alphas, betas = [], []
    for d in devices:
        t0 = d.total_time(0.0, n, k)
        t1 = d.total_time(1e9, n, k)
        alphas.append((t1 - t0) / 1e9)
        betas.append(t0)
    zero = [i for i in range(len(devices)) if alphas[i] <= 0.0]
    active = [i for i in range(len(devices)) if alphas[i] > 0.0]
    T = math.inf
    if active:
        while True:
            num = N + sum(betas[i] / alphas[i] for i in active)
            den = sum(1.0 / alphas[i] for i in active)
            T = num / den
            drop = [i for i in active if betas[i] >= T - _EPS]
            if not drop:
                break
            active = [i for i in active if i not in drop]
            if not active:
                T = math.inf
                break
    if zero:
        j = min(zero, key=lambda i: betas[i])
        if betas[j] <= T:   # constant-time device beats (or is) the LP
            ops = [0.0] * len(devices)
            ops[j] = N
            finish = _finish_times(devices, ops, n, k, "independent")
            return OptimizeResult(ops, max(finish), finish, "independent")
    if not active:  # pragma: no cover
        raise RuntimeError("no device can make progress")
    ops = [0.0] * len(devices)
    for i in active:
        ops[i] = (T - betas[i]) / alphas[i]
    # normalize tiny numerical drift
    s = sum(ops)
    ops = [c * (N / s) for c in ops]
    finish = _finish_times(devices, ops, n, k, "independent")
    return OptimizeResult(ops, max(finish), finish, "independent")


# ---------------------------------------------------------------------------
# Local-search CSP fallback (paper §3.2: non-linear models)
# ---------------------------------------------------------------------------


def solve_local_search(devices: Sequence[DeviceProfile], N: float, *,
                       n: int, k: int, bus: str | BusTopology = "independent",
                       iters: int = 4000, seed: int = 0) -> OptimizeResult:
    """Coordinate-descent on op shares.  Works for arbitrary monotone models;
    used as a CSP-style fallback and as an independent check of bisection."""
    import numpy as np
    rng = np.random.default_rng(seed)
    m = len(devices)
    bus = BusTopology.from_spec(bus, devices)
    order = priority_order(devices)

    def makespan(ops):
        return max(_finish_times(devices, list(ops), n, k, bus, order))

    ops = np.full(m, N / m)
    best = makespan(ops)
    step = N / 4.0
    it = 0
    while step > N * 1e-9 and it < iters:
        improved = False
        for src in range(m):
            for dst in range(m):
                if src == dst or ops[src] <= 0:
                    continue
                delta = min(step, ops[src])
                cand = ops.copy()
                cand[src] -= delta
                cand[dst] += delta
                t = makespan(cand)
                it += 1
                if t < best - _EPS:
                    ops, best, improved = cand, t, True
        if not improved:
            step *= 0.5
    finish = _finish_times(devices, list(ops), n, k, bus, order)
    return OptimizeResult(list(ops), max(finish), finish, bus.spec,
                          iterations=it)
