"""The POAS framework object — Predict → Optimize → Adapt → Schedule.

POAS itself is a *generic model*: it does not schedule applications directly
but produces a DS-POAS (domain-specific POAS) when bound to a domain's
predictor/optimizer/adapter/scheduler (paper §3, Fig. 1).  The binding point
is the ``Domain`` protocol (``core.domain``); ``POAS.plan`` runs the four
phases in order, each phase's output feeding the next, memoizing solved
plans in a ``PlanCache`` keyed on (workload geometry, device models).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Hashable, Sequence

from .adapt import GemmPlan, ops_to_mnk
from .bus import BusTopology
from .device_model import DeviceProfile, priority_order
from .domain import Domain, FunctionDomain, PlanCache, Workload, register_domain
from .optimize import OptimizeResult, solve_bisection
from .schedule import (Schedule, DynamicScheduler, make_spec,
                       simulate_timeline)


@dataclasses.dataclass(frozen=True)
class GemmWorkload:
    m: int
    n: int
    k: int

    def total_ops(self) -> float:
        return float(self.m) * self.n * self.k


@dataclasses.dataclass
class POASPlan:
    """Fully-adapted, schedulable plan (the DS-POAS output)."""
    workload: Any
    optimize: OptimizeResult
    adapted: Any          # domain-specific (GemmPlan for hgemms)
    schedule: Schedule


class POAS:
    """Generic four-phase pipeline over a bound ``Domain``.

    ``plan`` consults the ``PlanCache`` first: a hit skips the optimize
    solve (the expensive phase) entirely.  Pass ``cache=None`` to disable.
    """

    def __init__(self, domain: Domain, *, cache: PlanCache | None = None):
        self.domain = domain
        self.cache = cache
        # Dynamic domains re-fit models mid-run; hook cache invalidation so
        # a refit can never serve a plan solved under stale models.
        dyn = getattr(domain, "dyn", None)
        if cache is not None and isinstance(dyn, DynamicScheduler):
            dyn.add_refit_listener(cache.invalidate)

    @classmethod
    def from_callables(cls, *, predict: Callable[[], Sequence[DeviceProfile]],
                       optimize: Callable[..., OptimizeResult],
                       adapt: Callable[..., Any],
                       schedule: Callable[..., Schedule],
                       name: str = "custom") -> "POAS":
        """Legacy construction from four loose callables (uncached)."""
        return cls(FunctionDomain(name, predict, optimize, adapt, schedule))

    def plan(self, workload: Workload) -> POASPlan:
        devices = list(self.domain.predict())
        key: Hashable | None = None
        if self.cache is not None:
            key = self.cache.key(self.domain, devices, workload)
            hit = self.cache.get(key)
            if hit is not None:
                # shallow copy carrying the *caller's* workload; the solved
                # phases (optimize/adapted/schedule) are shared
                return dataclasses.replace(hit, workload=workload)
        opt = self.domain.optimize(devices, workload)
        adapted = self.domain.adapt(devices, opt, workload)
        sched = self.domain.schedule(devices, adapted, workload)
        plan = POASPlan(workload=workload, optimize=opt, adapted=adapted,
                        schedule=sched)
        if self.cache is not None and key is not None:
            # strip the workload before caching: for domains like serving
            # dispatch it holds the full request batch, which must not be
            # pinned for the cache's lifetime
            self.cache.put(key, dataclasses.replace(plan, workload=None))
        return plan


# ---------------------------------------------------------------------------
# The GEMM domain (paper §4 — hgemms builds on this)
# ---------------------------------------------------------------------------


@register_domain("gemm")
class GemmDomain:
    """The paper's DS-POAS for heterogeneous GEMM."""

    name = "gemm"

    def __init__(self, devices: Sequence[DeviceProfile], *,
                 bus: str | BusTopology = "serialized",
                 dynamic: bool = False):
        self._devices = list(devices)
        self.topology = BusTopology.from_spec(bus, self._devices)
        self.bus = self.topology.spec
        self.dyn = DynamicScheduler(self._devices, bus=self.topology) \
            if dynamic else None

    def predict(self) -> Sequence[DeviceProfile]:
        return self.dyn.snapshot() if self.dyn is not None else self._devices

    def optimize(self, devices: Sequence[DeviceProfile],
                 w: GemmWorkload) -> OptimizeResult:
        return solve_bisection(devices, w.total_ops(), n=w.n, k=w.k,
                               bus=self.topology)

    def adapt(self, devices: Sequence[DeviceProfile], opt: OptimizeResult,
              w: GemmWorkload) -> GemmPlan:
        return ops_to_mnk(devices, opt.ops, w.m, w.n, w.k)

    def schedule(self, devices: Sequence[DeviceProfile], plan: GemmPlan,
                 w: GemmWorkload) -> Schedule:
        ops = [float(a.m) * w.n * w.k for a in plan.assignments]
        # price the chunk counts adapt actually produced (alignment grain
        # can cap a device below its nominal pipeline_chunks)
        chunks = [max(1, len(a.chunk_rows)) for a in plan.assignments]
        tl = simulate_timeline(devices, ops, w.n, w.k,
                               topology=self.topology, chunks=chunks)
        finish = [tl.device_finish(d.name) for d in devices]
        res = OptimizeResult(ops=ops, makespan=tl.makespan,
                             finish_times=finish, bus=self.bus)
        return Schedule(result=res, timeline=tl,
                        priorities=priority_order(list(devices)),
                        spec=make_spec(devices, ops, w.n, w.k, self.topology,
                                       chunks))

    def cost_signature(self, w: GemmWorkload) -> Hashable:
        return (w.m, w.n, w.k)


def make_gemm_poas(devices: Sequence[DeviceProfile], *,
                   bus: str | BusTopology = "serialized",
                   dynamic: bool = False,
                   cache: bool = True) -> tuple[POAS, DynamicScheduler | None]:
    """Build the paper's DS-POAS for GEMM (hgemms uses this)."""
    domain = GemmDomain(devices, bus=bus, dynamic=dynamic)
    poas = POAS(domain, cache=PlanCache() if cache else None)
    return poas, domain.dyn
