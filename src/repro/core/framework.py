"""The POAS framework object — Predict → Optimize → Adapt → Schedule.

POAS itself is a *generic model*: it does not schedule applications directly
but produces a DS-POAS (domain-specific POAS) when bound to a domain's
predictor/optimizer/adapter/scheduler (paper §3, Fig. 1).  ``POAS.plan`` runs
the four phases in order, each phase's output feeding the next.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Protocol, Sequence

from .adapt import GemmPlan, ops_to_mnk
from .device_model import DeviceProfile
from .optimize import OptimizeResult, solve_bisection
from .schedule import Schedule, StaticScheduler, DynamicScheduler, simulate_timeline


class Workload(Protocol):
    """Anything with a total op count; domains add their own geometry."""

    def total_ops(self) -> float: ...


@dataclasses.dataclass(frozen=True)
class GemmWorkload:
    m: int
    n: int
    k: int

    def total_ops(self) -> float:
        return float(self.m) * self.n * self.k


@dataclasses.dataclass
class POASPlan:
    """Fully-adapted, schedulable plan (the DS-POAS output)."""
    workload: Any
    optimize: OptimizeResult
    adapted: Any          # domain-specific (GemmPlan for hgemms)
    schedule: Schedule


class POAS:
    """Generic four-phase pipeline.  Bind domain callables to specialize."""

    def __init__(self, *,
                 predict: Callable[[], Sequence[DeviceProfile]],
                 optimize: Callable[[Sequence[DeviceProfile], Workload], OptimizeResult],
                 adapt: Callable[[Sequence[DeviceProfile], OptimizeResult, Workload], Any],
                 schedule: Callable[[Sequence[DeviceProfile], Any, Workload], Schedule]):
        self._predict = predict
        self._optimize = optimize
        self._adapt = adapt
        self._schedule = schedule

    def plan(self, workload: Workload) -> POASPlan:
        devices = list(self._predict())
        opt = self._optimize(devices, workload)
        adapted = self._adapt(devices, opt, workload)
        sched = self._schedule(devices, adapted, workload)
        return POASPlan(workload=workload, optimize=opt, adapted=adapted,
                        schedule=sched)


def make_gemm_poas(devices: Sequence[DeviceProfile], *,
                   bus: str = "serialized",
                   dynamic: bool = False) -> tuple[POAS, DynamicScheduler | None]:
    """Build the paper's DS-POAS for GEMM (hgemms uses this)."""
    dyn = DynamicScheduler(devices, bus=bus) if dynamic else None

    def predict() -> Sequence[DeviceProfile]:
        return dyn.devices if dyn is not None else devices

    def optimize(devs: Sequence[DeviceProfile], w: GemmWorkload) -> OptimizeResult:
        return solve_bisection(devs, w.total_ops(), n=w.n, k=w.k, bus=bus)

    def adapt(devs, opt: OptimizeResult, w: GemmWorkload) -> GemmPlan:
        return ops_to_mnk(devs, opt.ops, w.m, w.n, w.k)

    def schedule(devs, plan: GemmPlan, w: GemmWorkload) -> Schedule:
        ops = [float(a.m) * w.n * w.k for a in plan.assignments]
        tl = simulate_timeline(devs, ops, w.n, w.k)
        res = OptimizeResult(ops=ops, makespan=tl.makespan,
                             finish_times=[tl.makespan] * len(ops), bus=bus)
        from .device_model import priority_order
        return Schedule(result=res, timeline=tl,
                        priorities=priority_order(list(devs)))

    return POAS(predict=predict, optimize=optimize, adapt=adapt,
                schedule=schedule), dyn
