"""POAS core — the paper's contribution (Predict, Optimize, Adapt, Schedule).

Public API:
    DeviceProfile, LinearTimeModel, RooflineTimeModel, CopyModel
    fit_linear, Profiler, relative_error, rmse
    solve_bisection, solve_analytic, solve_local_search, OptimizeResult
    ops_to_mnk, decompose_square, squareness, GemmPlan
    Link, BusTopology, build_timeline, engine_finish_times, with_pipeline
    StaticScheduler, DynamicScheduler, simulate_timeline, Timeline
    Domain, PlanCache, register_domain, get_domain, list_domains
    OverlappedExecutor, DeviceTask
    POAS, GemmWorkload, GemmDomain, make_gemm_poas, HGemms
    TaskGraph, TaskNode, TaskGraphDomain, solve_list_schedule,
    build_graph_timeline, transformer_block, CoExecutionRuntime
"""
from .bus import (BusEvent, BusTopology, ClockState, GraphSimContext,
                  GraphSimState, GraphTimelineSpec,
                  Link, TaskSpec, Timeline, TimelineSpec,
                  build_graph_timeline, build_timeline, carry_clocks,
                  engine_finish_times, graph_finish_times)
from .device_model import (CopyModel, DeviceProfile, LinearTimeModel, NO_COPY,
                           RooflineTimeModel, paper_mach1, paper_mach2,
                           priority_order, tpu_group, with_pipeline,
                           TPU_PEAK_FLOPS, TPU_HBM_BW, TPU_ICI_BW,
                           TPU_VMEM_BYTES)
from .predict import (Profiler, fit_linear, host_cpu_runner, load_profiles,
                      relative_error, rmse, save_profiles, simulated_runner)
from .optimize import (GraphScheduleResult, MAKESPAN_OBJECTIVE, Objective,
                       OptimizeResult, SHARED_TEMPLATE_CACHE,
                       TemplatePlanCache, divisible_energy, graph_energy,
                       solve_analytic, solve_bisection, solve_hierarchical,
                       solve_list_schedule, solve_local_search)
from .adapt import (DeviceAssignment, GemmPlan, SubProduct, decompose_square,
                    ops_to_mnk, squareness)
from .schedule import (DynamicScheduler, Schedule, StaticScheduler,
                       simulate_graph_timeline, simulate_timeline)
from .graph import (GraphPlan, TaskGraph, TaskGraphDomain, TaskNode,
                    TemplatePartition, detect_templates, diamond, moe_block,
                    moe_stack, ssm_block, ssm_stack, transformer_block,
                    transformer_stack, verify_graph_dependencies)
from .domain import (Domain, FunctionDomain, PlanCache, QoS, TIER_BATCH,
                     TIER_LATENCY, Workload, device_signature, get_domain,
                     list_domains, register_domain)
from .executor import (DeviceTask, JobHandle, OverlappedExecutor, StreamCore,
                       TicketBus)
from .framework import (GemmDomain, GemmWorkload, POAS, POASPlan,
                        make_gemm_poas)
from .hgemms import ExecutionReport, HGemms
from .runtime import (AdmissionRejected, CoExecutionRuntime, FairAdmission,
                      ObservationPump, ReplanRecord, StreamJob, Tenant,
                      copy_throttled, model_sleep_tasks, throttled,
                      truth_from_profiles, verify_stream_invariants)

__all__ = [
    "BusEvent", "BusTopology", "Link", "build_timeline",
    "engine_finish_times",
    "CopyModel", "DeviceProfile", "LinearTimeModel", "NO_COPY",
    "RooflineTimeModel", "paper_mach1", "paper_mach2", "priority_order",
    "tpu_group", "with_pipeline", "TPU_PEAK_FLOPS", "TPU_HBM_BW",
    "TPU_ICI_BW", "TPU_VMEM_BYTES",
    "Profiler", "fit_linear", "host_cpu_runner", "load_profiles",
    "relative_error", "rmse", "save_profiles", "simulated_runner",
    "OptimizeResult", "solve_analytic", "solve_bisection",
    "solve_local_search",
    "DeviceAssignment", "GemmPlan", "SubProduct", "decompose_square",
    "ops_to_mnk", "squareness",
    "BusEvent", "DynamicScheduler", "Schedule", "StaticScheduler",
    "Timeline", "simulate_timeline",
    "Domain", "FunctionDomain", "PlanCache", "QoS", "TIER_BATCH",
    "TIER_LATENCY", "Workload", "device_signature",
    "get_domain", "list_domains", "register_domain",
    "DeviceTask", "JobHandle", "OverlappedExecutor", "StreamCore",
    "TicketBus",
    "GemmDomain", "GemmWorkload", "POAS", "POASPlan", "make_gemm_poas",
    "ExecutionReport", "HGemms",
    "ClockState", "TimelineSpec", "carry_clocks",
    "AdmissionRejected", "CoExecutionRuntime", "FairAdmission",
    "ObservationPump", "ReplanRecord", "StreamJob", "Tenant",
    "copy_throttled", "model_sleep_tasks", "throttled",
    "truth_from_profiles", "verify_stream_invariants",
    "GraphSimContext", "GraphSimState",
    "GraphTimelineSpec", "TaskSpec", "build_graph_timeline",
    "graph_finish_times", "GraphScheduleResult", "solve_list_schedule",
    "simulate_graph_timeline",
    "GraphPlan", "TaskGraph", "TaskGraphDomain", "TaskNode", "diamond",
    "moe_block", "moe_stack", "ssm_block", "ssm_stack",
    "transformer_block", "transformer_stack",
    "verify_graph_dependencies",
    "SHARED_TEMPLATE_CACHE", "TemplatePlanCache", "TemplatePartition",
    "detect_templates", "solve_hierarchical",
    "MAKESPAN_OBJECTIVE", "Objective", "divisible_energy", "graph_energy",
]
