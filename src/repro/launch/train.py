"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-12b --tiny \
        --steps 200 --batch 8 --seq 128 [--ckpt-dir /tmp/ckpt] [--resume]

Composes the full stack: config → Model → AdamW → synthetic data pipeline →
fault-tolerant runner (checkpoint/restart) → POAS hetero-DP split when more
than one pod profile is given.  On this container run with ``--tiny``; on a
TPU fleet drop the flag and launch one process per host.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCH_IDS, get_config, get_tiny_config
from ..data.pipeline import DataConfig, Prefetcher, SyntheticLM
from ..distributed.elastic import FaultTolerantRunner, RunnerConfig
from ..models import Model
from ..training.optim import AdamW, cosine_schedule
from ..training.step import make_train_step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-12b", choices=ARCH_IDS)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_tiny_config(args.arch) if args.tiny else get_config(args.arch)
    model = Model(cfg)
    opt = AdamW(learning_rate=cosine_schedule(args.lr, warmup=20,
                                              total=args.steps),
                state_dtype=jnp.float32 if args.tiny else jnp.bfloat16)

    params = model.init(jax.random.PRNGKey(args.seed))
    state = {"params": params, "opt": opt.init(params)}
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.2f}M "
          f"steps={args.steps} batch={args.batch}x{args.seq}")

    data = SyntheticLM(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, seed=args.seed,
        embed_dim=cfg.d_model if cfg.frontend != "none" else 0))
    step_fn = jax.jit(make_train_step(model, opt))

    def wrapped(state, batch):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        new_state, metrics = step_fn(state, batch)
        return new_state, metrics

    losses = []

    def on_metrics(step, metrics):
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == 1:
            print(f"step {step:5d}  loss {losses[-1]:.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"lr {float(metrics['lr']):.2e}", flush=True)

    start_step = 0
    if args.ckpt_dir:
        runner = FaultTolerantRunner(
            RunnerConfig(checkpoint_dir=args.ckpt_dir,
                         checkpoint_every=args.ckpt_every),
            step_fn=wrapped, state=state)
        if args.resume and runner.restore_latest():
            print(f"resumed from step {runner.step}")
        t0 = time.time()
        runner.run(Prefetcher(data.stream(runner.step)), args.steps,
                   on_metrics=on_metrics)
        dt = time.time() - t0
    else:
        t0 = time.time()
        pf = Prefetcher(data.stream(0))
        for step in range(1, args.steps + 1):
            state, metrics = wrapped(state, next(pf))
            on_metrics(step, metrics)
        dt = time.time() - t0

    if len(losses) >= 20:
        first = np.mean(losses[:5])
        last = np.mean(losses[-5:])
        print(f"loss {first:.4f} -> {last:.4f} "
              f"({'improved' if last < first else 'NOT improved'}) "
              f"in {dt:.0f}s ({dt/max(len(losses),1)*1e3:.0f} ms/step)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
