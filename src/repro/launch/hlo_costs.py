"""HLO cost counter with while-loop trip-count multiplication.

XLA's ``compiled.cost_analysis()`` visits each ``while`` body ONCE, so any
scanned model (layer scan, flash KV scan, chunked loss) is undercounted by
the trip count — for a 95-layer model that's a ~100× error.  This module
parses the post-optimization HLO text (``compiled.as_text()``), builds the
computation graph, and accumulates per-device:

* ``flops``      — dot ops (2·|out|·K) + reduces, bodies × known_trip_count;
* ``bytes``      — HBM traffic modeled as Σ(operand + output bytes) over
                   computation-level ops (fusion boundaries only — fused
                   interiors are on-chip), likewise trip-multiplied;
* ``collectives``— per-kind operand bytes × trip counts.

Trip counts come from ``backend_config={"known_trip_count":{"n":...}}``
which scan-lowered loops always carry.
"""
from __future__ import annotations

import dataclasses
import json
import re
from functools import lru_cache

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.+)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w.\-]+)\s*\(.*\)\s*->.*{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_RE = re.compile(r"(?:calls|body|to_apply)=(%[\w.\-]+)")
_COND_RE = re.compile(r"condition=(%[\w.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops that move no HBM bytes themselves
_FREE_OPS = {"get-tuple-element", "tuple", "parameter", "bitcast", "constant",
             "after-all", "partition-id", "replica-id", "iota", "domain"}


def _type_bytes(type_str: str) -> int:
    """Bytes of a (possibly tuple) HLO type string."""
    return sum(
        DTYPE_BYTES.get(dt, 4) * _numel(dims)
        for dt, dims in _SHAPE_RE.findall(type_str))


def _numel(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    kernelized_bytes: float = 0.0   # flash-loop traffic: VMEM-resident on TPU
    transcendentals: float = 0.0
    collective_bytes: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})
    collective_counts: dict = dataclasses.field(
        default_factory=lambda: {k: 0 for k in COLLECTIVES})

    def add(self, other: "Cost", times: float = 1.0, *,
            compute_only: bool = False, kernelize: bool = False) -> None:
        self.flops += other.flops * times
        self.transcendentals += other.transcendentals * times
        if not compute_only:
            if kernelize:
                self.kernelized_bytes += (other.bytes
                                          + other.kernelized_bytes) * times
            else:
                self.bytes += other.bytes * times
                self.kernelized_bytes += other.kernelized_bytes * times
        for k in COLLECTIVES:
            self.collective_bytes[k] += other.collective_bytes[k] * times
            self.collective_counts[k] += int(other.collective_counts[k] * times)


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.computations: dict[str, list[str]] = {}
        self._parse_computations(hlo_text)
        self._memo: dict[str, Cost] = {}

    def _parse_computations(self, text: str) -> None:
        cur = None
        for line in text.splitlines():
            hdr = _COMP_HDR_RE.match(line)
            if hdr:
                cur = hdr.group(1)
                self.computations[cur] = []
                continue
            if line.startswith("}"):
                cur = None
                continue
            if cur is not None and line.strip():
                self.computations[cur].append(line)

    # -- per-computation local symbol table -------------------------------

    @staticmethod
    def _defs(lines: list[str]) -> dict[str, str]:
        out = {}
        for ln in lines:
            m = _DEF_RE.match(ln)
            if m:
                out[m.group(1)] = m.group(2)
        return out

    def cost_of(self, comp: str) -> Cost:
        if comp in self._memo:
            return self._memo[comp]
        self._memo[comp] = Cost()  # break cycles defensively
        lines = self.computations.get(comp, [])
        defs = self._defs(lines)
        total = Cost()
        for ln in lines:
            m = _DEF_RE.match(ln)
            if not m:
                continue
            rhs = m.group(2)
            # type string is everything up to the op name
            op_m = re.match(r"((?:\([^)]*\)|[a-z0-9\[\],{}\s]*?))\s*"
                            r"([a-z][a-z0-9\-]*)\(", rhs)
            if not op_m:
                continue
            type_str, op = op_m.group(1), op_m.group(2)
            out_bytes = _type_bytes(type_str)
            operand_names = self._operands(rhs, op)
            in_bytes = sum(_type_bytes(defs[o].split("(")[0])
                           for o in operand_names if o in defs)

            if op in _FREE_OPS or op == "copy":
                continue
            if op == "while":
                trips = 1
                tm = _TRIP_RE.search(rhs)
                if tm:
                    trips = int(tm.group(1))
                # flash-attention loops are the Pallas kernel on TPU: their
                # interior HBM traffic is VMEM-resident there (tracked
                # separately so both memory terms can be reported)
                kernelize = "flash_attention" in rhs
                body = _CALL_RE.search(rhs)
                if body:
                    total.add(self.cost_of(body.group(1)), trips,
                              kernelize=kernelize)
                cond = _COND_RE.search(rhs)
                if cond:
                    total.add(self.cost_of(cond.group(1)), trips,
                              kernelize=kernelize)
                continue
            if op in ("fusion", "custom-call", "conditional",
                      "reduce", "reduce-window", "sort", "scatter", "map",
                      "select-and-scatter", "all-reduce", "reduce-scatter"):
                # fused interiors are on-chip: count their compute, not bytes
                for cm in _CALL_RE.finditer(rhs):
                    total.add(self.cost_of(cm.group(1)), compute_only=True)
                root = self._fusion_root(rhs) if op == "fusion" else None
                if root == "dynamic-update-slice":
                    # in-place cache update: bill update+indices twice, not
                    # the whole (aliased) cache
                    op_bytes = [_type_bytes(defs[o].split("(")[0])
                                for o in operand_names if o in defs]
                    total.bytes += 2 * (sum(op_bytes) - max(op_bytes,
                                                            default=0))
                    continue
                if root in ("gather", "dynamic-slice"):
                    # bill gathered rows + indices, not the whole table
                    op_bytes = [_type_bytes(defs[o].split("(")[0])
                                for o in operand_names if o in defs]
                    total.bytes += (2 * out_bytes
                                    + sum(op_bytes) - max(op_bytes, default=0))
                    continue
            elif op == "call":
                for cm in _CALL_RE.finditer(rhs):
                    total.add(self.cost_of(cm.group(1)))
            base = op.replace("-start", "")
            if base in COLLECTIVES:
                total.collective_bytes[base] += in_bytes
                total.collective_counts[base] += 1
                total.bytes += in_bytes + out_bytes
                continue
            if op.endswith("-done"):
                continue
            if op == "dot":
                total.flops += self._dot_flops(rhs, defs, type_str)
                total.bytes += in_bytes + out_bytes
                continue
            # indexed ops touch only the accessed elements (XLA's own cost
            # analysis models these the same way): counting the full operand
            # would bill a one-token cache update for the whole KV cache.
            if op in ("dynamic-slice", "gather"):
                total.bytes += 2 * out_bytes
                continue
            if op == "dynamic-update-slice":
                upd = self._operands(rhs, op)
                upd_bytes = (_type_bytes(defs[upd[1]].split("(")[0])
                             if len(upd) > 1 and upd[1] in defs else out_bytes)
                total.bytes += 2 * upd_bytes
                continue
            if op == "scatter":
                ops_ = self._operands(rhs, op)
                upd_bytes = (_type_bytes(defs[ops_[2]].split("(")[0])
                             if len(ops_) > 2 and ops_[2] in defs else out_bytes)
                total.bytes += 2 * upd_bytes
                continue
            if op == "convolution":
                # not used by these models; approximate via output*K
                total.flops += 2 * _numel_from_type(type_str)
                total.bytes += in_bytes + out_bytes
                continue
            if op in ("reduce", "reduce-window"):
                total.flops += sum(
                    _numel_from_type(defs[o].split("(")[0])
                    for o in operand_names if o in defs) / max(len(operand_names), 1)
                total.bytes += in_bytes + out_bytes
                continue
            if op in ("exponential", "tanh", "log", "rsqrt", "power"):
                total.transcendentals += _numel_from_type(type_str)
            # generic op (incl. fusion boundaries): HBM traffic only
            total.bytes += in_bytes + out_bytes
        self._memo[comp] = total
        return total

    def _fusion_root(self, rhs: str) -> str | None:
        """Root op kind of the fusion's called computation (or None)."""
        m = _CALL_RE.search(rhs)
        if not m:
            return None
        for ln in self.computations.get(m.group(1), []):
            if "ROOT" in ln:
                for k in ("dynamic-update-slice", "dynamic-slice", "gather"):
                    if f" {k}(" in ln:
                        return k
        return None

    @staticmethod
    def _operands(rhs: str, op: str) -> list[str]:
        # Operands are in the first (...) right after the op name.  Depending
        # on the XLA version the list is either bare names ("dot(%a, %b)") or
        # typed ("dot(f32[8,8]{1,0} %a, ...)" — types may themselves contain
        # parenthesized tuple types), so scan to the balanced close paren and
        # pull the %-prefixed names.
        i = rhs.find(op + "(")
        if i < 0:
            return []
        start = i + len(op) + 1
        depth, j = 1, start
        while j < len(rhs) and depth:
            if rhs[j] == "(":
                depth += 1
            elif rhs[j] == ")":
                depth -= 1
            j += 1
        inner = rhs[start:j - 1]
        names = re.findall(r"%[\w.\-]+", inner)
        if names:
            return names
        return [s.strip() for s in inner.split(",") if s.strip()]

    def _dot_flops(self, rhs: str, defs: dict[str, str], type_str: str
                   ) -> float:
        out_elems = _numel_from_type(type_str)
        ops = self._operands(rhs, "dot")
        k = 1
        mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
        if ops and mc and ops[0] in defs:
            lhs_dims = _shape_dims(defs[ops[0]].split("(")[0])
            for idx in mc.group(1).split(","):
                if idx and int(idx) < len(lhs_dims):
                    k *= lhs_dims[int(idx)]
        return 2.0 * out_elems * k

    def entry_cost(self) -> Cost:
        entry = None
        for name in self.computations:
            if ".entry" in name or name.endswith("main.0") or entry is None:
                entry = name
        # the ENTRY computation is the last one in the file by convention;
        # more robustly, pick the one that is not referenced anywhere.
        referenced = set()
        for lines in self.computations.values():
            for ln in lines:
                for cm in _CALL_RE.finditer(ln):
                    referenced.add(cm.group(1))
                cm = _COND_RE.search(ln)
                if cm:
                    referenced.add(cm.group(1))
        roots = [c for c in self.computations if c not in referenced]
        total = Cost()
        for r in roots:
            total.add(self.cost_of(r))
        return total


def _numel_from_type(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    return _numel(m.group(2)) if m else 0


def breakdown(hlo_text: str, top: int = 25) -> list[tuple[str, float]]:
    """Top HBM-byte contributors: (op_kind @ metadata-scope, bytes including
    loop trip multiplication).  Diagnostic for the §Perf loop."""
    model = HloCostModel(hlo_text)
    # compute trip multiplier per computation by walking from roots
    mult: dict[str, float] = {}
    referenced = set()
    for lines in model.computations.values():
        for ln in lines:
            for cm in _CALL_RE.finditer(ln):
                referenced.add(cm.group(1))
    roots = [c for c in model.computations if c not in referenced]

    def walk(comp: str, m: float):
        mult[comp] = mult.get(comp, 0.0) + m
        for ln in model.computations.get(comp, []):
            dm = _DEF_RE.match(ln)
            if not dm:
                continue
            rhs = dm.group(2)
            trips = 1
            if " while(" in rhs:
                tm = _TRIP_RE.search(rhs)
                trips = int(tm.group(1)) if tm else 1
            for cm in _CALL_RE.finditer(rhs):
                walk(cm.group(1), m * trips)
            cnd = _COND_RE.search(rhs)
            if cnd:
                walk(cnd.group(1), m * trips)

    for r in roots:
        walk(r, 1.0)

    agg: dict[str, float] = {}
    for comp, lines in model.computations.items():
        m = mult.get(comp, 0.0)
        if m == 0.0:
            continue
        defs = model._defs(lines)
        for ln in lines:
            dm = _DEF_RE.match(ln)
            if not dm:
                continue
            rhs = dm.group(2)
            op_m = re.match(r"((?:\([^)]*\)|[a-z0-9\[\],{}\s]*?))\s*"
                            r"([a-z][a-z0-9\-]*)\(", rhs)
            if not op_m:
                continue
            type_str, op = op_m.group(1), op_m.group(2)
            if op in _FREE_OPS or op in ("while", "copy"):
                continue
            out_b = _type_bytes(type_str)
            in_b = sum(_type_bytes(defs[o].split("(")[0])
                       for o in model._operands(rhs, op) if o in defs)
            scope = ""
            sm = re.search(r'op_name="([^"]+)"', rhs)
            if sm:
                parts = sm.group(1).split("/")
                scope = "/".join(p for p in parts
                                 if not p.startswith("jit("))[:70]
            agg.setdefault(f"{op} @ {scope}", 0.0)
            agg[f"{op} @ {scope}"] += (in_b + out_b) * m
    return sorted(agg.items(), key=lambda kv: -kv[1])[:top]


def analyze(hlo_text: str) -> dict:
    cost = HloCostModel(hlo_text).entry_cost()
    return {
        "flops": cost.flops,
        "bytes": cost.bytes + cost.kernelized_bytes,
        "bytes_kernelized": cost.bytes,   # flash-loop traffic in VMEM (TPU)
        "flash_loop_bytes": cost.kernelized_bytes,
        "transcendentals": cost.transcendentals,
        "collective_bytes": cost.collective_bytes,
        "collective_counts": cost.collective_counts,
    }
