import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=" +
                           os.environ.get("REPRO_DRYRUN_DEVICES", "512"))
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell with
ShapeDtypeStruct inputs (no allocation) and extract the roofline terms.

The two lines above MUST run before any other import (jax locks the device
count on first init).  Do not replicate this flag anywhere else — tests and
benchmarks must see 1 device.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun \
        [--arch <id> ...] [--shape <name> ...] [--multipod|--singlepod|--both]
        [--out experiments/dryrun] [--skip-done]
"""
import argparse  # noqa: E402
import json  # noqa: E402
import math  # noqa: E402
import re  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from ..configs import ARCH_IDS, get_config  # noqa: E402
from ..distributed.context import use_mesh  # noqa: E402
from ..distributed.sharding import (batch_shardings, cache_shardings,  # noqa: E402
                                    param_shardings, replicated)
from ..models import Model  # noqa: E402
from ..training.step import (default_optimizer, make_serve_step,  # noqa: E402
                             make_prefill_step, make_train_step)
from .mesh import make_production_mesh  # noqa: E402
from .specs import SHAPES, input_specs, param_specs, shape_applicable  # noqa: E402

COLLECTIVE_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[^=]*=\s*(\([^)]*\)|\S+)\s")
SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|pred|f64|s64|c64)\[([\d,]*)\]")

DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
               "u8": 1, "pred": 1, "f64": 8, "s64": 8, "c64": 8}


def collective_bytes(hlo_text: str) -> dict:
    """Sum per-device operand bytes of every collective op in the HLO."""
    out = {"all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    count = {k: 0 for k in out}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?:\(?)([a-z0-9\[\],{}\s\-]*?)"
                     r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                     r"collective-permute)(?:-start|-done)?\(", s)
        if not m:
            continue
        kind = m.group(2)
        if "-done" in s.split("(")[0]:
            continue  # avoid double count of async pairs
        # operand bytes: shapes on the result side of the assignment
        lhs = s.split("=", 1)[1]
        shapes = SHAPE_RE.findall(lhs.split("(")[0])
        nbytes = 0
        for dt, dims in shapes:
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * DTYPE_BYTES.get(dt, 4)
        out[kind] += nbytes
        count[kind] += 1
    out["counts"] = count
    return out


def model_flops(cfg, shape) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE); decode counts one token/seq."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.batch * shape.seq
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.batch * shape.seq
        return 2.0 * n * tokens
    return 2.0 * n * shape.batch  # decode: one token per sequence


def run_cell(arch: str, shape_name: str, mesh, multi_pod: bool, *,
             tiny: bool = False, shape=None, opt: bool = False) -> dict:
    from ..configs import get_tiny_config
    cfg = get_tiny_config(arch) if tiny else get_config(arch)
    if opt:
        # §Perf optimized configuration (beyond-paper; see EXPERIMENTS.md
        # §Perf): sequence-parallel residual stream + larger loss slabs.
        # (Sequence-sharding the decode cache was tried and REFUTED — the
        # SPMD select-based DUS doubles decode HBM traffic.)
        import dataclasses as _dc
        cfg = _dc.replace(cfg, seq_shard_activations=True, loss_chunk=8192)
    shape = shape or SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skip", "reason": why}
    model = Model(cfg)
    t0 = time.time()
    with use_mesh(mesh):
        specs = input_specs(cfg, shape)
        pspecs = param_specs(cfg)
        pshard = param_shardings(pspecs, mesh)
        bshard = batch_shardings(specs["batch"], mesh)

        def attach(tree, shardings):
            return jax.tree.map(
                lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
                tree, shardings)

        params_in = attach(pspecs, pshard)
        batch_in = attach(specs["batch"], bshard)

        if shape.kind == "train":
            opt = default_optimizer(cfg)
            ostate = jax.eval_shape(lambda p: opt.init(p), pspecs)
            oshard = param_shardings(ostate, mesh)  # same rules; scalars -> P()
            state_in = {"params": params_in, "opt": attach(ostate, oshard)}
            step = make_train_step(model, opt)
            lowered = jax.jit(step).lower(state_in, batch_in)
        elif shape.kind == "prefill":
            step = make_prefill_step(model)
            lowered = jax.jit(step).lower(params_in, batch_in)
        else:
            cshard = cache_shardings(specs["cache"], mesh, seq_shard=False)
            cache_in = attach(specs["cache"], cshard)
            step = make_serve_step(model)
            # pin the output cache sharding to the input's — otherwise XLA
            # picks an unsharded layout for the scan's stacked cache output
            # and gathers/upcasts the whole cache every step (§Perf iter. 4)
            lowered = jax.jit(
                step, out_shardings=(None, cshard),
                donate_argnums=(1,),
            ).lower(params_in, cache_in, batch_in)

        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        try:
            mem = compiled.memory_analysis()
        except Exception:  # noqa: BLE001 - backend-dependent
            mem = None
        try:
            cost = compiled.cost_analysis() or {}
        except Exception:  # noqa: BLE001
            cost = {}
        if isinstance(cost, (list, tuple)):  # jax <= 0.4.x returns [dict]
            cost = cost[0] if cost else {}
        hlo = compiled.as_text()
        # trip-count-aware HLO accounting (XLA's cost_analysis counts while
        # bodies once — wrong by ~num_layers for scanned models)
        from .hlo_costs import analyze
        acc = analyze(hlo)

    chips = math.prod(mesh.devices.shape)
    rec = {
        "arch": arch, "shape": shape.name,
        "mesh": "multi" if multi_pod else "single",
        "chips": chips,
        "status": "ok",
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops_per_device": acc["flops"],
        "bytes_per_device": acc["bytes"],
        "bytes_per_device_kernelized": acc["bytes_kernelized"],
        "flash_loop_bytes_per_device": acc["flash_loop_bytes"],
        "collective_bytes_per_device": acc["collective_bytes"],
        "collective_counts": acc["collective_counts"],
        "xla_flops_per_device_loopbody_once": cost.get("flops", -1.0),
        "xla_bytes_per_device_loopbody_once": cost.get("bytes accessed", -1.0),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", -1),
            "output_bytes": getattr(mem, "output_size_in_bytes", -1),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", -1),
            "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0)
                           + getattr(mem, "temp_size_in_bytes", 0)),
        },
        "model_flops_global": model_flops(cfg, shape),
        "hlo_bytes": len(hlo),
    }
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", nargs="*", default=ARCH_IDS)
    ap.add_argument("--shape", nargs="*", default=list(SHAPES))
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--singlepod", action="store_true")
    ap.add_argument("--both", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--tiny", action="store_true",
                    help="reduced configs (pipeline validation only)")
    ap.add_argument("--opt", action="store_true",
                    help="§Perf optimized config (SP activations, "
                         "seq-sharded decode cache, bigger loss slabs)")
    ap.add_argument("--mesh-shape", default=None,
                    help="debug override, e.g. 2,2,2 (axes pod,data,model)")
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    args = ap.parse_args(argv)

    modes = []
    if args.both or (not args.multipod and not args.singlepod):
        modes = [False, True]
    else:
        if args.singlepod:
            modes.append(False)
        if args.multipod:
            modes.append(True)

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    failures = 0
    for multi in modes:
        if args.mesh_shape:
            dims = tuple(int(x) for x in args.mesh_shape.split(","))
            axes = (("pod", "data", "model") if len(dims) == 3
                    else ("data", "model"))
            mesh = jax.make_mesh(dims, axes,
                                 devices=jax.devices()[:math.prod(dims)])
            if multi:
                continue  # custom mesh: run once
        else:
            mesh = make_production_mesh(multi_pod=multi)
        print(f"=== mesh {'multi(2,16,16)' if multi else 'single(16,16)'} "
              f"axes={mesh.axis_names} devices={math.prod(mesh.devices.shape)}",
              flush=True)
        for arch in args.arch:
            for shape_name in args.shape:
                tag = f"{arch}__{shape_name}__{'multi' if multi else 'single'}"
                path = outdir / f"{tag}.json"
                if args.skip_done and path.exists():
                    rec = json.loads(path.read_text())
                    if rec.get("status") in ("ok", "skip"):
                        print(f"[cached] {tag}", flush=True)
                        continue
                t0 = time.time()
                shape = SHAPES[shape_name]
                if args.seq or args.batch:
                    import dataclasses as _dc
                    shape = _dc.replace(shape, seq=args.seq or shape.seq,
                                        batch=args.batch or shape.batch)
                try:
                    rec = run_cell(arch, shape_name, mesh, multi,
                                   tiny=args.tiny, shape=shape, opt=args.opt)
                except Exception as e:  # noqa: BLE001
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": "multi" if multi else "single",
                           "status": "error", "error": repr(e),
                           "traceback": traceback.format_exc()[-4000:]}
                    failures += 1
                path.write_text(json.dumps(rec, indent=2, default=float))
                status = rec["status"]
                extra = (f"compile={rec.get('compile_s')}s "
                         f"flops/dev={rec.get('flops_per_device', 0):.3g}"
                         if status == "ok" else rec.get("reason",
                                                        rec.get("error", "")))
                print(f"[{status}] {tag} ({time.time()-t0:.0f}s) {extra}",
                      flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
