"""Serving driver: batched generation with POAS dispatch.

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-12b --tiny \
        --requests 16 --max-new 8 [--groups 2]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import ARCH_IDS, get_config, get_tiny_config
from ..core.device_model import DeviceProfile, LinearTimeModel, NO_COPY
from ..models import Model
from ..serving.engine import PoasDispatcher, Request, ServingEngine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-12b", choices=ARCH_IDS)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--groups", type=int, default=2,
                    help="simulated replica groups for POAS dispatch")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_tiny_config(args.arch) if args.tiny else get_config(args.arch)
    if cfg.frontend != "none":
        print(f"{cfg.name}: stub-frontend arch — serving demo uses token "
              "inputs; pick a text arch")
        return 0
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    engine = ServingEngine(model, params)

    rng = np.random.default_rng(args.seed)
    reqs = [Request(uid=i,
                    tokens=rng.integers(1, cfg.vocab_size,
                                        int(rng.integers(4, 32))),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]

    groups = [DeviceProfile(f"group{i}", "tpu-group",
                            LinearTimeModel(a=(1 + i) * 1e-6, b=1e-3),
                            NO_COPY)
              for i in range(args.groups)]
    disp = PoasDispatcher(groups)
    buckets = disp.split(reqs)
    shares = (disp.last_plan.optimize.shares() if disp.last_plan
              else [0.0] * len(groups))
    print(f"dispatch[{disp.domain.name}]:", [len(b) for b in buckets],
          f"shares {[f'{s:.2f}' for s in shares]} "
          f"predicted makespan {disp.predicted_makespan(buckets)*1e3:.2f}ms")
    disp.split(reqs)   # identical batch geometry -> PlanCache hit
    print(f"plan cache: {disp.poas.cache.stats()}")

    t0 = time.perf_counter()
    done = []
    for bucket in buckets:
        done += engine.generate(bucket)
    dt = time.perf_counter() - t0
    total = sum(len(c.tokens) for c in done)
    print(f"{len(done)} completions, {total} tokens in {dt:.2f}s "
          f"({total/dt:.0f} tok/s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
