"""Assigned input shapes and ShapeDtypeStruct stand-ins for every model input.

Shapes (per assignment):
    train_4k     seq=4096    global_batch=256   -> train_step
    prefill_32k  seq=32768   global_batch=32    -> serve prefill
    decode_32k   seq=32768   global_batch=128   -> serve_step (1 new token,
                                                  KV cache of seq_len)
    long_500k    seq=524288  global_batch=1     -> serve_step; SSM/SWA archs
                                                  only (sub-quadratic)
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..models import Model
from ..models.config import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str       # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """long_500k needs sub-quadratic attention (see DESIGN.md §5)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: 524k decode skipped per assignment"
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for the step function's data inputs."""
    B, S = shape.batch, shape.seq
    dt = jnp.dtype(cfg.dtype)
    if shape.kind == "train":
        batch = {"labels": _sds((B, S), jnp.int32)}
        if cfg.frontend != "none":
            batch["embeds"] = _sds((B, S, cfg.d_model), dt)
        else:
            batch["tokens"] = _sds((B, S), jnp.int32)
        return {"batch": batch}
    if shape.kind == "prefill":
        batch = ({"embeds": _sds((B, S, cfg.d_model), dt)}
                 if cfg.frontend != "none"
                 else {"tokens": _sds((B, S), jnp.int32)})
        return {"batch": batch}
    # decode: one new token against a seq_len cache
    model = Model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(batch=B, max_len=S))
    step = ({"embeds": _sds((B, 1, cfg.d_model), dt)}
            if cfg.frontend != "none"
            else {"tokens": _sds((B, 1), jnp.int32)})
    return {"cache": cache, "batch": step}


def param_specs(cfg: ArchConfig) -> dict:
    model = Model(cfg)
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
