"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single-pod: 16×16 = 256 chips, axes
("data", "model").  Multi-pod: 2×16×16 = 512 chips, axes
("pod", "data", "model") — the leading "pod" axis crosses the DCN.
"""
from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devices)} — "
            "run under launch/dryrun.py (it forces 512 host devices) or on "
            "real hardware")
    return jax.make_mesh(shape, axes, devices=devices[:need])


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Tiny mesh for unit tests (requires forced host device count)."""
    need = math.prod(shape)
    return jax.make_mesh(shape, axes, devices=jax.devices()[:need])
