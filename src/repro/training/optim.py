"""Optimizers — AdamW (dtype-configurable states) and Adafactor-style
factored second moment for the largest models, plus global-norm clipping and
LR schedules.  Pure pytree transforms (no external deps); optimizer states
inherit the parameter sharding specs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


# ---------------------------------------------------------------------------
# LR schedules
# ---------------------------------------------------------------------------


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    floor: float = 0.1) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        step = step.astype(F32)
        warm = base_lr * step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos)
    return lr


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: Any = jnp.float32   # bf16 halves optimizer memory (400B archs)

    def init(self, params):
        zeros = lambda p: jnp.zeros(p.shape, dtype=self.state_dtype)
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
        }

    def _lr(self, step):
        if callable(self.learning_rate):
            return self.learning_rate(step)
        return jnp.asarray(self.learning_rate, F32)

    def update(self, grads, state, params):
        step = state["step"] + 1
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-12))
        lr = self._lr(step)
        b1, b2 = self.b1, self.b2
        bc1 = 1 - b1 ** step.astype(F32)
        bc2 = 1 - b2 ** step.astype(F32)

        def upd(g, m, v, p):
            g = g.astype(F32) * scale
            m_new = b1 * m.astype(F32) + (1 - b1) * g
            v_new = b2 * v.astype(F32) + (1 - b2) * g * g
            mh = m_new / bc1
            vh = v_new / bc2
            delta = mh / (jnp.sqrt(vh) + self.eps)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                delta = delta + self.weight_decay * p.astype(F32)
            new_p = (p.astype(F32) - lr * delta).astype(p.dtype)
            return new_p, m_new.astype(self.state_dtype), v_new.astype(self.state_dtype)

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_state = {"step": step, "m": new_m, "v": new_v}
        metrics = {"grad_norm": gnorm, "lr": lr}
        return new_params, new_state, metrics


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(F32) ** 2) for l in leaves))


# ---------------------------------------------------------------------------
# Adafactor-style factored second moment (for the 400B-class archs)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FactoredAdam:
    """First moment in bf16, second moment factored over the two largest
    dims of >=2D params (O(n+m) instead of O(nm) memory)."""
    learning_rate: Callable | float = 3e-4
    b1: float = 0.9
    decay: float = 0.99
    eps: float = 1e-30
    clip_norm: float = 1.0
    weight_decay: float = 0.0

    def init(self, params):
        def second(p):
            if p.ndim < 2:
                return {"v": jnp.zeros(p.shape, F32)}
            return {"vr": jnp.zeros(p.shape[:-1], F32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], F32)}
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16),
                              params),
            "v": jax.tree.map(second, params,
                              is_leaf=lambda x: isinstance(x, jax.Array)),
        }

    def _lr(self, step):
        if callable(self.learning_rate):
            return self.learning_rate(step)
        return jnp.asarray(self.learning_rate, F32)

    def update(self, grads, state, params):
        step = state["step"] + 1
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-12))
        lr = self._lr(step)
        d = self.decay

        def upd(g, m, v, p):
            g = g.astype(F32) * scale
            g2 = g * g + self.eps
            if p.ndim < 2:
                v_new = {"v": d * v["v"] + (1 - d) * g2}
                precond = jax.lax.rsqrt(v_new["v"])
            else:
                vr = d * v["vr"] + (1 - d) * g2.mean(axis=-1)
                vc = d * v["vc"] + (1 - d) * g2.mean(axis=-2)
                v_new = {"vr": vr, "vc": vc}
                rfac = jax.lax.rsqrt(
                    vr / jnp.maximum(vr.mean(axis=-1, keepdims=True), self.eps))
                cfac = jax.lax.rsqrt(vc)
                precond = rfac[..., None] * cfac[..., None, :]
            m_new = self.b1 * m.astype(F32) + (1 - self.b1) * g
            delta = m_new * precond
            if p.ndim >= 2 and self.weight_decay:
                delta = delta + self.weight_decay * p.astype(F32)
            new_p = (p.astype(F32) - lr * delta).astype(p.dtype)
            return new_p, m_new.astype(jnp.bfloat16), v_new

        is_v = lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)
        flat_p, tree = jax.tree_util.tree_flatten(params)
        flat_g = jax.tree_util.tree_leaves(grads)
        flat_m = jax.tree_util.tree_leaves(state["m"])
        flat_v = jax.tree_util.tree_flatten(state["v"], is_leaf=is_v)[0]
        outs = [upd(g, m, v, p)
                for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_params = jax.tree_util.tree_unflatten(tree, [o[0] for o in outs])
        new_m = jax.tree_util.tree_unflatten(tree, [o[1] for o in outs])
        vtree = jax.tree_util.tree_structure(state["v"], is_leaf=is_v)
        new_v = jax.tree_util.tree_unflatten(vtree, [o[2] for o in outs])
        return new_params, {"step": step, "m": new_m, "v": new_v}, \
            {"grad_norm": gnorm, "lr": lr}
