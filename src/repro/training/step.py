"""Train / serve step builders — the functions the dry-run lowers and the
training loop executes.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..models import Model
from ..models.config import ArchConfig
from .optim import AdamW, FactoredAdam, cosine_schedule


def default_optimizer(cfg: ArchConfig):
    """bf16 AdamW states by default; factored second moment for ≥100B params
    (the 400B-class archs can't hold full Adam states on one pod)."""
    lr = cosine_schedule(3e-4, warmup=200, total=10_000)
    if cfg.param_count() > 100e9:
        return FactoredAdam(learning_rate=lr)
    return AdamW(learning_rate=lr, state_dtype=jnp.bfloat16)


def make_train_step(model: Model, optimizer) -> Callable:
    def train_step(state: dict, batch: dict) -> tuple[dict, dict]:
        def loss_fn(p):
            return model.loss(p, batch)

        loss, grads = jax.value_and_grad(loss_fn)(state["params"])
        new_params, new_opt, metrics = optimizer.update(
            grads, state["opt"], state["params"])
        metrics["loss"] = loss
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_eval_step(model: Model) -> Callable:
    def eval_step(params, batch):
        return model.loss(params, batch)
    return eval_step


def make_prefill_step(model: Model) -> Callable:
    def prefill_step(params, batch):
        return model.prefill(params, batch)
    return prefill_step


def make_serve_step(model: Model) -> Callable:
    def serve_step(params, cache, batch):
        return model.decode_step(params, cache, batch)
    return serve_step


def init_state(model: Model, optimizer, key) -> dict:
    params = model.init(key)
    return {"params": params, "opt": optimizer.init(params)}
