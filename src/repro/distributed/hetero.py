"""POAS-driven heterogeneous data parallelism — the paper's scheduler as the
framework's batch partitioner (DESIGN.md §3.2).

Pods (or pod-slices) are POAS "devices": per-pod throughput is predicted by
a linear model over tokens (``ops`` ≙ tokens × FLOPs/token), the min-makespan
solver splits the global batch, and the Adapt phase rounds each share to the
pod's shard grain (data_shards × microbatch).  The Dynamic scheduler re-fits
from measured step times, so a straggling pod automatically sheds load —
straggler mitigation without preemption.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from ..core.device_model import DeviceProfile, LinearTimeModel, NO_COPY
from ..core.optimize import solve_bisection
from ..core.schedule import DynamicScheduler


@dataclasses.dataclass(frozen=True)
class PodProfile:
    name: str
    chips: int
    peak_flops: float           # per chip
    derate: float = 1.0         # thermal / generation derate
    grain: int = 1              # batch rows must be a multiple (data shards)


def pod_device(p: PodProfile, flops_per_token: float) -> DeviceProfile:
    """A pod as a POAS device; 'ops' are tokens."""
    tok_per_s = p.chips * p.peak_flops * p.derate * 0.4 / flops_per_token
    return DeviceProfile(
        p.name, "tpu-group",
        LinearTimeModel(a=1.0 / tok_per_s, b=2e-3),
        NO_COPY, align_m=p.grain)


@dataclasses.dataclass
class BatchSplit:
    sizes: list[int]           # per-pod batch rows (sum == global batch)
    predicted_step_s: float

    def offsets(self) -> list[int]:
        out, acc = [], 0
        for s in self.sizes:
            out.append(acc)
            acc += s
        return out


class HeteroBatchScheduler:
    """Static or dynamic POAS split of the global batch across pods."""

    def __init__(self, pods: Sequence[PodProfile], *, flops_per_token: float,
                 seq_len: int, dynamic: bool = True):
        self.pods = list(pods)
        self.seq_len = seq_len
        self.flops_per_token = flops_per_token
        devices = [pod_device(p, flops_per_token) for p in pods]
        self.dyn = DynamicScheduler(devices, bus="independent") if dynamic \
            else None
        self.devices = devices

    def _solve(self, global_batch: int) -> BatchSplit:
        devices = self.dyn.devices if self.dyn else self.devices
        tokens = float(global_batch * self.seq_len)
        res = solve_bisection(devices, tokens, n=1, k=1, bus="independent")
        # Adapt: tokens -> batch rows, rounded to each pod's grain
        raw = [c / self.seq_len for c in res.ops]
        sizes = [int(r // p.grain) * p.grain
                 for r, p in zip(raw, self.pods)]
        rem = global_batch - sum(sizes)
        order = sorted(range(len(self.pods)),
                       key=lambda i: -(raw[i] - sizes[i]))
        j = 0
        while rem > 0:
            i = order[j % len(order)]
            add = min(self.pods[i].grain, rem)
            sizes[i] += add
            rem -= add
            j += 1
        while rem < 0:
            i = max(range(len(sizes)), key=lambda q: sizes[q])
            take = min(self.pods[i].grain, sizes[i], -rem)
            sizes[i] -= take
            rem += take
        pred = max(d.compute(s * self.seq_len)
                   for d, s in zip(devices, sizes) if s > 0)
        return BatchSplit(sizes=sizes, predicted_step_s=pred)

    def plan(self, global_batch: int) -> BatchSplit:
        return self._solve(global_batch)

    def observe(self, pod_index: int, batch_rows: int, seconds: float):
        """Feed a measured per-pod step time (dynamic mode)."""
        if self.dyn is None:
            return
        self.dyn.observe(pod_index, float(batch_rows * self.seq_len), seconds)

    def imbalance(self, split: BatchSplit) -> float:
        """Predicted idle fraction of the fastest-finishing pod."""
        devices = self.dyn.devices if self.dyn else self.devices
        times = [d.compute(s * self.seq_len)
                 for d, s in zip(devices, split.sizes) if s > 0]
        if not times:
            return 0.0
        return 1.0 - min(times) / max(times)
