"""POAS-driven heterogeneous data parallelism — the paper's scheduler as the
framework's batch partitioner (DESIGN.md §3.2).

Pods (or pod-slices) are POAS "devices": per-pod throughput is predicted by
a linear model over tokens (``ops`` ≙ tokens × FLOPs/token), the min-makespan
solver splits the global batch, and the Adapt phase rounds each share to the
pod's shard grain (data_shards × microbatch) via the core grain-rounding
primitive.  All four phases are bound as the registered ``train-step``
domain; ``HeteroBatchScheduler`` is a facade over it.  The Dynamic scheduler
re-fits from measured step times — which invalidates the plan cache — so a
straggling pod automatically sheds load: straggler mitigation without
preemption.
"""
from __future__ import annotations

import dataclasses
from typing import Hashable, Mapping, Sequence

import numpy as np

from ..core.adapt import round_shares_to_grain
from ..core.bus import BusTopology, Timeline
from ..core.device_model import (DeviceProfile, LinearTimeModel, NO_COPY,
                                 priority_order)
from ..core.domain import PlanCache, register_domain
from ..core.framework import POAS
from ..core.optimize import OptimizeResult, solve_bisection
from ..core.runtime import ObservationPump
from ..core.schedule import (DynamicScheduler, Schedule, make_spec,
                             simulate_timeline)


@dataclasses.dataclass(frozen=True)
class PodProfile:
    name: str
    chips: int
    peak_flops: float           # per chip
    derate: float = 1.0         # thermal / generation derate
    grain: int = 1              # batch rows must be a multiple (data shards)


def pod_device(p: PodProfile, flops_per_token: float) -> DeviceProfile:
    """A pod as a POAS device; 'ops' are tokens."""
    tok_per_s = p.chips * p.peak_flops * p.derate * 0.4 / flops_per_token
    return DeviceProfile(
        p.name, "tpu-group",
        LinearTimeModel(a=1.0 / tok_per_s, b=2e-3),
        NO_COPY, align_m=p.grain)


@dataclasses.dataclass(frozen=True)
class TrainStepWorkload:
    """One data-parallel training step; ops are tokens."""

    global_batch: int
    seq_len: int

    def total_ops(self) -> float:
        return float(self.global_batch * self.seq_len)


@dataclasses.dataclass(frozen=True)
class BatchSplit:
    """Frozen: instances are shared via the PlanCache, so caller mutation
    would corrupt every future cache hit."""

    sizes: tuple[int, ...]     # per-pod batch rows (sum == global batch)
    predicted_step_s: float

    def __post_init__(self):
        object.__setattr__(self, "sizes", tuple(self.sizes))

    def offsets(self) -> list[int]:
        out, acc = [], 0
        for s in self.sizes:
            out.append(acc)
            acc += s
        return out


@register_domain("train-step")
class TrainStepDomain:
    """DS-POAS for the heterogeneous data-parallel training step."""

    name = "train-step"

    def __init__(self, pods: Sequence[PodProfile], *, flops_per_token: float,
                 seq_len: int, dynamic: bool = True):
        self.pods = list(pods)
        self.seq_len = seq_len
        self.flops_per_token = flops_per_token
        self._devices = [pod_device(p, flops_per_token) for p in self.pods]
        # pods feed through their own interconnects, not a shared host bus:
        # each gets an independent link in the topology (no contention)
        self.topology = BusTopology.independent(self._devices)
        self.dyn = DynamicScheduler(self._devices, bus=self.topology) \
            if dynamic else None

    def predict(self) -> Sequence[DeviceProfile]:
        return self.dyn.snapshot() if self.dyn is not None else self._devices

    def set_pods(self, pods: Sequence[PodProfile]) -> None:
        """Elastic membership change-point (DESIGN.md §16): replace the
        pod set.  Dynamic mode carries re-fitted models for surviving
        pods (matched by name) and invalidates hooked plan caches."""
        self.pods = list(pods)
        self._devices = [pod_device(p, self.flops_per_token)
                         for p in self.pods]
        self.topology = BusTopology.independent(self._devices)
        if self.dyn is not None:
            self.dyn.bus = self.topology
            self.dyn.set_devices(self._devices)

    def set_devices(self, devices: Sequence[DeviceProfile], *,
                    topology=None) -> None:
        """Runtime-facing membership hook (``CoExecutionRuntime.device_
        leave/join``): the given profiles are authoritative; pod rows are
        matched by name, and a joiner announced as a raw ``DeviceProfile``
        gets a derived pod row (grain from its row alignment)."""
        by_name = {p.name: p for p in self.pods}
        self.pods = [by_name.get(d.name,
                                 PodProfile(d.name, chips=1, peak_flops=0.0,
                                            grain=max(1, d.align_m)))
                     for d in devices]
        self._devices = list(devices)
        self.topology = BusTopology.independent(self._devices)
        if self.dyn is not None:
            self.dyn.bus = self.topology
            self.dyn.set_devices(self._devices)

    def optimize(self, devices: Sequence[DeviceProfile],
                 w: TrainStepWorkload) -> OptimizeResult:
        return solve_bisection(devices, w.total_ops(), n=1, k=1,
                               bus=self.topology)

    def adapt(self, devices: Sequence[DeviceProfile], opt: OptimizeResult,
              w: TrainStepWorkload) -> BatchSplit:
        # tokens -> batch rows, rounded to each pod's grain
        raw = [c / self.seq_len for c in opt.ops]
        sizes = round_shares_to_grain(
            raw, [p.grain for p in self.pods], w.global_batch)
        pred = max((d.compute(s * self.seq_len)
                    for d, s in zip(devices, sizes) if s > 0), default=0.0)
        return BatchSplit(sizes=sizes, predicted_step_s=pred)

    def schedule(self, devices: Sequence[DeviceProfile], split: BatchSplit,
                 w: TrainStepWorkload) -> Schedule:
        ops = [float(s * self.seq_len) for s in split.sizes]
        tl = simulate_timeline(devices, ops, 1, 1, topology=self.topology)
        res = OptimizeResult(ops=ops, makespan=tl.makespan,
                             finish_times=[tl.device_finish(d.name)
                                           for d in devices],
                             bus="independent")
        return Schedule(result=res, timeline=tl,
                        priorities=priority_order(list(devices)),
                        spec=make_spec(devices, ops, 1, 1, self.topology))

    def cost_signature(self, w: TrainStepWorkload) -> Hashable:
        return (w.global_batch, w.seq_len)


class HeteroBatchScheduler:
    """Static or dynamic POAS split of the global batch across pods.

    Facade over the registered ``train-step`` domain; repeated ``plan``
    calls for the same global batch are served from the ``PlanCache`` until
    a measured observation re-fits a pod model.
    """

    def __init__(self, pods: Sequence[PodProfile], *, flops_per_token: float,
                 seq_len: int, dynamic: bool = True, cache: bool = True):
        self.pods = list(pods)
        self.seq_len = seq_len
        self.flops_per_token = flops_per_token
        self.domain = TrainStepDomain(pods, flops_per_token=flops_per_token,
                                      seq_len=seq_len, dynamic=dynamic)
        self.poas = POAS(self.domain, cache=PlanCache() if cache else None)
        # the one feedback path (DESIGN.md §9): measured step times flow
        # through the same ObservationPump the streaming runtime uses
        self.pump: ObservationPump | None = None
        if self.domain.dyn is not None:
            self.pump = ObservationPump(self.domain.dyn,
                                        [p.name for p in self.pods])

    @property
    def dyn(self) -> DynamicScheduler | None:
        return self.domain.dyn

    @property
    def devices(self) -> list[DeviceProfile]:
        return list(self.domain.predict())

    @property
    def plan_cache(self) -> PlanCache | None:
        return self.poas.cache

    def plan(self, global_batch: int) -> BatchSplit:
        w = TrainStepWorkload(global_batch=global_batch, seq_len=self.seq_len)
        return self.poas.plan(w).adapted

    def observe(self, pod_index: int, batch_rows: int, seconds: float):
        """Feed a measured per-pod step time (dynamic mode)."""
        if self.pump is None:
            return
        self.pump.observe(self.pods[pod_index].name,
                          float(batch_rows * self.seq_len), seconds)

    def feed_step(self, split: BatchSplit,
                  measured: "Timeline | Mapping[str, float]") -> int:
        """Feed one training step's measurements through the pump.

        ``measured`` is either a measured ``Timeline`` (per-pod compute
        events, e.g. from the streaming runtime) or a plain mapping of pod
        name -> step seconds.  Returns the number of observations fed.
        """
        if self.pump is None:
            return 0
        ops = {p.name: float(s * self.seq_len)
               for p, s in zip(self.pods, split.sizes) if s > 0}
        if isinstance(measured, Timeline):
            return self.pump.feed(measured, ops)
        fed = 0
        for name, seconds in measured.items():
            if ops.get(name, 0.0) > 0.0:
                self.pump.observe(name, ops[name], float(seconds))
                fed += 1
        return fed

    def pod_leave(self, name: str) -> None:
        """Pod departure as a membership change-point: shrink the split
        domain (surviving pods keep their re-fitted models), drop the
        plan cache, re-key the pump — the next ``plan`` solves on the
        smaller cluster."""
        pods = [p for p in self.pods if p.name != name]
        if len(pods) == len(self.pods):
            return
        if not pods:
            raise ValueError(f"pod {name!r} is the last pod; cannot leave")
        self.pods = pods
        self.domain.set_pods(pods)
        if self.poas.cache is not None:
            self.poas.cache.invalidate()
        if self.pump is not None:
            self.pump.index = {p.name: i for i, p in enumerate(pods)}

    def pod_join(self, pod: PodProfile) -> None:
        """Pod arrival: widen the split domain at the next ``plan``."""
        if any(p.name == pod.name for p in self.pods):
            return
        pods = self.pods + [pod]
        self.pods = pods
        self.domain.set_pods(pods)
        if self.poas.cache is not None:
            self.poas.cache.invalidate()
        if self.pump is not None:
            self.pump.index = {p.name: i for i, p in enumerate(pods)}

    def imbalance(self, split: BatchSplit) -> float:
        """Predicted idle fraction of the fastest-finishing pod."""
        devices = self.domain.predict()
        times = [d.compute(s * self.seq_len)
                 for d, s in zip(devices, split.sizes) if s > 0]
        if not times:
            return 0.0
        return 1.0 - min(times) / max(times)
