"""Sharding rules: parameter/optimizer/activation/cache PartitionSpecs.

Conventions (see DESIGN.md §8):
* batch dims shard over ("pod","data") — pure DP across pods (grad all-reduce
  crosses the DCN once per step);
* weights shard over "model" (TP/EP) plus "data" (FSDP / ZeRO-3) on a large
  non-TP dim, replicated across "pod" so weight collectives stay on ICI;
* a dim is sharded over an axis only if divisible by the axis size — rules
  degrade to replication rather than producing invalid specs.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ArchConfig


def _axsize(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _fits(dim: int, mesh: Mesh, axis: str | None) -> bool:
    if axis is None:
        return True
    return axis in mesh.axis_names and dim % _axsize(mesh, axis) == 0


def _maybe(dim: int, mesh: Mesh, axis: str | None):
    return axis if axis is not None and _fits(dim, mesh, axis) and _axsize(mesh, axis) > 1 else None


def batch_spec(mesh: Mesh, shape: tuple[int, ...]) -> P:
    """Shard dim 0 over pod×data; drop axes that don't divide the batch."""
    ba: list[str] = []
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names and shape[0] % (n * _axsize(mesh, a)) == 0:
            ba.append(a)
            n *= _axsize(mesh, a)
    return P(tuple(ba) if ba else None, *([None] * (len(shape) - 1)))


# ---------------------------------------------------------------------------
# Parameter specs by tree path
# ---------------------------------------------------------------------------


def _param_spec(path: tuple[str, ...], shape: tuple[int, ...],
                mesh: Mesh) -> P:
    """Map one parameter (by its tree path + shape) to a PartitionSpec."""
    name = path[-1]
    inside_layers = "layers" in path
    fsdp = "data" if "data" in mesh.axis_names else None

    def spec(*axes):
        # validate divisibility dim-by-dim; drop the axis if it doesn't fit
        fixed = [_maybe(d, mesh, a) for d, a in zip(shape, axes)]
        return P(*fixed)

    # ---- top level ----
    if not inside_layers:
        if name == "embed":
            return spec("model", fsdp)
        if name == "lm_head":
            return spec(fsdp, "model")
        if name == "adapter":
            return spec(None, fsdp)
        return P()                                  # final_norm etc.

    # strip the leading L (scan) dim for layer params
    def lspec(*axes):
        return spec(None, *axes)

    parent = path[-2] if len(path) >= 2 else ""
    grand = path[-3] if len(path) >= 3 else ""

    if name == "scale":                              # any RMSNorm
        return P()
    # ---- attention ----
    if parent == "attn" or grand == "attn":
        if name == "wq":
            return lspec(fsdp, "model", None)
        if name in ("wk", "wv"):
            # kv heads rarely divide the model axis; shard head_dim instead
            if _fits(shape[2], mesh, "model") and shape[2] >= _axsize(mesh, "model"):
                return lspec(fsdp, "model", None)
            return lspec(fsdp, None, "model")
        if name == "wo":
            return lspec("model", None, fsdp)
        if name in ("bq",):
            return lspec("model", None)
        if name in ("bk", "bv"):
            return lspec(None, "model") if not _fits(shape[1], mesh, "model") \
                else lspec("model", None)
        # MLA
        if name == "wq_a":
            return lspec(fsdp, None)
        if name == "wq_b":
            return lspec(None, "model", None)
        if name == "wkv_a":
            return lspec(fsdp, None)
        if name in ("wk_b", "wv_b"):
            return lspec(None, "model", None)
    # ---- mlp (incl. moe shared expert) ----
    if parent in ("mlp", "shared"):
        if name in ("wi", "wg"):
            return lspec(fsdp, "model")
        if name == "wo":
            return lspec("model", fsdp)
    # ---- moe ----
    if parent == "moe":
        if name == "router":
            return P()
        if name in ("w_in", "w_gate"):
            return lspec("model", fsdp, None)
        if name == "w_out":
            return lspec("model", None, fsdp)
    # ---- ssm ----
    if parent == "ssm":
        if name == "w_in":
            return lspec(fsdp, "model")
        if name == "conv_w":
            return lspec(None, "model")
        if name == "conv_b":
            return lspec("model")
        if name == "w_out":
            return lspec("model", fsdp)
        if name in ("A_log", "D", "dt_bias"):
            return P()
    return P()


def _path_names(path) -> tuple[str, ...]:
    out = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            out.append(str(p.key))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            out.append(p.name)
        else:
            out.append(str(p))
    return tuple(out)


def param_shardings(params_shape: Any, mesh: Mesh) -> Any:
    """NamedSharding pytree matching a params (or ShapeDtypeStruct) pytree.

    Also covers optimizer-state trees: full-shape moments ("m"/"v" subtrees)
    reuse the parameter rules via their path tail; Adafactor's factored
    moments ("vr"/"vc", one dim removed) inherit the parent spec minus the
    removed dim.
    """
    flat, tree = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = []
    for path, leaf in flat:
        names = _path_names(path)
        shape = tuple(leaf.shape)
        if names[-1] == "vr":          # parent shape minus last dim
            parent = _param_spec(names[:-1], shape + (1,), mesh)
            spec = P(*(tuple(parent) + (None,) * (len(shape) - len(parent)))[
                :len(shape)])
        elif names[-1] == "vc":        # parent shape minus dim -2
            parent = _param_spec(names[:-1],
                                 shape[:-1] + (1,) + shape[-1:], mesh)
            pl = tuple(parent) + (None,) * (len(shape) + 1 - len(parent))
            spec = P(*(pl[:len(shape) - 1] + (pl[len(shape)],)))
        else:
            spec = _param_spec(names, shape, mesh)
        # drop axes that don't divide (factored shapes can break divisibility)
        fixed = []
        padded = tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))
        for i, a in enumerate(padded[:len(shape)]):
            if a is None:
                fixed.append(None)
                continue
            axes = a if isinstance(a, tuple) else (a,)
            n = math.prod(_axsize(mesh, ax) for ax in axes)
            fixed.append(a if n > 0 and shape[i] % n == 0 else None)
        specs.append(NamedSharding(mesh, P(*fixed)))
    return jax.tree_util.tree_unflatten(tree, specs)


# ---------------------------------------------------------------------------
# Cache specs (decode)
# ---------------------------------------------------------------------------


def cache_shardings(cache_shape: Any, mesh: Mesh, *,
                    seq_shard: bool = False) -> Any:
    """Decode-cache specs.

    ``seq_shard=True`` shards the cache *sequence* dim over "model"
    (flash-decode style): the per-step attention becomes partial-softmax +
    tiny psum combine, instead of all-gathering head-dim-sharded K/V — the
    §Perf fix for collective-bound decode cells.
    """
    def _ba(dim: int):
        out, n = [], 1
        for a in ("pod", "data"):
            if a in mesh.axis_names and dim % (n * _axsize(mesh, a)) == 0:
                out.append(a)
                n *= _axsize(mesh, a)
        return tuple(out) if out else None

    def one(path, leaf):
        name = _path_names(path)[-1]
        shp = tuple(leaf.shape)
        ba = _ba(shp[1]) if len(shp) > 1 else None
        if name == "pos":
            return NamedSharding(mesh, P())
        if name in ("k", "v"):           # (L, B, S, KH, hd)
            if seq_shard and _fits(shp[2], mesh, "model"):
                return NamedSharding(mesh, P(None, ba, "model", None, None))
            kh_ok = _fits(shp[3], mesh, "model") and shp[3] >= _axsize(mesh, "model")
            spec = (P(None, ba, None, "model", None) if kh_ok
                    else P(None, ba, None, None, _maybe(shp[4], mesh, "model")))
            return NamedSharding(mesh, spec)
        if name in ("ckv", "krope"):     # (L, B, S, r)
            if seq_shard and _fits(shp[2], mesh, "model"):
                return NamedSharding(mesh, P(None, ba, "model", None))
            return NamedSharding(
                mesh, P(None, ba, None, _maybe(shp[3], mesh, "model")))
        if name == "state":              # (L, B, nh, hp, ds)
            if _fits(shp[2], mesh, "model"):
                return NamedSharding(mesh, P(None, ba, "model", None, None))
            return NamedSharding(
                mesh, P(None, ba, None, _maybe(shp[3], mesh, "model"), None))
        if name == "conv":               # (L, B, K-1, conv_dim)
            return NamedSharding(
                mesh, P(None, ba, None, _maybe(shp[3], mesh, "model")))
        return NamedSharding(mesh, P())

    flat, tree = jax.tree_util.tree_flatten_with_path(cache_shape)
    return jax.tree_util.tree_unflatten(
        tree, [one(p, l) for p, l in flat])


def batch_shardings(batch_shape: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda l: NamedSharding(mesh, batch_spec(mesh, tuple(l.shape))),
        batch_shape)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
