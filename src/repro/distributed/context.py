"""Global mesh context — lets model code apply sharding constraints without
threading the mesh through every call signature.

``use_mesh(mesh)`` installs the mesh for the dynamic extent; ``constrain``
becomes the identity when no mesh is installed (single-device smoke tests).
"""
from __future__ import annotations

import contextlib
import contextvars
import math
from typing import Iterator

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: contextvars.ContextVar[Mesh | None] = contextvars.ContextVar(
    "repro_mesh", default=None)


def current_mesh() -> Mesh | None:
    return _MESH.get()


@contextlib.contextmanager
def use_mesh(mesh: Mesh | None) -> Iterator[None]:
    token = _MESH.set(mesh)
    try:
        if mesh is not None:
            with mesh:  # legacy resource-env context; NamedShardings are
                yield  # explicit so this only aids P-spec-only APIs
        else:
            yield
    finally:
        _MESH.reset(token)


def batch_axes(mesh: Mesh | None = None) -> tuple[str, ...]:
    """Mesh axes the batch dimension is sharded over (pod+data)."""
    mesh = mesh or current_mesh()
    if mesh is None:
        return ()
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def fsdp_axis(mesh: Mesh | None = None) -> str | None:
    mesh = mesh or current_mesh()
    if mesh is None or "data" not in mesh.axis_names:
        return None
    return "data"


def model_axis_size(mesh: Mesh | None = None) -> int:
    mesh = mesh or current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return 1
    return mesh.shape["model"]


def data_shards(mesh: Mesh | None = None) -> int:
    mesh = mesh or current_mesh()
    if mesh is None:
        return 1
    return math.prod(mesh.shape[a] for a in batch_axes(mesh))


def constrain(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint iff a mesh is installed."""
    mesh = current_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


def constrain_batch(x: jax.Array) -> jax.Array:
    """Shard the leading (batch) dim over pod+data, rest replicated."""
    mesh = current_mesh()
    if mesh is None:
        return x
    ba = batch_axes(mesh)
    return constrain(x, ba, *([None] * (x.ndim - 1)))


def constrain_tokens(x: jax.Array, *, seq_shard: bool = False) -> jax.Array:
    """Residual stream (B, S, d): batch over pod+data; optionally shard the
    sequence dim over "model" (Megatron-SP) — activations per device drop by
    the TP degree at the cost of gather/scatter at attention boundaries."""
    mesh = current_mesh()
    if mesh is None:
        return x
    ba = batch_axes(mesh)
    if (seq_shard and "model" in mesh.axis_names
            and x.ndim >= 3 and x.shape[1] % mesh.shape["model"] == 0):
        return constrain(x, ba, "model", *([None] * (x.ndim - 2)))
    return constrain(x, ba, *([None] * (x.ndim - 1)))
