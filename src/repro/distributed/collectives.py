"""Distributed-optimization collectives: compressed gradient all-reduce.

Cross-pod (DCN) gradient sync is the bandwidth-critical collective at
multi-pod scale.  ``compressed_psum_mean`` quantizes to int8 with per-tensor
scale and stochastic rounding before the all-reduce, cutting DCN bytes 4×
vs f32 (2× vs bf16); the error is zero-mean so SGD-style training tolerates
it (tests bound the error).  Used by the pod-axis grad sync when
``grad_compression="int8"``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def _stochastic_round(x: jax.Array, key: jax.Array) -> jax.Array:
    lo = jnp.floor(x)
    frac = x - lo
    return lo + (jax.random.uniform(key, x.shape) < frac)


def quantize_int8(x: jax.Array, key: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = _stochastic_round(x.astype(jnp.float32) / scale, key)
    return jnp.clip(q, -127, 127).astype(jnp.int8), scale


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compressed_psum_mean(x: jax.Array, axis_name: str, key: jax.Array,
                         *, mode: str = "int8") -> jax.Array:
    """Mean over ``axis_name`` with compressed payload.

    Call inside shard_map.  mode: "int8" (stochastic-rounded) | "bf16" |
    "none".
    """
    n = jax.lax.psum(1, axis_name)
    if mode == "none":
        return jax.lax.psum(x, axis_name) / n
    if mode == "bf16":
        return jax.lax.psum(x.astype(jnp.bfloat16), axis_name).astype(
            x.dtype) / n
    q, scale = quantize_int8(x, key)
    # int8 payload summed in int32 to avoid overflow (n <= 2^23 ranks);
    # per-rank scales vary, so sum q*scale via f32 pairing of the scalar.
    total = jax.lax.psum(q.astype(jnp.int32).astype(jnp.float32) * scale,
                         axis_name)
    return (total / n).astype(x.dtype)


def tree_compressed_psum_mean(tree, axis_name: str, key: jax.Array,
                              *, mode: str = "int8"):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    out = [compressed_psum_mean(l, axis_name, k, mode=mode)
           for l, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, out)
