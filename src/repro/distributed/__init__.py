"""Distribution layer: mesh context, sharding rules, hetero-DP, elastic."""
from .context import (batch_axes, constrain, constrain_batch, current_mesh,
                      data_shards, fsdp_axis, model_axis_size, use_mesh)

__all__ = ["batch_axes", "constrain", "constrain_batch", "current_mesh",
           "data_shards", "fsdp_axis", "model_axis_size", "use_mesh"]
