"""Elastic scaling + fault tolerance.

``FaultTolerantRunner`` wraps a step function with:
* periodic checkpointing (atomic, keep-k — see repro.checkpoint.store);
* retry-with-restore on step failure (simulating preempted/failed workers);
* re-meshing: on permanent device loss the runner rebuilds state for a new
  mesh by restoring the last checkpoint with the new mesh's shardings
  (checkpoints are host-side full arrays, so any mesh shape works);
* straggler detection hooks feeding the POAS DynamicScheduler.

On this container "device failure" is injected by the tests/examples; the
control flow is exactly what a real multi-pod deployment runs per step.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Iterator

log = logging.getLogger(__name__)


@dataclasses.dataclass
class RunnerConfig:
    checkpoint_dir: str
    checkpoint_every: int = 50
    keep: int = 3
    max_retries_per_step: int = 2
    max_total_restarts: int = 10


class StepFailure(RuntimeError):
    """Raised by a step to simulate a worker failure / preemption."""


class FaultTolerantRunner:
    def __init__(self, cfg: RunnerConfig, *,
                 step_fn: Callable[[Any, dict], tuple[Any, dict]],
                 state: Any,
                 restore_shardings: Any = None):
        from ..checkpoint import store
        self._store = store
        self.cfg = cfg
        self.step_fn = step_fn
        self.state = state
        self.restore_shardings = restore_shardings
        self.step = 0
        self.restarts = 0
        self.step_times: list[float] = []

    # -- checkpoint/restore -------------------------------------------------

    def maybe_checkpoint(self, force: bool = False) -> None:
        if force or (self.step > 0 and
                     self.step % self.cfg.checkpoint_every == 0):
            self._store.save(self.cfg.checkpoint_dir, self.step, self.state,
                             keep=self.cfg.keep)

    def restore_latest(self) -> bool:
        try:
            self.state, self.step = self._store.restore(
                self.cfg.checkpoint_dir, self.state,
                shardings=self.restore_shardings)
            return True
        except FileNotFoundError:
            return False

    # -- main loop ----------------------------------------------------------

    def run(self, batches: Iterator[dict], num_steps: int,
            on_metrics: Callable[[int, dict], None] | None = None) -> Any:
        it = iter(batches)
        while self.step < num_steps:
            try:
                batch = next(it)
            except StopIteration:
                # the batch stream can run dry before num_steps (finite
                # datasets, truncated replays): stop cleanly with a final
                # checkpoint instead of leaking StopIteration to the caller
                log.warning("batch stream exhausted at step %d/%d; stopping",
                            self.step, num_steps)
                break
            retries = 0
            while True:
                try:
                    t0 = time.perf_counter()
                    self.state, metrics = self.step_fn(self.state, batch)
                    dt = time.perf_counter() - t0
                    self.step_times.append(dt)
                    break
                except StepFailure as e:
                    retries += 1
                    self.restarts += 1
                    log.warning("step %d failed (%s); restoring (retry %d)",
                                self.step, e, retries)
                    if (retries > self.cfg.max_retries_per_step or
                            self.restarts > self.cfg.max_total_restarts):
                        raise
                    if not self.restore_latest():
                        log.warning("no checkpoint yet; retrying from "
                                    "current state")
            self.step += 1
            if on_metrics:
                on_metrics(self.step, metrics)
            self.maybe_checkpoint()
        self.maybe_checkpoint(force=True)
        return self.state

    # -- elastic re-mesh ----------------------------------------------------

    def remesh(self, new_shardings: Any, *, scheduler: Any = None,
               lost: tuple = (), joined: tuple = ()) -> None:
        """Rebuild state for a different mesh (e.g. after losing a pod):
        checkpoint now, then restore with the new shardings.

        When the training loop splits batches with a
        ``HeteroBatchScheduler``, pass it (plus the departed pod names /
        joined ``PodProfile``s) and the same call routes the membership
        change through the POAS change-point path (``pod_leave`` /
        ``pod_join`` — re-fitted models carried for survivors, plan cache
        invalidated), so the very next step's batch split is solved on
        the new cluster instead of the stale one."""
        self.maybe_checkpoint(force=True)
        if scheduler is not None:
            for name in lost:
                scheduler.pod_leave(name)
            for pod in joined:
                scheduler.pod_join(pod)
        self.restore_shardings = new_shardings
        self.state, self.step = self._store.restore(
            self.cfg.checkpoint_dir, self.state, shardings=new_shardings)
