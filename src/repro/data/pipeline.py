"""Deterministic synthetic LM data pipeline.

Produces per-host shards of a structured token stream (Zipf-distributed
vocabulary with Markov bigram structure so the loss actually decreases),
with background prefetch.  Deterministic in (seed, step, host) — a restarted
job resumes the exact stream (fault-tolerance requirement: data must be
replayable from the checkpointed step).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_hosts: int = 1
    host_index: int = 0
    embed_dim: int = 0        # >0: emit "embeds" instead of tokens (stub
                              # frontends per the assignment)


class SyntheticLM:
    """Zipf marginals + deterministic bigram mixing."""

    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.num_hosts == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.num_hosts
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # fixed random permutation as the bigram successor map
        self._succ = rng.permutation(v)
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = 1.0 / ranks ** 1.1
        self._probs = p / p.sum()

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed, step, cfg.host_index))
        shape = (self.local_batch, cfg.seq_len + 1)
        toks = rng.choice(cfg.vocab_size, size=shape, p=self._probs)
        # mix in bigram structure: with p=0.5 the next token is succ[prev]
        follow = rng.random(shape[:1] + (shape[1] - 1,)) < 0.5
        for t in range(1, shape[1]):
            toks[:, t] = np.where(follow[:, t - 1],
                                  self._succ[toks[:, t - 1]], toks[:, t])
        out = {"labels": toks[:, 1:].astype(np.int32)}
        if cfg.embed_dim:
            emb_rng = np.random.default_rng((cfg.seed + 7, step,
                                             cfg.host_index))
            out["embeds"] = (emb_rng.standard_normal(
                (self.local_batch, cfg.seq_len, cfg.embed_dim))
                .astype(np.float32) * 0.02)
        else:
            out["tokens"] = toks[:, :-1].astype(np.int32)
        return out

    def stream(self, start_step: int = 0) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch of the host data stream."""

    def __init__(self, it: Iterator[dict], depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = it
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        for item in self._it:
            if self._stop.is_set():
                return
            self._q.put(item)

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            self._q.get_nowait()
        except queue.Empty:
            pass
