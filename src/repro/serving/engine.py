"""Batched serving engine with POAS request dispatch.

``ServingEngine`` runs prefill + decode for batches of requests on one model
replica.  ``PoasDispatcher`` splits an incoming request batch across device
groups (model replicas with differing throughput) through the registered
``serving-dispatch`` POAS domain: predicted prefill+decode time per group
(linear in tokens), min-makespan split (core optimizer), largest-first
bucket packing (core adapt primitive) — the serving analogue of hgemms
(DESIGN.md §3.3).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Hashable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.adapt import pack_largest_first
from ..core.bus import BusTopology
from ..core.device_model import DeviceProfile, priority_order
from ..core.domain import PlanCache, register_domain
from ..core.framework import POAS, POASPlan
from ..core.optimize import OptimizeResult, solve_bisection
from ..core.schedule import Schedule, simulate_timeline
from ..models import Model


@dataclasses.dataclass
class Request:
    uid: int
    tokens: np.ndarray          # (prompt_len,)
    max_new_tokens: int = 16


@dataclasses.dataclass
class Completion:
    uid: int
    tokens: np.ndarray
    prefill_s: float
    decode_s: float


class ServingEngine:
    """One replica: batched greedy decode with a shared-length KV cache."""

    def __init__(self, model: Model, params):
        self.model = model
        self.params = params
        self._prefill = jax.jit(model.prefill)
        self._step = jax.jit(model.decode_step)

    def generate(self, requests: Sequence[Request]) -> list[Completion]:
        if not requests:
            return []
        plen = max(len(r.tokens) for r in requests)
        max_new = max(r.max_new_tokens for r in requests)
        B = len(requests)
        prompts = np.zeros((B, plen), np.int32)
        for i, r in enumerate(requests):   # left-pad with token 0
            prompts[i, plen - len(r.tokens):] = r.tokens

        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(prompts)})
        cache = self.model.extend_cache(cache, max_new)
        jax.block_until_ready(logits)
        t_prefill = time.perf_counter() - t0

        outs = [logits.argmax(-1)]
        t0 = time.perf_counter()
        for _ in range(max_new - 1):
            tok = outs[-1][:, None].astype(jnp.int32)
            logits, cache = self._step(self.params, cache, {"tokens": tok})
            outs.append(logits.argmax(-1))
        jax.block_until_ready(outs[-1])
        t_decode = time.perf_counter() - t0

        gen = np.stack([np.asarray(o) for o in outs], axis=1)
        return [Completion(r.uid, gen[i, :r.max_new_tokens],
                           t_prefill, t_decode)
                for i, r in enumerate(requests)]


@dataclasses.dataclass(frozen=True)
class RequestBatch:
    """A request batch as a POAS workload; ops = tokens to process
    (prompt + generated) per request."""

    requests: tuple[Request, ...]

    def token_counts(self) -> list[int]:
        return [len(r.tokens) + r.max_new_tokens for r in self.requests]

    def total_ops(self) -> float:
        return float(sum(self.token_counts()))


@dataclasses.dataclass(frozen=True)
class DispatchPlan:
    """Adapt-phase output: request *indices* per serving group.

    Indices (not request objects) make the plan reusable from the
    ``PlanCache``: any batch with the same ordered token geometry gets the
    same packing applied to its own requests.  Frozen (tuple fields) because
    instances are shared across cache hits.
    """

    index_buckets: tuple[tuple[int, ...], ...]
    bucket_tokens: tuple[float, ...]

    def __post_init__(self):
        object.__setattr__(self, "index_buckets",
                           tuple(tuple(b) for b in self.index_buckets))
        object.__setattr__(self, "bucket_tokens", tuple(self.bucket_tokens))

    def assign(self, requests: Sequence[Request]) -> list[list[Request]]:
        return [[requests[i] for i in bucket] for bucket in self.index_buckets]


@register_domain("serving-dispatch")
class ServingDispatchDomain:
    """DS-POAS for request dispatch across heterogeneous model replicas.

    Optimize is the core min-makespan solver over token counts; Adapt is the
    core largest-first packer (op shares -> request buckets); Schedule is the
    standard priority timeline over bucket token totals.
    """

    name = "serving-dispatch"

    def __init__(self, groups: Sequence[DeviceProfile]):
        self._groups = list(groups)
        # replica groups don't share a host bus: one private link each
        self.topology = BusTopology.independent(self._groups)

    def predict(self) -> Sequence[DeviceProfile]:
        return self._groups

    def optimize(self, groups: Sequence[DeviceProfile],
                 batch: RequestBatch) -> OptimizeResult:
        return solve_bisection(groups, batch.total_ops(), n=1, k=1,
                               bus=self.topology)

    def adapt(self, groups: Sequence[DeviceProfile], opt: OptimizeResult,
              batch: RequestBatch) -> DispatchPlan:
        tok = batch.token_counts()
        packed = pack_largest_first(tok, opt.ops)
        return DispatchPlan(
            index_buckets=packed,
            bucket_tokens=[float(sum(tok[i] for i in b)) for b in packed])

    def schedule(self, groups: Sequence[DeviceProfile], plan: DispatchPlan,
                 batch: RequestBatch) -> Schedule:
        ops = plan.bucket_tokens
        tl = simulate_timeline(groups, ops, 1, 1, topology=self.topology)
        res = OptimizeResult(ops=ops, makespan=tl.makespan,
                             finish_times=[tl.device_finish(g.name)
                                           for g in groups],
                             bus="independent")
        return Schedule(result=res, timeline=tl,
                        priorities=priority_order(list(groups)))

    def cost_signature(self, batch: RequestBatch) -> Hashable:
        return tuple(batch.token_counts())


class PoasDispatcher:
    """Split a request batch across heterogeneous serving groups.

    A thin facade over the registered ``serving-dispatch`` domain: repeated
    batches with identical token geometry hit the ``PlanCache`` and skip the
    solve.
    """

    def __init__(self, groups: Sequence[DeviceProfile], *, grain: int = 1,
                 cache: bool = True):
        self.groups = list(groups)
        self.grain = grain
        self.domain = ServingDispatchDomain(self.groups)
        self.poas = POAS(self.domain, cache=PlanCache() if cache else None)
        self.last_plan: POASPlan | None = None

    def split(self, requests: Sequence[Request]) -> list[list[Request]]:
        if not requests:
            self.last_plan = None      # never expose a previous batch's plan
            return [[] for _ in self.groups]
        plan = self.poas.plan(RequestBatch(requests=tuple(requests)))
        self.last_plan = plan
        # apply the (possibly cached) index packing to THIS batch's requests
        return plan.adapted.assign(requests)

    def predicted_makespan(self, buckets: Sequence[Sequence[Request]]) -> float:
        t = 0.0
        for g, reqs in zip(self.groups, buckets):
            ops = float(sum(len(r.tokens) + r.max_new_tokens for r in reqs))
            t = max(t, g.compute(ops))
        return t
