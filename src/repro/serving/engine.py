"""Batched serving engine with POAS request dispatch.

``ServingEngine`` runs prefill + decode for batches of requests on one model
replica.  ``PoasDispatcher`` splits an incoming request batch across device
groups (model replicas with differing throughput) using the POAS pipeline:
predicted prefill+decode time per group (linear in tokens), min-makespan
split, grain rounding — the serving analogue of hgemms (DESIGN.md §3.3).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.device_model import DeviceProfile
from ..core.optimize import solve_bisection
from ..models import Model


@dataclasses.dataclass
class Request:
    uid: int
    tokens: np.ndarray          # (prompt_len,)
    max_new_tokens: int = 16


@dataclasses.dataclass
class Completion:
    uid: int
    tokens: np.ndarray
    prefill_s: float
    decode_s: float


class ServingEngine:
    """One replica: batched greedy decode with a shared-length KV cache."""

    def __init__(self, model: Model, params):
        self.model = model
        self.params = params
        self._prefill = jax.jit(model.prefill)
        self._step = jax.jit(model.decode_step)

    def generate(self, requests: Sequence[Request]) -> list[Completion]:
        if not requests:
            return []
        plen = max(len(r.tokens) for r in requests)
        max_new = max(r.max_new_tokens for r in requests)
        B = len(requests)
        prompts = np.zeros((B, plen), np.int32)
        for i, r in enumerate(requests):   # left-pad with token 0
            prompts[i, plen - len(r.tokens):] = r.tokens

        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(prompts)})
        cache = self.model.extend_cache(cache, max_new)
        jax.block_until_ready(logits)
        t_prefill = time.perf_counter() - t0

        outs = [logits.argmax(-1)]
        t0 = time.perf_counter()
        for _ in range(max_new - 1):
            tok = outs[-1][:, None].astype(jnp.int32)
            logits, cache = self._step(self.params, cache, {"tokens": tok})
            outs.append(logits.argmax(-1))
        jax.block_until_ready(outs[-1])
        t_decode = time.perf_counter() - t0

        gen = np.stack([np.asarray(o) for o in outs], axis=1)
        return [Completion(r.uid, gen[i, :r.max_new_tokens],
                           t_prefill, t_decode)
                for i, r in enumerate(requests)]


class PoasDispatcher:
    """Split a request batch across heterogeneous serving groups."""

    def __init__(self, groups: Sequence[DeviceProfile], *, grain: int = 1):
        self.groups = list(groups)
        self.grain = grain

    def split(self, requests: Sequence[Request]) -> list[list[Request]]:
        if not requests:
            return [[] for _ in self.groups]
        # ops = tokens to process (prompt + generated) per request
        tok = [len(r.tokens) + r.max_new_tokens for r in requests]
        total = float(sum(tok))
        res = solve_bisection(self.groups, total, n=1, k=1,
                              bus="independent")
        # Adapt: convert op shares to request counts (greedy largest-first)
        order = np.argsort(tok)[::-1]
        budgets = list(res.ops)
        buckets: list[list[Request]] = [[] for _ in self.groups]
        for idx in order:
            g = int(np.argmax(budgets))
            buckets[g].append(requests[idx])
            budgets[g] -= tok[idx]
        return buckets

    def predicted_makespan(self, buckets: Sequence[Sequence[Request]]) -> float:
        t = 0.0
        for g, reqs in zip(self.groups, buckets):
            ops = float(sum(len(r.tokens) + r.max_new_tokens for r in reqs))
            t = max(t, g.compute(ops))
        return t
