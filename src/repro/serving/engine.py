"""Batched serving engine with POAS request dispatch.

``ServingEngine`` runs prefill + decode for batches of requests on one model
replica.  ``PoasDispatcher`` splits an incoming request batch across device
groups (model replicas with differing throughput) through the registered
``serving-dispatch`` POAS domain: predicted prefill+decode time per group
(linear in tokens), min-makespan split (core optimizer), largest-first
bucket packing (core adapt primitive) — the serving analogue of hgemms
(DESIGN.md §3.3).

Continuous batching (DESIGN.md §9): with ``dynamic=True`` the dispatcher
keeps an admission queue — requests arriving while a batch is in flight are
``admit``-ed and picked up by the next ``dispatch_pending`` — and routes
per-bucket measured generation times through the shared ``ObservationPump``
back into the group models, so the split adapts to replicas that slow down
(and the ``PlanCache`` is invalidated on every re-fit, never serving a
stale packing).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Hashable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.adapt import pack_largest_first
from ..core.bus import BusTopology
from ..core.device_model import DeviceProfile, priority_order
from ..core.domain import PlanCache, register_domain
from ..core.framework import POAS, POASPlan
from ..core.optimize import OptimizeResult, solve_bisection
from ..core.runtime import ObservationPump
from ..core.schedule import (DynamicScheduler, Schedule, make_spec,
                             simulate_timeline)
from ..models import Model


@dataclasses.dataclass
class Request:
    uid: int
    tokens: np.ndarray          # (prompt_len,)
    max_new_tokens: int = 16


@dataclasses.dataclass
class Completion:
    uid: int
    tokens: np.ndarray
    prefill_s: float
    decode_s: float


class ServingEngine:
    """One replica: batched greedy decode with a shared-length KV cache."""

    def __init__(self, model: Model, params):
        self.model = model
        self.params = params
        self._prefill = jax.jit(model.prefill)
        self._step = jax.jit(model.decode_step)

    def generate(self, requests: Sequence[Request]) -> list[Completion]:
        if not requests:
            return []
        plen = max(len(r.tokens) for r in requests)
        max_new = max(r.max_new_tokens for r in requests)
        B = len(requests)
        prompts = np.zeros((B, plen), np.int32)
        for i, r in enumerate(requests):   # left-pad with token 0
            prompts[i, plen - len(r.tokens):] = r.tokens

        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(prompts)})
        cache = self.model.extend_cache(cache, max_new)
        jax.block_until_ready(logits)
        t_prefill = time.perf_counter() - t0

        outs = [logits.argmax(-1)]
        t0 = time.perf_counter()
        for _ in range(max_new - 1):
            tok = outs[-1][:, None].astype(jnp.int32)
            logits, cache = self._step(self.params, cache, {"tokens": tok})
            outs.append(logits.argmax(-1))
        jax.block_until_ready(outs[-1])
        t_decode = time.perf_counter() - t0

        gen = np.stack([np.asarray(o) for o in outs], axis=1)
        return [Completion(r.uid, gen[i, :r.max_new_tokens],
                           t_prefill, t_decode)
                for i, r in enumerate(requests)]


@dataclasses.dataclass(frozen=True)
class RequestBatch:
    """A request batch as a POAS workload; ops = tokens to process
    (prompt + generated) per request."""

    requests: tuple[Request, ...]

    def token_counts(self) -> list[int]:
        return [len(r.tokens) + r.max_new_tokens for r in self.requests]

    def total_ops(self) -> float:
        return float(sum(self.token_counts()))


@dataclasses.dataclass(frozen=True)
class DispatchPlan:
    """Adapt-phase output: request *indices* per serving group.

    Indices (not request objects) make the plan reusable from the
    ``PlanCache``: any batch with the same ordered token geometry gets the
    same packing applied to its own requests.  Frozen (tuple fields) because
    instances are shared across cache hits.
    """

    index_buckets: tuple[tuple[int, ...], ...]
    bucket_tokens: tuple[float, ...]

    def __post_init__(self):
        object.__setattr__(self, "index_buckets",
                           tuple(tuple(b) for b in self.index_buckets))
        object.__setattr__(self, "bucket_tokens", tuple(self.bucket_tokens))

    def assign(self, requests: Sequence[Request]) -> list[list[Request]]:
        return [[requests[i] for i in bucket] for bucket in self.index_buckets]


@register_domain("serving-dispatch")
class ServingDispatchDomain:
    """DS-POAS for request dispatch across heterogeneous model replicas.

    Optimize is the core min-makespan solver over token counts; Adapt is the
    core largest-first packer (op shares -> request buckets); Schedule is the
    standard priority timeline over bucket token totals.
    """

    name = "serving-dispatch"

    def __init__(self, groups: Sequence[DeviceProfile], *,
                 dynamic: bool = False):
        self._groups = list(groups)
        # replica groups don't share a host bus: one private link each
        self.topology = BusTopology.independent(self._groups)
        self.dyn = DynamicScheduler(self._groups, bus=self.topology) \
            if dynamic else None

    def predict(self) -> Sequence[DeviceProfile]:
        return self.dyn.snapshot() if self.dyn is not None else self._groups

    def optimize(self, groups: Sequence[DeviceProfile],
                 batch: RequestBatch) -> OptimizeResult:
        return solve_bisection(groups, batch.total_ops(), n=1, k=1,
                               bus=self.topology)

    def adapt(self, groups: Sequence[DeviceProfile], opt: OptimizeResult,
              batch: RequestBatch) -> DispatchPlan:
        tok = batch.token_counts()
        packed = pack_largest_first(tok, opt.ops)
        return DispatchPlan(
            index_buckets=packed,
            bucket_tokens=[float(sum(tok[i] for i in b)) for b in packed])

    def schedule(self, groups: Sequence[DeviceProfile], plan: DispatchPlan,
                 batch: RequestBatch) -> Schedule:
        ops = plan.bucket_tokens
        tl = simulate_timeline(groups, ops, 1, 1, topology=self.topology)
        res = OptimizeResult(ops=ops, makespan=tl.makespan,
                             finish_times=[tl.device_finish(g.name)
                                           for g in groups],
                             bus="independent")
        return Schedule(result=res, timeline=tl,
                        priorities=priority_order(list(groups)),
                        spec=make_spec(groups, ops, 1, 1, self.topology))

    def cost_signature(self, batch: RequestBatch) -> Hashable:
        return tuple(batch.token_counts())


class PoasDispatcher:
    """Split a request batch across heterogeneous serving groups.

    A thin facade over the registered ``serving-dispatch`` domain: repeated
    batches with identical token geometry hit the ``PlanCache`` and skip the
    solve.

    Continuous-batching mode (``dynamic=True``): requests arriving while a
    batch is in flight are ``admit``-ed into a pending queue and picked up
    by the next ``dispatch_pending``; per-bucket measured generation times
    fed to ``complete`` flow through the shared ``ObservationPump`` into the
    group models (re-fit → ``PlanCache`` invalidation → the next dispatch is
    re-planned under the refreshed throughputs).
    """

    def __init__(self, groups: Sequence[DeviceProfile], *, grain: int = 1,
                 cache: bool = True, dynamic: bool = False):
        self.groups = list(groups)
        self.grain = grain
        self.domain = ServingDispatchDomain(self.groups, dynamic=dynamic)
        self.poas = POAS(self.domain, cache=PlanCache() if cache else None)
        self.pump: ObservationPump | None = None
        if self.domain.dyn is not None:
            self.pump = ObservationPump(self.domain.dyn,
                                        [g.name for g in self.groups])
        self.last_plan: POASPlan | None = None
        self.tenant = None             # set by attach() (DESIGN.md §13)
        self._pending: list[Request] = []
        self._lock = threading.Lock()

    def split(self, requests: Sequence[Request]) -> list[list[Request]]:
        if not requests:
            self.last_plan = None      # never expose a previous batch's plan
            return [[] for _ in self.groups]
        plan = self.poas.plan(RequestBatch(requests=tuple(requests)))
        self.last_plan = plan
        # apply the (possibly cached) index packing to THIS batch's requests
        return plan.adapted.assign(requests)

    # -- continuous batching ------------------------------------------------

    def admit(self, *requests: Request) -> None:
        """Queue requests for the next dispatch (safe to call from serving
        threads while a batch is in flight)."""
        with self._lock:
            self._pending.extend(requests)

    @property
    def pending(self) -> int:
        with self._lock:
            return len(self._pending)

    def dispatch_pending(self) -> list[list[Request]]:
        """Drain the admission queue into a planned dispatch (empty buckets
        when nothing is pending)."""
        with self._lock:
            batch, self._pending = self._pending, []
        return self.split(batch)

    def complete(self, group_index: int, requests: Sequence[Request],
                 seconds: float) -> None:
        """Report one bucket's measured generation time; in dynamic mode it
        is pumped into that group's model (no-op for static dispatchers)."""
        if self.pump is None or not requests:
            return
        tokens = float(sum(len(r.tokens) + r.max_new_tokens
                           for r in requests))
        self.pump.observe(self.groups[group_index].name, tokens, seconds)

    # -- shared-runtime tenancy (DESIGN.md §13) -----------------------------

    def attach(self, runtime, name: str = "serving", qos=None):
        """Register this dispatcher's domain as a tenant on a shared
        multi-tenant ``CoExecutionRuntime``: batches submitted through
        ``submit_batch`` interleave with other tenants' jobs on the shared
        carried-clock timeline under weighted-fair, SLO-aware admission
        (latency-tier serving traffic can preempt batch tenants).  The
        tenant's pump *replaces* the dispatcher's private one, so
        completions reported through either path re-fit the same models."""
        self.tenant = runtime.register(name, self.domain, qos)
        if self.tenant.pump is not None:
            self.pump = self.tenant.pump
        return self.tenant

    def submit_batch(self, requests: Sequence[Request], *,
                     deadline_s: float | None = None,
                     arrival: float | None = None):
        """Submit one request batch as a ``StreamJob`` on the attached
        runtime (``attach`` first).  The job's plan carries the same
        ``DispatchPlan`` the ``split`` facade would produce — recover the
        buckets with ``job.plan.adapted.assign(requests)``; an infeasible
        ``deadline_s`` raises at the job, never dispatching a ticket."""
        if self.tenant is None:
            raise RuntimeError("attach() this dispatcher to a runtime "
                               "before submit_batch()")
        return self.tenant.submit(RequestBatch(requests=tuple(requests)),
                                  deadline_s=deadline_s, arrival=arrival)

    # -- prediction ---------------------------------------------------------

    def predicted_makespan(self, buckets: Sequence[Sequence[Request]]) -> float:
        """Predicted completion of a bucketed dispatch on the *current*
        (possibly re-fitted) group models — priced on the same timeline
        engine the solver and simulator use, so copy/link time is included
        for groups that have it (it used to price ``g.compute(ops)`` only,
        disagreeing with the solver/simulator/executor contract)."""
        groups = list(self.domain.predict())
        ops = [float(sum(len(r.tokens) + r.max_new_tokens for r in reqs))
               for g, reqs in zip(groups, buckets)]
        ops += [0.0] * (len(groups) - len(ops))   # callers may pass fewer
        tl = simulate_timeline(groups, ops, 1, 1,
                               topology=self.domain.topology)
        return tl.makespan
