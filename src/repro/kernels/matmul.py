"""MXU-tiled matmul Pallas kernel — the hgemms per-device compute unit.

The paper's case-study hot spot is GEMM; on TPU the per-partition sub-GEMM
produced by ``ops_to_mnk`` runs through this kernel.  Grid is (M/bm, N/bn,
K/bk) with a float32 VMEM accumulator; block shapes are chosen so that
(bm·bk + bk·bn + bm·bn) tiles fit VMEM and the MXU dims are multiples of
(8, 128) — exactly the paper's "hardware adjustments" transplanted to TPU
(DESIGN.md §2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import CompilerParams


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_pallas(a: jax.Array, b: jax.Array, *,
                  block_m: int = 256, block_n: int = 256, block_k: int = 512,
                  out_dtype=None, interpret: bool = False) -> jax.Array:
    """C = A @ B with explicit VMEM tiling.  Shapes need not be multiples of
    the block sizes — inputs are zero-padded and the result cropped."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    out_dtype = out_dtype or jnp.promote_types(a.dtype, b.dtype)
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    # MXU alignment: sublane multiples of 8, lane multiples of 128 where the
    # dims allow it.
    bm = max(8 * (bm // 8), min(bm, m)) if m >= 8 else m
    bn = max(128 * (bn // 128), min(bn, n)) if n >= 128 else n
    bk = max(128 * (bk // 128), min(bk, k)) if k >= 128 else k

    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, pk), (0, pn)))
    M, K = a.shape
    _, N = b.shape
    k_steps = K // bk

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=(M // bm, N // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
    if pm or pn:
        out = out[:m, :n]
    return out
