"""Flash-attention Pallas TPU kernel (causal / sliding-window, GQA).

Online-softmax over KV blocks with the running (m, l, acc) triple held in
VMEM scratch.  Grid: (batch, q_heads, Sq/bq, Skv/bk) with the KV dimension
innermost ("arbitrary" semantics) so the accumulator carries across KV
steps.  Block shapes keep q/k/v tiles within VMEM and lane-align head_dim.

The pure-JAX oracle is ``ref.flash_attention_ref`` (also what the model
stack executes on CPU); this kernel is the TPU drop-in.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  kv_steps: int, block_q: int, block_k: int, sq: int, skv: int,
                  causal: bool, window: int, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32)             # (bq, d)
    k = k_ref[...].astype(jnp.float32)             # (bk, d)
    v = v_ref[...].astype(jnp.float32)             # (bk, dv)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    mask = k_pos < skv
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == kv_steps - 1)
    def _store():
        o_ref[...] = (acc_ref[...] /
                      jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int = 0,
                           block_q: int = 512, block_k: int = 512,
                           scale: float | None = None,
                           interpret: bool = False) -> jax.Array:
    """q: (B, Sq, H, D); k/v: (B, Skv, KH, D/Dv).  Returns (B, Sq, H, Dv).

    GQA: the q-head→kv-head mapping happens in the k/v index_maps, so no
    repeated K/V materialization.
    """
    B, Sq, H, D = q.shape
    _, Skv, KH, Dv = v.shape
    G = H // KH
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    bq, bk = min(block_q, Sq), min(block_k, Skv)
    pq, pk = (-Sq) % bq, (-Skv) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    SQ, SK = q.shape[1], k.shape[1]
    # layout: (B, H, S, D) blocks of (1, 1, bs, d)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    kv_steps = SK // bk

    out = pl.pallas_call(
        functools.partial(_flash_kernel, kv_steps=kv_steps, block_q=bq,
                          block_k=bk, sq=Sq, skv=Skv, causal=causal,
                          window=window, scale=scale),
        grid=(B, H, SQ // bq, kv_steps),
        in_specs=[
            pl.BlockSpec((None, None, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((None, None, bk, D),
                         lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((None, None, bk, Dv),
                         lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, bq, Dv),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, SQ, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, Dv), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)
    out = out.transpose(0, 2, 1, 3)
    if pq:
        out = out[:, :Sq]
    return out
