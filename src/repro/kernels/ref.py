"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array, out_dtype=None) -> jax.Array:
    out_dtype = out_dtype or jnp.promote_types(a.dtype, b.dtype)
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                   preferred_element_type=jnp.float32).astype(out_dtype)


def ssd_chunk_ref(xdt: jax.Array, B: jax.Array, C: jax.Array,
                  cum: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Oracle for the intra-chunk SSD kernel.

    xdt: (b,NC,Q,nh,hp)   B,C: (b,NC,Q,G,ds)   cum: (b,NC,Q,nh)
    Returns y_intra (b,NC,Q,nh,hp) and states (b,NC,nh,ds,hp).
    """
    b, nc, Q, nh, hp = xdt.shape
    G = B.shape[3]
    hg = nh // G
    Bh = jnp.repeat(B, hg, axis=3).astype(jnp.float32)  # (b,NC,Q,nh,ds)
    Ch = jnp.repeat(C, hg, axis=3).astype(jnp.float32)
    x = xdt.astype(jnp.float32)
    cum = cum.astype(jnp.float32)
    cb = jnp.einsum("bnqhs,bnths->bnhqt", Ch, Bh)
    diff = cum.transpose(0, 1, 3, 2)[..., None] - \
        cum.transpose(0, 1, 3, 2)[..., None, :]         # (b,NC,nh,Q,Q)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(causal[None, None, None], jnp.exp(diff), 0.0)
    y = jnp.einsum("bnhqt,bnthp->bnqhp", cb * decay, x)
    seg_end = cum[:, :, -1, :]
    w = jnp.exp(seg_end[:, :, None, :] - cum)           # (b,NC,Q,nh)
    states = jnp.einsum("bnqhs,bnqhp->bnhsp", Bh * w[..., None], x)
    return y.astype(xdt.dtype), states


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        scale: float | None = None) -> jax.Array:
    """Naive masked softmax attention with GQA head grouping."""
    B, Sq, H, D = q.shape
    _, Skv, KH, Dv = v.shape
    G = H // KH
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    kx = jnp.repeat(k, G, axis=2).astype(jnp.float32)
    vx = jnp.repeat(v, G, axis=2).astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kx) * scale
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= qp >= kp
    if window > 0:
        mask &= kp > qp - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vx)
    return out.astype(q.dtype)
