"""Pallas TPU kernels for the perf-critical compute layers.

* ``matmul``          — MXU-tiled GEMM (the paper's domain; hgemms per-device
                        compute unit)
* ``flash_attention`` — causal/windowed GQA flash attention
* ``ssd_chunk``       — Mamba-2 SSD intra-chunk (the SSD quadratic hot spot)

Each has a pure-jnp oracle in ``ref.py``; kernels are validated in
interpret mode on CPU (see tests/test_kernels_*.py) and run natively on TPU.
"""
from jax.experimental.pallas import tpu as _pltpu

# Version-compat shim: jax >= 0.5 renamed ``TPUCompilerParams`` to
# ``CompilerParams``; older releases (e.g. 0.4.37 on this container) only
# ship the TPU-prefixed name.  Kernel modules import this package-local
# alias (``from . import CompilerParams``) — jax's own namespace is left
# untouched.  Defined before the kernel imports below so it is bound when
# they load.
CompilerParams = getattr(_pltpu, "CompilerParams", None) \
    or _pltpu.TPUCompilerParams

from .ops import flash_attention, matmul
from .ssd_chunk import ssd_chunk_pallas
from . import ref

__all__ = ["CompilerParams", "flash_attention", "matmul",
           "ssd_chunk_pallas", "ref"]
