"""Pallas TPU kernels for the perf-critical compute layers.

* ``matmul``          — MXU-tiled GEMM (the paper's domain; hgemms per-device
                        compute unit)
* ``flash_attention`` — causal/windowed GQA flash attention
* ``ssd_chunk``       — Mamba-2 SSD intra-chunk (the SSD quadratic hot spot)

Each has a pure-jnp oracle in ``ref.py``; kernels are validated in
interpret mode on CPU (see tests/test_kernels_*.py) and run natively on TPU.
"""
from .ops import flash_attention, matmul
from .ssd_chunk import ssd_chunk_pallas
from . import ref

__all__ = ["flash_attention", "matmul", "ssd_chunk_pallas", "ref"]
