"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU so the same call sites run everywhere:
real MXU kernels on TPU, Python-interpreted (bit-accurate) on CPU.
"""
from __future__ import annotations

from functools import partial

import jax

from .flash_attention import flash_attention_pallas
from .matmul import matmul_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                   "interpret"))
def matmul(a, b, *, block_m: int = 256, block_n: int = 256,
           block_k: int = 512, interpret: bool | None = None):
    if interpret is None:
        interpret = _default_interpret()
    return matmul_pallas(a, b, block_m=block_m, block_n=block_n,
                         block_k=block_k, interpret=interpret)


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 512, block_k: int = 512,
                    interpret: bool | None = None):
    if interpret is None:
        interpret = _default_interpret()
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  block_q=block_q, block_k=block_k,
                                  interpret=interpret)
