"""Mamba-2 SSD intra-chunk Pallas kernel.

The SSD chunked algorithm (arXiv:2405.21060 §6) splits into an inter-chunk
recurrence (cheap, O(S/Q)) and an **intra-chunk quadratic part** — the
compute hot spot this kernel fuses:

    CB[q,t]  = C_q · B_t                      (Q×Q matmul on the MXU)
    L[q,t]   = exp(cum_q − cum_t) · 1[q ≥ t]  (decay mask, on the VPU)
    y[q]     = Σ_t (CB·L)[q,t] · (dt·x)[t]    (second MXU matmul)
    state    = Σ_t exp(cum_end − cum_t) · B_t ⊗ (dt·x)[t]

One grid cell = one (batch, head, chunk); all four stages stay in VMEM —
the (Q,Q) score tile never touches HBM.  ``ref.ssd_chunk_ref`` is the
pure-jnp oracle (also what `repro.models.ssm.ssd_scan` computes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import CompilerParams

F32 = jnp.float32


def _ssd_chunk_kernel(xdt_ref, b_ref, c_ref, cum_ref, y_ref, state_ref):
    xdt = xdt_ref[...].astype(F32)        # (Q, hp)   x * dt
    bmat = b_ref[...].astype(F32)         # (Q, ds)
    cmat = c_ref[...].astype(F32)         # (Q, ds)
    cum = cum_ref[...].astype(F32)        # (Q, 1)    within-chunk cumsum(dtA)

    q = xdt.shape[0]
    cb = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                             preferred_element_type=F32)      # (Q, Q)
    diff = cum - cum.reshape(1, q)                            # cum_q - cum_t
    qi = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    ti = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    decay = jnp.where(qi >= ti, jnp.exp(diff), 0.0)
    m = cb * decay
    y_ref[...] = jax.lax.dot_general(
        m, xdt, (((1,), (0,)), ((), ())),
        preferred_element_type=F32).astype(y_ref.dtype)       # (Q, hp)

    # chunk state: Σ_t exp(cum_end - cum_t) B_t ⊗ xdt_t   -> (ds, hp)
    seg_end = cum[q - 1:q, :]                                 # (1, 1)
    w = jnp.exp(seg_end - cum)                                # (Q, 1)
    state_ref[...] = jax.lax.dot_general(
        bmat * w, xdt, (((0,), (0,)), ((), ())),
        preferred_element_type=F32).astype(state_ref.dtype)   # (ds, hp)


def ssd_chunk_pallas(xdt: jax.Array, B: jax.Array, C: jax.Array,
                     cum: jax.Array, *, interpret: bool = False
                     ) -> tuple[jax.Array, jax.Array]:
    """Intra-chunk SSD for all (batch, chunk, head) cells.

    xdt: (b, NC, Q, nh, hp)    B, C: (b, NC, Q, G, ds)   cum: (b, NC, Q, nh)
    Returns y_intra: (b, NC, Q, nh, hp) and states: (b, NC, nh, hp->?, ds)
    laid out as (b, NC, nh, ds, hp) to match the kernel's natural output.
    """
    b, nc, Q, nh, hp = xdt.shape
    G, ds = B.shape[3], B.shape[4]
    hg = nh // G

    xdt_t = xdt.transpose(0, 1, 3, 2, 4)      # (b, NC, nh, Q, hp)
    b_t = B.transpose(0, 1, 3, 2, 4)          # (b, NC, G, Q, ds)
    c_t = C.transpose(0, 1, 3, 2, 4)
    cum_t = cum.transpose(0, 1, 3, 2)[..., None]  # (b, NC, nh, Q, 1)

    grid = (b, nc, nh)
    y, st = pl.pallas_call(
        _ssd_chunk_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, None, Q, hp),
                         lambda i, j, h: (i, j, h, 0, 0)),
            pl.BlockSpec((None, None, None, Q, ds),
                         lambda i, j, h: (i, j, h // hg, 0, 0)),
            pl.BlockSpec((None, None, None, Q, ds),
                         lambda i, j, h: (i, j, h // hg, 0, 0)),
            pl.BlockSpec((None, None, None, Q, 1),
                         lambda i, j, h: (i, j, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, None, None, Q, hp),
                         lambda i, j, h: (i, j, h, 0, 0)),
            pl.BlockSpec((None, None, None, ds, hp),
                         lambda i, j, h: (i, j, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nc, nh, Q, hp), xdt.dtype),
            jax.ShapeDtypeStruct((b, nc, nh, ds, hp), F32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel")),
        interpret=interpret,
    )(xdt_t, b_t, c_t, cum_t)
    return y.transpose(0, 1, 3, 2, 4), st
