"""MusicGen-medium — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].
EnCodec frontend stubbed per assignment (input_specs supplies precomputed
frame embeddings)."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium", family="audio",
    num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24,
    d_ff=6144, vocab_size=2048, head_dim=64,
    attention="gqa", frontend="audio_stub",
)
