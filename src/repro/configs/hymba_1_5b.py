"""Hymba-1.5B — hybrid parallel attention+SSM heads [arXiv:2411.13676; hf].

Sliding-window attention on most layers (3 full-attention layers: first,
middle, last) makes it eligible for the 524k long-context decode shape.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1_5b", family="hybrid",
    num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5,
    d_ff=5504, vocab_size=32001, head_dim=64,
    attention="swa", window=1024, global_layers=(0, 15, 31),
    ssm_state=16, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
)
