"""Assigned-architecture registry: ``get_config(name)`` / ``--arch <id>``.

Each module defines ``CONFIG`` (the exact published architecture) built from
public literature; reduced same-family variants for CPU smoke tests come from
``repro.models.config.reduced``.
"""
from __future__ import annotations

import importlib

from ..models.config import ArchConfig, reduced

ARCH_IDS = [
    "stablelm-12b",
    "deepseek-67b",
    "minicpm3-4b",
    "qwen2-72b",
    "hymba-1_5b",
    "internvl2-26b",
    "llama4-maverick-400b-a17b",
    "dbrx-132b",
    "mamba2-2_7b",
    "musicgen-medium",
]

_ALIASES = {
    "hymba-1.5b": "hymba-1_5b",
    "mamba2-2.7b": "mamba2-2_7b",
}


def get_config(name: str) -> ArchConfig:
    name = _ALIASES.get(name, name).replace(".", "_").replace("-", "_")
    for arch in ARCH_IDS:
        if arch.replace("-", "_").replace(".", "_") == name:
            mod = importlib.import_module(f".{arch.replace('-', '_')}",
                                          __package__)
            return mod.CONFIG
    raise KeyError(f"unknown arch {name!r}; available: {ARCH_IDS}")


def get_tiny_config(name: str) -> ArchConfig:
    return reduced(get_config(name))


def all_configs() -> dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
