"""Llama-4-Maverick-400B-A17B — MoE 128 experts top-1 + shared expert,
early-fusion [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=8192, vocab_size=202048, head_dim=128,
    attention="gqa",
    num_experts=128, experts_per_token=1, shared_expert_ff=8192,
    moe_every=2,
)
