"""InternVL2-26B — InternViT frontend (stubbed) + InternLM2-20B-style LM
backbone [arXiv:2404.16821; hf].  Per assignment, ``input_specs()`` provides
precomputed patch embeddings; the backbone below is the transformer."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b", family="vlm",
    num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=16384, vocab_size=92553, head_dim=128,
    attention="gqa", frontend="vlm_stub",
)
