"""Mamba2-2.7B — attention-free SSD (state-space duality)
[arXiv:2405.21060; unverified]."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2_7b", family="ssm",
    num_layers=64, d_model=2560, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    attention="none",
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
)
