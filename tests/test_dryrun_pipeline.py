"""End-to-end dry-run pipeline test (subprocess, 8 forced host devices,
tiny configs): lower+compile train/prefill/decode cells on a (2,2,2)
pod/data/model mesh and check the recorded accounting is sane."""
import json
import os
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).parent.parent


@pytest.mark.parametrize("arch,shapes", [
    pytest.param("qwen2-72b", ["train_4k", "decode_32k"],
                 marks=pytest.mark.slow),
    ("dbrx-132b", ["train_4k"]),
    ("hymba-1_5b", ["long_500k"]),
])
def test_tiny_dryrun_cell(arch, shapes, tmp_path):
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--tiny",
           "--singlepod", "--mesh-shape", "2,2,2",
           "--arch", arch, "--shape", *shapes,
           "--seq", "64", "--batch", "8", "--out", str(tmp_path)]
    env = {**os.environ, "PYTHONPATH": "src", "REPRO_DRYRUN_DEVICES": "8"}
    r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                       cwd=REPO, timeout=900)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    for shape in shapes:
        rec = json.loads((tmp_path / f"{arch}__{shape}__single.json")
                         .read_text())
        assert rec["status"] == "ok", rec
        assert rec["flops_per_device"] > 0
        assert rec["bytes_per_device"] > 0
        assert rec["memory"]["peak_bytes"] > 0
        # trip-count-aware flops must exceed XLA's loop-body-once count
        assert rec["flops_per_device"] >= rec[
            "xla_flops_per_device_loopbody_once"]


def test_hlo_cost_model_scan_multiplication():
    """The core accounting invariant: scans multiply by trip count."""
    import jax
    import jax.numpy as jnp
    from repro.launch.hlo_costs import analyze

    def f(a, ws):
        def body(x, w):
            return x @ w, None
        out, _ = jax.lax.scan(body, a, ws)
        return out

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((256, 256), jnp.float32),
        jax.ShapeDtypeStruct((12, 256, 256), jnp.float32)).compile()
    r = analyze(c.as_text())
    expected = 12 * 2 * 256 ** 3
    assert abs(r["flops"] - expected) / expected < 0.01
