"""SSD intra-chunk Pallas kernel vs oracle + vs the model's ssd_scan."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ref import ssd_chunk_ref
from repro.kernels.ssd_chunk import ssd_chunk_pallas


def _inputs(key, b, nc, Q, nh, G, hp, ds, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    xdt = jax.random.normal(ks[0], (b, nc, Q, nh, hp), jnp.float32) * 0.5
    B = jax.random.normal(ks[1], (b, nc, Q, G, ds), jnp.float32) * 0.5
    C = jax.random.normal(ks[2], (b, nc, Q, G, ds), jnp.float32) * 0.5
    dtA = -jax.nn.softplus(jax.random.normal(ks[3], (b, nc, Q, nh)))
    cum = jnp.cumsum(dtA, axis=2)
    return (xdt.astype(dtype), B.astype(dtype), C.astype(dtype),
            cum.astype(jnp.float32))


@pytest.mark.parametrize("b,nc,Q,nh,G,hp,ds", [
    (1, 2, 16, 4, 1, 16, 16),
    pytest.param(2, 3, 32, 4, 2, 32, 16,      # grouped B/C
                 marks=pytest.mark.slow),
    pytest.param(1, 1, 64, 8, 1, 64, 128,     # mamba2-like dims
                 marks=pytest.mark.slow),
])
def test_ssd_chunk_allclose(b, nc, Q, nh, G, hp, ds):
    xdt, B, C, cum = _inputs(jax.random.PRNGKey(Q + nh), b, nc, Q, nh, G,
                             hp, ds)
    y, st = ssd_chunk_pallas(xdt, B, C, cum, interpret=True)
    y_ref, st_ref = ssd_chunk_ref(xdt, B, C, cum)
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(st, st_ref, rtol=1e-4, atol=1e-4)


def test_ssd_chunk_bf16():
    xdt, B, C, cum = _inputs(jax.random.PRNGKey(0), 1, 2, 32, 4, 1, 32, 32,
                             dtype=jnp.bfloat16)
    y, st = ssd_chunk_pallas(xdt, B, C, cum, interpret=True)
    y_ref, st_ref = ssd_chunk_ref(xdt, B, C, cum)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=3e-2, atol=3e-2)
    np.testing.assert_allclose(st, st_ref, rtol=3e-2, atol=3e-2)


def test_ssd_kernel_composes_to_full_scan():
    """Kernel intra-chunk + inter-chunk recurrence == model ssd_scan."""
    from repro.models.ssm import ssd_scan
    b, S, nh, hp, G, ds, Q = 2, 64, 4, 16, 1, 16, 16
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 4)
    xh = jax.random.normal(ks[0], (b, S, nh, hp))
    B = jax.random.normal(ks[1], (b, S, G, ds)) * 0.5
    C = jax.random.normal(ks[2], (b, S, G, ds)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (b, S, nh)))
    A = -jnp.exp(jnp.linspace(-1.0, 0.5, nh))

    y_full, st_full = ssd_scan(xh, B, C, dt, A, chunk=Q)

    nc = S // Q
    dtc = dt.reshape(b, nc, Q, nh)
    xdt = xh.reshape(b, nc, Q, nh, hp) * dtc[..., None]
    Bc = B.reshape(b, nc, Q, G, ds)
    Cc = C.reshape(b, nc, Q, G, ds)
    cum = jnp.cumsum(dtc * A, axis=2)
    y_intra, states = ssd_chunk_pallas(xdt, Bc, Cc, cum, interpret=True)
    # inter-chunk recurrence (cheap part, plain JAX)
    seg = jnp.exp(cum[:, :, -1, :])                       # (b,nc,nh)
    def combine(a, bb):
        d1, s1 = a
        d2, s2 = bb
        return d1 * d2, s1 * d2[..., None, None] + s2
    _, st_scan = jax.lax.associative_scan(
        combine, (seg, states.transpose(0, 1, 2, 4, 3)), axis=1)
    H_prev = jnp.concatenate(
        [jnp.zeros_like(st_scan[:, :1]), st_scan[:, :-1]], axis=1)
    Ch = jnp.repeat(Cc, nh // G, axis=3)
    y_inter = jnp.einsum("bnqhs,bnhps->bnqhp", Ch, H_prev) \
        * jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(b, S, nh, hp)
    np.testing.assert_allclose(y, y_full, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(st_scan[:, -1], st_full, rtol=2e-4, atol=2e-4)
