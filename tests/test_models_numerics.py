"""Numerical correctness of model layers vs naive references (CPU, f32)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ArchConfig
from repro.models.layers import (apply_rope, decode_attention,
                                 flash_attention, rmsnorm, init_rmsnorm)
from repro.models.ssm import ssd_scan

jax.config.update("jax_enable_x64", False)


def naive_attention(q, k, v, *, causal=True, window=0, scale=None):
    B, Sq, H, D = q.shape
    _, Sk, KH, _ = k.shape
    G = H // KH
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    kx = jnp.repeat(k, G, axis=2)
    vx = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kx) * scale
    qp, kp = jnp.arange(Sq)[:, None], jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qp >= kp
    if window:
        mask &= kp > qp - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vx)


@pytest.mark.parametrize("Sq,Sk,H,KH,D,chunk", [
    (32, 32, 4, 4, 16, 8),
    (64, 64, 8, 2, 32, 16),
    (17, 17, 4, 1, 8, 5),     # ragged: chunk does not divide S
    (128, 128, 6, 3, 64, 128),  # single chunk
])
@pytest.mark.slow
def test_flash_vs_naive(Sq, Sk, H, KH, D, chunk):
    key = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (2, Sq, H, D), jnp.float32)
    k = jax.random.normal(kk, (2, Sk, KH, D), jnp.float32)
    v = jax.random.normal(kv, (2, Sk, KH, D), jnp.float32)
    out = flash_attention(q, k, v, causal=True, kv_chunk=chunk)
    ref = naive_attention(q, k, v, causal=True)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [4, 16])
@pytest.mark.slow
def test_flash_window_vs_naive(window):
    key = jax.random.PRNGKey(1)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (2, 48, 4, 16), jnp.float32)
    k = jax.random.normal(kk, (2, 48, 2, 16), jnp.float32)
    v = jax.random.normal(kv, (2, 48, 2, 16), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window, kv_chunk=16)
    ref = naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


@pytest.mark.slow
def test_flash_traced_window_matches_static():
    key = jax.random.PRNGKey(2)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (1, 32, 2, 8), jnp.float32)
    k = jax.random.normal(kk, (1, 32, 2, 8), jnp.float32)
    v = jax.random.normal(kv, (1, 32, 2, 8), jnp.float32)
    st = flash_attention(q, k, v, window=8, kv_chunk=16)
    tr = flash_attention(q, k, v, window=jnp.int32(8), kv_chunk=16)
    full_tr = flash_attention(q, k, v, window=jnp.int32(0), kv_chunk=16)
    full_st = flash_attention(q, k, v, window=0, kv_chunk=16)
    np.testing.assert_allclose(st, tr, rtol=1e-6)
    np.testing.assert_allclose(full_tr, full_st, rtol=1e-6)


@pytest.mark.slow
def test_decode_matches_prefill_last_token():
    """Decode-step attention at position t == prefill attention row t."""
    key = jax.random.PRNGKey(3)
    kq, kk, kv = jax.random.split(key, 3)
    B, S, H, KH, D = 2, 24, 4, 2, 16
    q = jax.random.normal(kq, (B, S, H, D), jnp.float32)
    k = jax.random.normal(kk, (B, S, KH, D), jnp.float32)
    v = jax.random.normal(kv, (B, S, KH, D), jnp.float32)
    full = flash_attention(q, k, v, causal=True, kv_chunk=8)
    # cache with padding beyond S
    pad = 8
    kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    dec = decode_attention(q[:, S - 1:S], kc, vc, jnp.int32(S))
    np.testing.assert_allclose(dec[:, 0], full[:, -1], rtol=2e-5, atol=2e-5)


def naive_ssd(xh, B, C, dt, A):
    """O(S^2)-free sequential reference recurrence."""
    b, S, nh, hp = xh.shape
    G, ds = B.shape[2], B.shape[3]
    hg = nh // G
    Bh = jnp.repeat(B, hg, axis=2)
    Ch = jnp.repeat(C, hg, axis=2)
    h = jnp.zeros((b, nh, hp, ds))
    ys = []
    for t in range(S):
        dA = jnp.exp(dt[:, t] * A[None])                       # (b,nh)
        upd = jnp.einsum("bhp,bhs->bhps", xh[:, t] * dt[:, t][..., None],
                         Bh[:, t])
        h = h * dA[..., None, None] + upd
        ys.append(jnp.einsum("bhps,bhs->bhp", h, Ch[:, t]))
    return jnp.stack(ys, axis=1), h


@pytest.mark.parametrize("S,chunk,G", [(16, 4, 1), (24, 8, 2), (13, 5, 1)])
@pytest.mark.slow
def test_ssd_chunked_vs_sequential(S, chunk, G):
    key = jax.random.PRNGKey(4)
    ks = jax.random.split(key, 4)
    b, nh, hp, ds = 2, 4, 8, 16
    xh = jax.random.normal(ks[0], (b, S, nh, hp))
    B = jax.random.normal(ks[1], (b, S, G, ds)) * 0.5
    C = jax.random.normal(ks[2], (b, S, G, ds)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (b, S, nh)))
    A = -jnp.exp(jnp.linspace(-1.0, 1.0, nh))
    y, st = ssd_scan(xh, B, C, dt, A, chunk=chunk)
    y_ref, st_ref = naive_ssd(xh, B, C, dt, A)
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(st, st_ref, rtol=1e-4, atol=1e-4)


def test_rope_relative_property():
    """RoPE: <q_m, k_n> depends only on m - n."""
    key = jax.random.PRNGKey(5)
    q = jax.random.normal(key, (1, 1, 1, 32))
    k = jax.random.normal(jax.random.PRNGKey(6), (1, 1, 1, 32))
    def dot_at(m, n):
        qm = apply_rope(q, jnp.array([[m]]), 1e4)
        kn = apply_rope(k, jnp.array([[n]]), 1e4)
        return float(jnp.sum(qm * kn))
    assert dot_at(5, 3) == pytest.approx(dot_at(12, 10), rel=1e-5)
    assert dot_at(5, 3) != pytest.approx(dot_at(12, 3), rel=1e-3)


def test_rmsnorm_unit_scale():
    p = init_rmsnorm(16, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 3, 16)) * 10
    y = rmsnorm(p, x, 1e-6)
    norm = jnp.sqrt(jnp.mean(y ** 2, axis=-1))
    np.testing.assert_allclose(norm, jnp.ones_like(norm), rtol=1e-3)
