"""Prefill → decode continuity: prefilling a prompt then decoding must match
running the full sequence through teacher forcing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_tiny_config
from repro.models import Model

B, T = 2, 12


@pytest.mark.parametrize("arch", [
    "stablelm-12b", "mamba2-2_7b",
    pytest.param("qwen2-72b", marks=pytest.mark.slow),
    pytest.param("minicpm3-4b", marks=pytest.mark.slow),
    pytest.param("hymba-1_5b", marks=pytest.mark.slow),
    pytest.param("dbrx-132b", marks=pytest.mark.slow),
])
def test_prefill_then_decode_matches_full(arch):
    cfg = get_tiny_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0,
                                cfg.vocab_size)
    full = model.logits(params, {"tokens": tokens})

    split = T // 2
    logits_p, cache = jax.jit(model.prefill)(
        params, {"tokens": tokens[:, :split]})
    np.testing.assert_allclose(np.asarray(logits_p),
                               np.asarray(full[:, split - 1]),
                               rtol=2e-3, atol=2e-3,
                               err_msg=f"{arch}: prefill last-logit mismatch")

    cache = model.extend_cache(cache, T - split)
    step_fn = jax.jit(model.decode_step)
    for t in range(split, T):
        logits, cache = step_fn(params, cache, {"tokens": tokens[:, t:t + 1]})
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full[:, t]), rtol=3e-3, atol=3e-3,
            err_msg=f"{arch}: decode@{t} after prefill mismatch")


def test_prefill_cache_shapes_vlm():
    cfg = get_tiny_config("internvl2-26b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    embeds = jax.random.normal(jax.random.PRNGKey(2),
                               (B, T, cfg.d_model)) * 0.02
    logits, cache = jax.jit(model.prefill)(params, {"embeds": embeds})
    assert logits.shape == (B, cfg.vocab_size)
    assert cache["k"].shape[2] == T
    assert int(cache["pos"]) == T
