"""Multi-tenant co-execution (DESIGN.md §13): SFQ weighted-fair admission
(hypothesis property on the tag algebra), strict tier priority, SLO-aware
admission control (infeasible deadlines rejected before a ticket is
issued), and priority preemption splices — virtual and threaded — checked
against the same stream invariants as every other plan-switch path."""
import random
import time

import pytest

try:        # the property test widens coverage when hypothesis is present;
            # the deterministic grid test below always runs
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import (AdmissionRejected, CoExecutionRuntime, CopyModel,
                        DeviceProfile, FairAdmission, LinearTimeModel,
                        NO_COPY, QoS, TIER_BATCH, TIER_LATENCY,
                        TaskGraphDomain, diamond, transformer_block,
                        truth_from_profiles, verify_graph_dependencies,
                        verify_stream_invariants)


def _dev(name, tflops, bw=None, b=1e-4):
    ops_per_s = tflops * 1e12 / 2
    copy = NO_COPY if bw is None else CopyModel(bw, dtype_size=4)
    return DeviceProfile(name, "gpu" if bw else "cpu",
                         LinearTimeModel(a=1 / ops_per_s, b=b), copy)


def _devices():
    return [_dev("cpu", 0.5), _dev("gpu", 6.0, bw=16e9),
            _dev("xpu", 12.0, bw=16e9)]


def _graph_domain():
    return TaskGraphDomain(_devices(), bus="serialized", dynamic=True)


def _block():
    return transformer_block(d_model=1024, seq=2048, groups=4)


# ------------------------------------------------------ SFQ fairness -------


def _check_weighted_interleaving(weights, per_tenant):
    """The SFQ fairness bound (Goyal et al.): while two tenants stay
    backlogged, the work admitted on their behalf tracks their weight
    ratio within one job of slack — for every admission prefix,
    ``|n_i/w_i - n_j/w_j| <= c/w_i + c/w_j`` at unit job cost ``c``."""
    adm = FairAdmission()
    jobs = []           # (vstart, uid, tenant_index)
    uid = 0
    # all tenants backlogged from t=0: stamp every job before any admit,
    # exactly what pause_admission() + submit + resume_admission() does
    for k in range(per_tenant):
        for i, w in enumerate(weights):
            vs, _ = adm.stamp(f"t{i}", w, 1.0)
            jobs.append((vs, uid, i))
            uid += 1
    order = sorted(jobs)            # the runtime's (vstart, uid) order key
    admitted = [0] * len(weights)
    for vs, _, i in order:
        adm.on_admit(vs)
        admitted[i] += 1
        if any(n >= per_tenant for n in admitted):
            break                    # someone drained: backlog premise gone
        for a in range(len(weights)):
            for b in range(a + 1, len(weights)):
                slack = abs(admitted[a] / weights[a]
                            - admitted[b] / weights[b])
                assert slack <= 1.0 / weights[a] + 1.0 / weights[b] + 1e-9


def test_sfq_admission_is_a_correct_weighted_interleaving():
    """Deterministic sweep of the fairness bound: weight grids plus a
    seeded random batch, so the property is exercised even without
    hypothesis installed."""
    for weights in ([1.0, 1.0], [1.0, 4.0], [0.25, 16.0],
                    [3.0, 1.0, 2.0], [0.5, 8.0, 1.0, 2.5]):
        _check_weighted_interleaving(weights, per_tenant=16)
    rng = random.Random(1234)
    for _ in range(40):
        n = rng.randint(2, 4)
        weights = [rng.uniform(0.25, 16.0) for _ in range(n)]
        _check_weighted_interleaving(weights, rng.randint(4, 24))


if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(weights=st.lists(st.floats(0.25, 16.0), min_size=2, max_size=4),
           per_tenant=st.integers(4, 24))
    def test_sfq_weighted_interleaving_property(weights, per_tenant):
        _check_weighted_interleaving(weights, per_tenant)


def test_single_tenant_sfq_degenerates_to_fifo():
    """One tenant's start tags are strictly nondecreasing in submit order,
    so the fair order key reduces to submission order — the legacy
    single-domain runtime behaves identically under the new admission."""
    adm = FairAdmission()
    tags = [adm.stamp("only", 2.0, c)[0] for c in (3.0, 1.0, 2.0, 0.5)]
    assert tags == sorted(tags)
    assert len(set(tags)) == len(tags)   # strictly increasing: cost > 0


def test_tier_priority_orders_before_weight():
    """A latency-tier job sorts ahead of every batch-tier job regardless
    of how far behind its start tag is (strict priority across tiers,
    SFQ within a tier)."""
    batch = (TIER_BATCH, 0.0, 0)     # earliest possible batch key
    late_latency = (TIER_LATENCY, 1e9, 99)
    assert late_latency < batch


# ------------------------------------------------- SLO admission control ---


def test_infeasible_deadline_rejected_before_dispatch():
    truth = truth_from_profiles(_devices())
    with CoExecutionRuntime(_graph_domain(), executor="virtual",
                            truth=truth, max_inflight=1) as rt:
        bad = rt.submit(_block(), deadline_s=1e-6)
        with pytest.raises(AdmissionRejected):
            bad.wait(30)
        assert bad.rejected
        assert isinstance(bad.error, AdmissionRejected)
        assert bad.error.predicted > bad.error.deadline
        # never dispatched: no measured timeline, no stream events
        assert bad.measured is None
        assert bad.planned is None
        assert not rt.stream_timeline().events
        # a feasible deadline on the same workload sails through
        ok = rt.submit(_block(), deadline_s=10.0)
        ok.wait(30)
        assert not ok.rejected and ok.error is None
        assert ok.measured.makespan <= 10.0
        stats = rt.stats()
        assert stats["rejected"] == 1
        assert stats["tenants"]["default"]["rejected"] == 1
        assert stats["tenants"]["default"]["jobs_done"] == 1


def test_tenant_deadline_applies_from_qos():
    """A tenant-level ``QoS.deadline_s`` applies to every submit that
    doesn't override it."""
    truth = truth_from_profiles(_devices())
    rt = CoExecutionRuntime(None, executor="virtual", truth=truth,
                            max_inflight=1)
    try:
        ten = rt.register("strict", _graph_domain(),
                          QoS(deadline_s=1e-6))
        j = ten.submit(_block())
        with pytest.raises(AdmissionRejected):
            j.wait(30)
        assert j.rejected and ten.rejected == 1
    finally:
        rt.shutdown()


# ---------------------------------------------------- priority preemption --


def test_virtual_preemption_splices_batch_victim():
    """A latency-tier arrival mid-way through a batch job revokes the
    victim's not-yet-started frontier, prices itself ahead of it, and the
    victim's re-solved frontier splices behind — all on the deterministic
    virtual timeline, with clean cross-plan invariants."""
    truth = truth_from_profiles(_devices())
    # one block's solo makespan anchors the latency job's arrival mid-job
    with CoExecutionRuntime(_graph_domain(), executor="virtual",
                            truth=truth, max_inflight=1) as probe:
        M = probe.run_stream([_block()])[0].measured.makespan
    rt = CoExecutionRuntime(None, executor="virtual", truth=truth,
                            feedback=True, max_inflight=2, preempt=True)
    try:
        batch = rt.register("batch", _graph_domain(), QoS(weight=1.0))
        lat = rt.register("lat", _graph_domain(),
                          QoS(weight=4.0, tier=TIER_LATENCY))
        rt.pause_admission()
        b1 = batch.submit(_block(), arrival=0.0)
        b2 = batch.submit(_block(), arrival=0.0)
        lj = lat.submit(diamond(ops=2e9, width=3), arrival=0.5 * M)
        rt.resume_admission()
        rt.drain()
    finally:
        rt.shutdown()
    jobs = [b1, b2, lj]
    assert all(j.error is None for j in jobs)
    # the victim (last-dispatched batch job) recorded the preemption splice
    assert [r.reason for r in b2.replans] == ["preempt"]
    assert b2.replans[0].straggler == f"j{lj.uid}"
    assert b2.replans[0].spliced          # >= 1 ticket actually revoked
    # the latency job ran *inside* the victim's span, not after it
    assert lj.measured.makespan < b2.measured.makespan
    assert verify_stream_invariants(jobs) == []
    for j in jobs:
        assert verify_graph_dependencies(j.final_spec, j.measured) == []


def test_threaded_preemption_reissues_victim_tickets():
    """Threaded half: the latency job's tickets are dispatched first, then
    the victim's pending tickets are revoked and re-appended at the bus
    tails through the §11 ``reissue`` machinery — the shared StreamCore
    never deadlocks and the stream invariants hold."""
    truth = truth_from_profiles(_devices())
    rt = CoExecutionRuntime(None, executor="threads", truth=truth,
                            feedback=True, max_inflight=2, preempt=True,
                            time_scale=20.0)
    try:
        batch = rt.register("batch", _graph_domain(), QoS(weight=1.0))
        lat = rt.register("lat", _graph_domain(),
                          QoS(weight=4.0, tier=TIER_LATENCY))
        b1 = batch.submit(_block())
        b2 = batch.submit(_block())
        time.sleep(0.05)                 # let the batch jobs get underway
        lj = lat.submit(diamond(ops=2e9, width=3))
        rt.drain(timeout=120)
    finally:
        rt.shutdown()
    jobs = [b1, b2, lj]
    assert all(j.error is None for j in jobs)
    preempts = [r for j in jobs for r in j.replans if r.reason == "preempt"]
    assert preempts, "no preemption splice recorded"
    assert all(r.straggler == f"j{lj.uid}" for r in preempts)
    assert verify_stream_invariants(jobs) == []
    for j in jobs:
        assert verify_graph_dependencies(j.final_spec, j.measured) == []


def test_preempted_stream_keeps_fairness_stats():
    """Per-tenant stats survive the multi-tenant run: each tenant reports
    its own jobs/latencies/pump traffic, and the runtime aggregates."""
    truth = truth_from_profiles(_devices())
    rt = CoExecutionRuntime(None, executor="virtual", truth=truth,
                            feedback=True, max_inflight=2, preempt=True)
    try:
        batch = rt.register("batch", _graph_domain(), QoS(weight=1.0))
        lat = rt.register("lat", _graph_domain(),
                          QoS(weight=4.0, tier=TIER_LATENCY))
        rt.pause_admission()
        for _ in range(3):
            batch.submit(_block(), arrival=0.0)
        lat.submit(diamond(ops=2e9, width=3), arrival=0.004)
        rt.resume_admission()
        rt.drain()
        stats = rt.stats()
    finally:
        rt.shutdown()
    assert stats["tenants"]["batch"]["jobs_done"] == 3
    assert stats["tenants"]["lat"]["jobs_done"] == 1
    assert stats["tenants"]["lat"]["p99_latency_s"] > 0.0
    # observations route to the owning tenant's pump, not a shared one
    # (the stream's final job can still be inside the virtual observation
    # lag, so only the backlogged batch tenant is guaranteed traffic)
    assert stats["tenants"]["batch"]["observations"] > 0
    assert stats["tenants"]["batch"]["refit_epoch"] >= 0
