"""Optimizer, checkpoint, data pipeline, and fault-tolerance tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM
from repro.distributed.elastic import (FaultTolerantRunner, RunnerConfig,
                                       StepFailure)
from repro.training.optim import AdamW, FactoredAdam, cosine_schedule, global_norm


# ----------------------------------------------------------------- optim --

def _quadratic_params():
    return {"w": jnp.array([3.0, -2.0, 1.0]), "b": jnp.array(0.5)}


def test_adamw_minimizes_quadratic():
    params = _quadratic_params()
    opt = AdamW(learning_rate=0.05, weight_decay=0.0, clip_norm=1e9)
    state = opt.init(params)

    def loss_fn(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2

    for _ in range(200):
        grads = jax.grad(loss_fn)(params)
        params, state, metrics = opt.update(grads, state, params)
    assert float(loss_fn(params)) < 1e-3
    assert int(state["step"]) == 200


def test_factored_adam_minimizes_matrix_quadratic():
    params = {"w": jnp.ones((8, 16)) * 2.0}
    opt = FactoredAdam(learning_rate=0.1)
    state = opt.init(params)
    # factored state is O(n+m), not O(nm)
    assert state["v"]["w"]["vr"].shape == (8,)
    assert state["v"]["w"]["vc"].shape == (16,)

    def loss_fn(p):
        return jnp.mean(p["w"] ** 2)

    for _ in range(300):
        grads = jax.grad(loss_fn)(params)
        params, state, _ = opt.update(grads, state, params)
    assert float(loss_fn(params)) < 1e-3


def test_grad_clipping():
    params = {"w": jnp.zeros(4)}
    opt = AdamW(learning_rate=1.0, clip_norm=1.0, weight_decay=0.0)
    state = opt.init(params)
    grads = {"w": jnp.full(4, 1e6)}
    _, _, metrics = opt.update(grads, state, params)
    assert metrics["grad_norm"] > 1e5  # reported pre-clip


def test_cosine_schedule():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(jnp.asarray(0))) == pytest.approx(0.0)
    assert float(lr(jnp.asarray(10))) == pytest.approx(1e-3, rel=1e-3)
    assert float(lr(jnp.asarray(100))) == pytest.approx(1e-4, rel=1e-2)


def test_global_norm():
    t = {"a": jnp.ones(4), "b": jnp.ones((2, 2)) * 2}
    assert float(global_norm(t)) == pytest.approx(np.sqrt(4 + 16))


# ------------------------------------------------------------ checkpoint --

def _tree(x=1.0):
    return {"params": {"w": jnp.full((4, 3), x), "b": jnp.zeros(3)},
            "opt": {"step": jnp.asarray(7, jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree(2.5)
    store.save(tmp_path, 42, t)
    restored, step = store.restore(tmp_path, jax.tree.map(jnp.zeros_like, t))
    assert step == 42
    np.testing.assert_array_equal(restored["params"]["w"], t["params"]["w"])
    assert store.latest_step(tmp_path) == 42


def test_checkpoint_keep_k(tmp_path):
    for s in (1, 2, 3, 4, 5):
        store.save(tmp_path, s, _tree(float(s)), keep=2)
    steps = sorted(p.name for p in tmp_path.iterdir()
                   if p.name.startswith("step_"))
    assert steps == ["step_00000004", "step_00000005"]


def test_checkpoint_atomic_crash_safety(tmp_path):
    store.save(tmp_path, 1, _tree(1.0))
    # simulate a crash mid-save: stale tmp dir must not break restore
    (tmp_path / "step_00000002.tmp").mkdir()
    restored, step = store.restore(tmp_path, _tree(0.0))
    assert step == 1
    assert float(restored["params"]["w"][0, 0]) == 1.0


def test_checkpoint_shape_mismatch_raises(tmp_path):
    store.save(tmp_path, 1, _tree())
    bad = {"params": {"w": jnp.zeros((5, 3)), "b": jnp.zeros(3)},
           "opt": {"step": jnp.asarray(0, jnp.int32)}}
    with pytest.raises(ValueError):
        store.restore(tmp_path, bad)


# ------------------------------------------------------------------ data --

def test_data_deterministic_and_host_sharded():
    cfg = dict(vocab_size=100, seq_len=16, global_batch=8, seed=3)
    a = SyntheticLM(DataConfig(**cfg, num_hosts=2, host_index=0)).batch(5)
    a2 = SyntheticLM(DataConfig(**cfg, num_hosts=2, host_index=0)).batch(5)
    b = SyntheticLM(DataConfig(**cfg, num_hosts=2, host_index=1)).batch(5)
    np.testing.assert_array_equal(a["tokens"], a2["tokens"])  # replayable
    assert not np.array_equal(a["tokens"], b["tokens"])       # disjoint hosts
    assert a["tokens"].shape == (4, 16)
    # labels are next-token shifted
    assert a["labels"].shape == (4, 16)


def test_data_has_learnable_structure():
    cfg = DataConfig(vocab_size=50, seq_len=128, global_batch=16, seed=0)
    data = SyntheticLM(cfg)
    batch = data.batch(0)
    toks, labels = batch["tokens"], batch["labels"]
    # bigram successor fires ~50% of the time
    hits = (labels == data._succ[toks]).mean()
    assert 0.3 < hits < 0.7


def test_prefetcher():
    cfg = DataConfig(vocab_size=10, seq_len=4, global_batch=2)
    pf = Prefetcher(SyntheticLM(cfg).stream(), depth=2)
    b0 = next(pf)
    b1 = next(pf)
    assert b0["tokens"].shape == (2, 4)
    assert not np.array_equal(b0["tokens"], b1["tokens"])
    pf.close()


# -------------------------------------------------------- fault tolerance --

def test_runner_recovers_from_failures(tmp_path):
    calls = {"n": 0}

    def flaky_step(state, batch):
        calls["n"] += 1
        if calls["n"] in (3, 7):   # two injected failures
            raise StepFailure("injected")
        return {"x": state["x"] + batch["inc"]}, {"x": state["x"]}

    cfg = RunnerConfig(checkpoint_dir=str(tmp_path), checkpoint_every=2)
    runner = FaultTolerantRunner(cfg, step_fn=flaky_step,
                                 state={"x": jnp.asarray(0.0)})
    batches = ({"inc": jnp.asarray(1.0)} for _ in range(100))
    final = runner.run(batches, num_steps=10)
    assert runner.step == 10
    assert runner.restarts == 2
    # state reflects 10 successful increments from the restored points
    assert float(final["x"]) >= 8.0
    assert store.latest_step(tmp_path) == 10


def test_runner_resumes_from_checkpoint(tmp_path):
    def step(state, batch):
        return {"x": state["x"] + 1.0}, {}

    cfg = RunnerConfig(checkpoint_dir=str(tmp_path), checkpoint_every=5)
    r1 = FaultTolerantRunner(cfg, step_fn=step, state={"x": jnp.asarray(0.0)})
    r1.run(({} for _ in range(100)), num_steps=7)
    # new runner (fresh process) resumes from step 7 checkpoint
    r2 = FaultTolerantRunner(cfg, step_fn=step, state={"x": jnp.asarray(0.0)})
    assert r2.restore_latest()
    assert r2.step == 7
    assert float(r2.state["x"]) == 7.0
