"""Task-graph workloads on the shared timeline engine (DESIGN.md §10):
TaskGraph validation, the HEFT-style list scheduler vs the engine / naive
baselines / brute force, executor dependency invariants (threaded and
virtual), and the runtime round trip with per-task observations."""
import itertools

import pytest

from repro.core import (CoExecutionRuntime, CopyModel, DeviceProfile,
                        GraphTimelineSpec, LinearTimeModel, NO_COPY, POAS,
                        PlanCache, TaskGraph, TaskGraphDomain, TaskNode,
                        build_graph_timeline, diamond, get_domain,
                        graph_finish_times, list_domains, paper_mach1,
                        simulate_graph_timeline, solve_list_schedule,
                        transformer_block, truth_from_profiles,
                        verify_graph_dependencies, verify_stream_invariants)
from repro.core.bus import _graph_topo_order


def _dev(name, tflops, bw=None, b=1e-4, kind=None):
    ops_per_s = tflops * 1e12 / 2
    copy = NO_COPY if bw is None else CopyModel(bw, dtype_size=4)
    return DeviceProfile(name, kind or ("gpu" if bw else "cpu"),
                         LinearTimeModel(a=1 / ops_per_s, b=b), copy)


def _devices():
    """A host CPU plus two PCIe accelerators of different speeds."""
    return [_dev("cpu", 0.5), _dev("gpu", 6.0, bw=16e9),
            _dev("xpu", 12.0, bw=16e9)]


def _chain(n=3, ops=1e9, out_bytes=1e6):
    nodes = tuple(TaskNode(f"t{i}", ops, in_bytes=out_bytes,
                           out_bytes=out_bytes) for i in range(n))
    edges = tuple((f"t{i}", f"t{i+1}") for i in range(n - 1))
    return TaskGraph(nodes=nodes, edges=edges)


# ---------------------------------------------------------- validation ------

def test_graph_validation_rejects_bad_graphs():
    a, b = TaskNode("a", 1.0), TaskNode("b", 1.0)
    with pytest.raises(ValueError, match="duplicate"):
        TaskGraph(nodes=(a, TaskNode("a", 2.0)))
    with pytest.raises(ValueError, match="unknown task"):
        TaskGraph(nodes=(a, b), edges=(("a", "zzz"),))
    with pytest.raises(ValueError, match="self-edge"):
        TaskGraph(nodes=(a, b), edges=(("a", "a"),))
    with pytest.raises(ValueError, match="cycle"):
        TaskGraph(nodes=(a, b), edges=(("a", "b"), ("b", "a")))


def test_topo_order_and_critical_path():
    g = diamond(ops=1e9, width=3)
    order = g.topo_order()
    pos = {i: p for p, i in enumerate(order)}
    for u, v in g.edge_indices():
        assert pos[u] < pos[v]
    length, path = g.critical_path()
    # src -> one mid -> sink
    assert length == pytest.approx(1e9 + 2e8)
    assert path[0].endswith("src") and path[-1].endswith("sink")
    assert g.total_ops() == pytest.approx(3e9 + 2e8)


def test_workload_protocol_and_cost_signature():
    g1 = _chain()
    g2 = _chain()
    g3 = _chain(ops=2e9)
    assert g1.cost_signature() == g2.cost_signature()
    assert g1.cost_signature() != g3.cost_signature()
    assert hash(g1.cost_signature())
    assert "task-graph" in list_domains()
    assert isinstance(get_domain("task-graph", _devices()), TaskGraphDomain)


# ----------------------------------------- solver == simulator == spec ------

def test_list_schedule_makespan_matches_simulated_timeline_exactly():
    """Acceptance: the list-scheduled makespan matches simulate_graph_timeline
    exactly on the same spec — one engine, no approximation gap."""
    for g in (transformer_block(d_model=1024, seq=2048, groups=4),
              diamond(ops=5e9, width=4), _chain(5)):
        devs = _devices()
        res = solve_list_schedule(devs, g.task_specs(), g.edge_indices(),
                                  bus="serialized")
        tl = simulate_graph_timeline(devs, g.task_specs(), g.edge_indices(),
                                     res.assign, topology="serialized",
                                     order=res.order)
        assert res.makespan == tl.makespan
        assert max(res.task_finish) == tl.makespan
        assert verify_graph_dependencies(g, tl) == []


def test_schedule_spec_rebase_reproduces_domain_timeline():
    g = transformer_block(d_model=1024, seq=1024, groups=2)
    dom = TaskGraphDomain(_devices(), bus="serialized")
    plan = POAS(dom).plan(g)
    spec = plan.schedule.spec
    assert isinstance(spec, GraphTimelineSpec)
    rb = spec.rebase()
    assert [(e.task, e.device, e.kind, e.start, e.end) for e in rb.events] \
        == [(e.task, e.device, e.kind, e.start, e.end)
            for e in plan.schedule.timeline.events]
    # per-device op totals agree between spec and optimize result
    by_dev = spec.ops_by_device()
    for d, c in zip(_devices(), plan.optimize.ops):
        assert by_dev.get(d.name, 0.0) == pytest.approx(c)
    # the adapt output's per-device task lists cover the graph exactly
    names = [t for d in _devices() for t in plan.adapted.tasks_of(d.name)]
    assert sorted(names) == sorted(n.name for n in g.nodes)


def test_list_schedule_beats_naive_topo_order_on_diamond():
    """Acceptance: on a fork-join diamond, rank/EFT placement parallelizes
    the branches while the naive topo-order baseline piles everything onto
    the myopically-fastest device and serializes them."""
    devs = _devices()
    g = diamond(ops=20e9, bytes_per_edge=1e6, width=3)
    smart = solve_list_schedule(devs, g.task_specs(), g.edge_indices(),
                                bus="serialized")
    naive = solve_list_schedule(devs, g.task_specs(), g.edge_indices(),
                                bus="serialized", priority="topo",
                                refine=False)
    assert smart.makespan < naive.makespan - 1e-9
    # the naive baseline is single-device (myopic EFT ignores queueing)
    assert len({naive.assign[i] for i in range(len(g))}) == 1
    assert len({smart.assign[i] for i in range(len(g))}) >= 2


def test_list_schedule_equals_brute_force_on_small_graphs():
    """Acceptance: <= 5 nodes x 3 devices — the solver returns the exact
    optimum (its small-instance mode enumerates the assignment space)."""
    devs = _devices()
    graphs = [
        _chain(3),
        diamond(ops=8e9, width=2),                      # 4 nodes
        diamond(ops=8e9, bytes_per_edge=64e6, width=3),  # 5, copy-heavy
        TaskGraph(nodes=(TaskNode("a", 4e9, out_bytes=4e6),
                         TaskNode("b", 6e9, out_bytes=1e6),
                         TaskNode("c", 2e9, out_bytes=1e6),
                         TaskNode("d", 9e9, in_bytes=32e6, out_bytes=8e6),
                         TaskNode("e", 1e9, out_bytes=1e6)),
                  edges=(("a", "c"), ("b", "c"), ("c", "e"), ("d", "e"))),
    ]
    for g in graphs:
        assert len(g) <= 5
        res = solve_list_schedule(devs, g.task_specs(), g.edge_indices(),
                                  bus="serialized")
        best = min(
            max(graph_finish_times(devs, g.task_specs(), g.edge_indices(),
                                   a, topology="serialized",
                                   order=res.order))
            for a in itertools.product(range(3), repeat=len(g)))
        assert res.makespan == pytest.approx(best, rel=1e-12)


def test_list_schedule_never_worse_than_best_single_device():
    """The degenerate-assignment guard (§3.4.3 in DAG form): EFT local
    optima must never lose to handing the whole graph to one device."""
    for devs_fn in (paper_mach1, _devices):
        devs = devs_fn() if callable(devs_fn) else devs_fn
        g = transformer_block(d_model=2048, seq=4096, groups=4)
        res = solve_list_schedule(devs, g.task_specs(), g.edge_indices(),
                                  bus="serialized")
        singles = [max(graph_finish_times(
            devs, g.task_specs(), g.edge_indices(), [j] * len(g),
            topology="serialized", order=res.order))
            for j in range(len(devs))]
        assert res.makespan <= min(singles) + 1e-12


# ------------------------------------------------------ engine details ------

def test_same_device_edges_are_free_cross_device_edges_pay_copies():
    devs = _devices()
    g = _chain(2, ops=1e9, out_bytes=8e6)
    specs, edges = g.task_specs(), g.edge_indices()
    same = build_graph_timeline(devs, specs, edges, [2, 2],
                                topology="serialized")
    cross = build_graph_timeline(devs, specs, edges, [2, 1],
                                 topology="serialized")
    # same-device: exactly one copy_in (t0's external input), one copy_out
    # (t1's sink return), no staging between the tasks
    assert len([e for e in same.events if e.kind == "copy_in"]) == 2
    assert len([e for e in same.events if e.kind == "copy_out"]) == 1
    # cross-device: t0's output staged to host, then read by t1's device
    stage = [e for e in cross.events
             if e.kind == "copy_out" and e.task == "t0"]
    read = [e for e in cross.events
            if e.kind == "copy_in" and e.task == "t1"]
    assert len(stage) == 1 and len(read) >= 1
    assert min(e.start for e in read) >= stage[0].end - 1e-12
    assert cross.makespan > same.makespan


def test_no_copy_host_reads_staged_output_and_writes_free():
    devs = _devices()
    g = _chain(2, ops=1e9, out_bytes=8e6)
    # t0 on xpu, t1 on the no-copy host: host waits for the staged copy,
    # and emits no copy events of its own
    tl = build_graph_timeline(devs, g.task_specs(), g.edge_indices(),
                              [2, 0], topology="serialized")
    host = [e for e in tl.events if e.device == "cpu"]
    assert all(e.kind == "compute" for e in host)
    stage = [e for e in tl.events if e.kind == "copy_out"][0]
    assert host[0].start >= stage.end - 1e-12


def test_graph_timeline_carried_clocks_serialize_across_plans():
    devs = _devices()
    g = transformer_block(d_model=1024, seq=1024, groups=2)
    res = solve_list_schedule(devs, g.task_specs(), g.edge_indices(),
                              bus="serialized")
    from repro.core import carry_clocks
    t1 = build_graph_timeline(devs, g.task_specs(), g.edge_indices(),
                              res.assign, topology="serialized",
                              order=res.order)
    t2 = build_graph_timeline(devs, g.task_specs(), g.edge_indices(),
                              res.assign, topology="serialized",
                              order=res.order, clocks=carry_clocks(t1))
    evs = sorted((e for e in t1.events + t2.events if e.kind != "compute"),
                 key=lambda e: (e.start, e.end))
    for a, b in zip(evs, evs[1:]):
        if a.link == b.link:
            assert b.start >= a.end - 1e-9
    for d in devs:
        if t1.device_events(d.name) and t2.device_events(d.name):
            assert min(e.start for e in t2.device_events(d.name)) >= \
                t1.device_finish(d.name) - 1e-9


def test_rank_order_is_topological():
    g = transformer_block(d_model=1024, seq=1024, groups=4)
    res = solve_list_schedule(_devices(), g.task_specs(), g.edge_indices(),
                              bus="serialized", refine=False)
    pos = {i: p for p, i in enumerate(res.order)}
    for u, v in g.edge_indices():
        assert pos[u] < pos[v]
    # sanity: Kahn order on the same edges agrees on reachability
    assert sorted(res.order) == _graph_topo_order(len(g), g.edge_indices())


def test_plan_cache_hits_on_structurally_equal_graphs():
    dom = TaskGraphDomain(_devices(), bus="serialized")
    poas = POAS(dom, cache=PlanCache())
    g1 = transformer_block(d_model=1024, seq=1024, groups=2)
    g2 = transformer_block(d_model=1024, seq=1024, groups=2)
    p1 = poas.plan(g1)
    p2 = poas.plan(g2)
    assert poas.cache.hits == 1
    assert p2.schedule is p1.schedule   # solved phases shared on a hit
    poas.plan(transformer_block(d_model=1024, seq=2048, groups=2))
    assert poas.cache.misses == 2


# -------------------------------------------------- executor invariants -----

THROTTLE = 3.0


def _truth(devs, at=2, device="xpu"):
    return truth_from_profiles(
        devs, lambda uid, name: THROTTLE if uid >= at and name == device
        else 1.0)


def test_virtual_executor_respects_dependencies():
    """Acceptance (virtual half): the measured (virtual-time) timelines
    never start a task before all upstream outputs have landed."""
    g = transformer_block(d_model=1024, seq=1024, groups=4)
    dom = TaskGraphDomain(_devices(), bus="serialized", dynamic=True)
    with CoExecutionRuntime(dom, executor="virtual",
                            truth=_truth(_devices()), feedback=True,
                            max_inflight=1) as rt:
        jobs = rt.run_stream([g] * 6)
    assert all(j.error is None for j in jobs)
    assert verify_stream_invariants(jobs) == []
    for j in jobs:
        assert verify_graph_dependencies(j.plan.schedule.spec,
                                         j.measured) == []


def test_threaded_executor_respects_dependencies():
    """Acceptance (threaded half): real StreamCore workers block on
    upstream task completion; measured wall-clock timelines pass the
    dependency and per-link invariants across plan boundaries."""
    g = transformer_block(d_model=1024, seq=1024, groups=4)
    dom = TaskGraphDomain(paper_mach1(), bus="serialized", dynamic=True)
    with CoExecutionRuntime(dom, executor="threads",
                            truth=_truth(paper_mach1(),
                                         device="2080ti-tensor"),
                            feedback=True, carry_clocks=True,
                            max_inflight=2, time_scale=0.02) as rt:
        jobs = rt.run_stream([g] * 4, timeout=120)
    assert all(j.error is None for j in jobs)
    assert verify_stream_invariants(jobs) == []
    for j in jobs:
        assert verify_graph_dependencies(j.plan.schedule.spec,
                                         j.measured) == []
    assert rt.pump.observations > 0


def test_threaded_upstream_failure_fails_downstream_not_runtime():
    """A failing task fails its dependents (their data never landed) and
    the job — but the core survives: the next job runs clean."""
    from repro.core import DeviceTask, StreamCore, Timeline
    from repro.core.bus import BusEvent
    core = StreamCore()
    try:
        def boom():
            raise RuntimeError("task a exploded")

        planned = {"pcie": [("a", "gpu", "copy_in"), ("b", "cpu", "copy_in")]}
        tasks = [
            DeviceTask("gpu", copy_in=lambda: None, compute=boom,
                       copy_out=None, task="a"),
            DeviceTask("cpu", copy_in=lambda: None, compute=lambda: None,
                       copy_out=None, task="b", deps=("a",)),
        ]
        h = core.dispatch(tasks, planned)
        with pytest.raises(RuntimeError, match="exploded"):
            h.wait(30)
        assert any("upstream task 'a' failed" in str(e) for e in h.errors)
        # the workers and buses survive: a clean graph job completes
        tasks2 = [
            DeviceTask("gpu", copy_in=lambda: None, compute=lambda: None,
                       copy_out=None, task="a"),
            DeviceTask("cpu", copy_in=lambda: None, compute=lambda: None,
                       copy_out=None, task="b", deps=("a",)),
        ]
        tl = core.dispatch(tasks2, planned).wait(30)
        assert isinstance(tl, Timeline)
        comp = {e.task: e for e in tl.events if e.kind == "compute"}
        assert comp["b"].start >= comp["a"].end - 1e-9
        assert all(isinstance(e, BusEvent) for e in tl.events)
    finally:
        core.shutdown()


# ------------------------------------------------- runtime round trip -------

def test_runtime_round_trip_with_per_task_observations_refits():
    """Acceptance: TaskGraph jobs round-trip through CoExecutionRuntime;
    per-task observations (many distinct sizes per device per job) trigger
    a re-fit, invalidate the PlanCache, and shed the throttled device."""
    g = transformer_block(d_model=1024, seq=1024, groups=4)
    dom = TaskGraphDomain(_devices(), bus="serialized", dynamic=True)
    with CoExecutionRuntime(dom, executor="virtual",
                            truth=_truth(_devices(), at=2),
                            feedback=True, max_inflight=1) as rt:
        jobs = rt.run_stream([g] * 8)
        stats = rt.stats()
    assert all(j.error is None for j in jobs)
    # one graph job feeds one observation per scheduled task
    n_sched = sum(1 for a in jobs[0].plan.optimize.assign if a >= 0)
    assert stats["observations"] >= n_sched
    # the re-fit happened and later plans were solved under newer models
    assert dom.dyn.epoch > 0
    assert rt.plan_cache.invalidations >= 1
    assert jobs[-1].epoch_at_plan > jobs[0].epoch_at_plan
    # the throttled xpu sheds ops share after the re-fit
    xpu = 2
    share_pre = jobs[1].plan.optimize.shares()[xpu]
    share_post = jobs[-1].plan.optimize.shares()[xpu]
    assert share_post < share_pre


# ------------------------------------------------------- solver budget ------

def test_descend_assign_never_exceeds_max_evals():
    """The reassignment descent's eval budget binds *mid-sweep*: with a
    budget far below one full sweep (len(tasks) * (d-1) candidate moves)
    the reported eval count must still stay within it."""
    from repro.core.bus import BusTopology
    from repro.core.optimize import _descend_assign, _rank_order
    from repro.core import GraphSimContext
    g = transformer_block(d_model=1024, seq=1024, groups=4)
    devices = _devices()
    tasks, edges = g.task_specs(), g.edge_indices()
    topo = BusTopology.from_spec("serialized", devices)
    order = _rank_order(devices, tasks, edges)
    sweep = len(tasks) * (len(devices) - 1)
    for budget in (2, 5, max(3, sweep - 1)):
        assert budget < sweep      # the cap can only hold inside a sweep
        ctx = GraphSimContext(devices, tasks, edges, topo, order)
        _, evals, span, _ = _descend_assign(ctx, [0] * len(tasks),
                                            max_evals=budget)
        assert 1 <= evals <= budget
        assert span > 0.0


def test_solve_list_schedule_partial_iterations_track_budget():
    """A partial re-solve (the splice path) draws its three seeds' descents
    from ONE shared ``max_evals`` pool — the old per-seed split
    (``max(40, budget // 3)`` each) let the sum overshoot the cap by up to
    3x at small budgets, which on a live splice is real added latency.
    Total iterations: EFT placement (free x devices) plus at most the pool,
    plus the >= 1-eval-per-seed floor that preserves the quality contract."""
    g = transformer_block(d_model=1024, seq=1024, groups=4)
    devices = _devices()
    tasks, edges = g.task_specs(), g.edge_indices()
    n = len(tasks)
    seed = [0] * n
    for budget in (6, 60, 200):
        res = solve_list_schedule(devices, tasks, edges, bus="serialized",
                                  seed_assign=seed, max_evals=budget)
        assert res.iterations <= n * len(devices) + budget + 3
        assert res.makespan > 0.0
