"""transformer_stack — the scheduler bench's DAG generator (no hypothesis
needed; the engine-exactness properties live in
test_scheduler_incremental.py)."""
import pytest

from repro.core import transformer_stack


def test_transformer_stack_shape():
    L, M, G = 2, 3, 4
    g = transformer_stack(layers=L, microbatches=M, groups=G)
    per_block = 4 * G + 3
    assert len(g) == L * M * per_block
    assert len(g.edges) == L * M * (5 * G + 1) + (L - 1) * M * G
    names = {nd.name for nd in g.nodes}
    assert all(u in names and v in names for u, v in g.edges)


def test_transformer_stack_from_config_zoo():
    g = transformer_stack("stablelm-12b", layers=2, microbatches=2)
    assert len(g) == 2 * 2 * (4 * 4 + 3)


def test_transformer_stack_cost_signature():
    a = transformer_stack(layers=2, microbatches=2)
    b = transformer_stack(layers=2, microbatches=2)
    c = transformer_stack(layers=2, microbatches=4)
    assert a.cost_signature() == b.cost_signature()
    assert a.cost_signature() != c.cost_signature()


def test_transformer_stack_validation():
    with pytest.raises(ValueError):
        transformer_stack(layers=0)
    with pytest.raises(ValueError):
        transformer_stack(microbatches=0)


def test_transformer_stack_microbatches_split_sequence():
    whole = transformer_stack(layers=1, microbatches=1, seq=4096)
    split = transformer_stack(layers=1, microbatches=4, seq=4096)
    assert len(split) == 4 * len(whole)
    # GEMM work is linear in seq (conserved); attention is quadratic, so
    # shorter microbatch sequences do strictly less attention work
    assert split.total_ops() < whole.total_ops()
