"""ssm_stack — mamba2-style scan-chain DAGs from the config zoo (the
ROADMAP's open whole-model-DAG item beyond attention stacks)."""
import pytest

from repro.core import ssm_block, ssm_stack


def test_ssm_block_shape():
    g = ssm_block(d_model=1024, seq=2048, chunk=256)
    n_chunks = 2048 // 256
    assert len(g) == 3 + 2 * n_chunks
    names = {nd.name for nd in g.nodes}
    assert all(u in names and v in names for u, v in g.edges)
    # the scan chain is serial: state{c-1} -> state{c} for every chunk
    for c in range(1, n_chunks):
        assert (f"ssm.state{c-1}", f"ssm.state{c}") in g.edges
    # intra chunks are mutually independent (the DAG width)
    assert not any(u.startswith("ssm.intra") and v.startswith("ssm.intra")
                   for u, v in g.edges)


def test_ssm_stack_from_config_zoo():
    g = ssm_stack("mamba2-2_7b", layers=2, microbatches=1, seq=8192)
    n_chunks = 8192 // 256          # the config's ssm_chunk
    assert len(g) == 2 * (3 + 2 * n_chunks)
    assert len(g.blocks) == 2
    # blocks chain through outproj -> inproj
    assert ("mamba2-2_7b.l0.m0.outproj",
            "mamba2-2_7b.l1.m0.inproj") in g.edges


def test_ssm_stack_microbatch_and_template_structure():
    g = ssm_stack(layers=5, microbatches=2, seq=2048, chunk=512)
    assert len(g.blocks) == 10
    part = g.template_partition(min_repeats=2)
    assert part is not None and len(part.instances) == 10
    # first / middle / last layers split on boundary arity alone
    assert part.n_templates == 3
    assert sorted(part.repeats().values()) == [2, 2, 6]


def test_ssm_stack_critical_path_is_the_scan_chain():
    g = ssm_block(d_model=512, seq=4096, chunk=256)
    _, path = g.critical_path()
    states = [p for p in path if ".state" in p]
    # the serial scan spine dominates the path (the tail may exit through
    # the last chunk's heavier intra term instead of its state)
    assert len(states) >= 4096 // 256 - 1


def test_ssm_stack_validation():
    with pytest.raises(ValueError):
        ssm_stack(layers=0)
    with pytest.raises(ValueError):
        ssm_stack(microbatches=0)
    with pytest.raises(ValueError):
        ssm_block(d_model=0)
