"""Mid-graph re-planning (DESIGN.md §11): straggler detection → frontier
freeze → pinned re-solve → ticket re-issue, in deterministic virtual time
and through the real threaded StreamCore — plus regression tests for the
runtime-hardening bugfix sweep (TicketBus under ``python -O``, stats
percentiles, the DAG copy-out invariant check)."""
import math
import threading
import time

import pytest

from repro.core import (CoExecutionRuntime, CopyModel, DeviceProfile,
                        GemmDomain, GemmWorkload, LinearTimeModel, NO_COPY,
                        TaskGraph, TaskGraphDomain, TaskNode, TicketBus,
                        Timeline, diamond, solve_list_schedule,
                        transformer_block, truth_from_profiles,
                        verify_graph_dependencies, verify_stream_invariants)
from repro.core.bus import BusEvent
from repro.core.runtime import StreamJob

THROTTLE = 6.0


def _dev(name, tflops, bw=None, b=1e-4):
    ops_per_s = tflops * 1e12 / 2
    copy = NO_COPY if bw is None else CopyModel(bw, dtype_size=4)
    return DeviceProfile(name, "gpu" if bw else "cpu",
                         LinearTimeModel(a=1 / ops_per_s, b=b), copy)


def _devices():
    return [_dev("cpu", 0.5), _dev("gpu", 6.0, bw=16e9),
            _dev("xpu", 12.0, bw=16e9)]


def _truth(factor=THROTTLE, device="xpu"):
    """Ground truth throttling ``device`` from the very first job — the
    plan is solved with nominal models, execution is slow: the
    mid-DAG-straggler scenario."""
    return truth_from_profiles(
        _devices(), lambda uid, name: factor if name == device else 1.0)


def _block():
    return transformer_block(d_model=1024, seq=2048, groups=4)


# ------------------------------------------------ frontier extraction -------


def test_frontier_subgraph_extracts_not_started_tasks():
    g = TaskGraph(nodes=(TaskNode("a", 1e9, out_bytes=4e6),
                         TaskNode("b", 2e9, in_bytes=1e6, out_bytes=1e6),
                         TaskNode("c", 3e9)),
                  edges=(("a", "b"), ("b", "c")))
    sub, boundary = g.frontier_subgraph({"a"})
    assert [t.name for t in sub.nodes] == ["b", "c"]
    assert boundary == (("a", "b"),)
    # the boundary payload is folded into the consumer's external input
    assert sub.node("b").in_bytes == pytest.approx(1e6 + 4e6)
    assert sub.edges == (("b", "c"),)
    # empty frontier / full frontier round-trips
    sub2, b2 = g.frontier_subgraph(set())
    assert len(sub2) == 3 and b2 == ()


def test_frontier_subgraph_rejects_non_ancestor_closed_snapshot():
    g = TaskGraph(nodes=(TaskNode("a", 1.0), TaskNode("b", 1.0)),
                  edges=(("a", "b"),))
    with pytest.raises(ValueError, match="not ancestor-closed"):
        g.frontier_subgraph({"b"})
    with pytest.raises(ValueError, match="unknown started"):
        g.frontier_subgraph({"zzz"})


# ----------------------------------------------------- partial solve --------


def test_solve_list_schedule_pinned_tasks_keep_assignment():
    devs = _devices()
    g = diamond(ops=8e9, width=3)
    pinned = {0: 0, 1: 1}   # src on cpu, first branch on gpu
    res = solve_list_schedule(devs, g.task_specs(), g.edge_indices(),
                              bus="serialized", pinned=pinned)
    assert res.assign[0] == 0 and res.assign[1] == 1
    assert all(a >= 0 for a in res.assign)


def test_partial_solve_with_ext_and_clocks_prices_frontier_only():
    """Frozen tasks priced externally: their (compute_end, avail) gate the
    frontier; an inf avail forbids moving consumers off the frozen
    producer's device (its output never reached the host)."""
    devs = _devices()
    g = TaskGraph(nodes=(TaskNode("a", 4e9, out_bytes=8e6),
                         TaskNode("b", 4e9, out_bytes=8e6),
                         TaskNode("c", 1e9)),
                  edges=(("a", "b"), ("b", "c")))
    specs, edges = g.task_specs(), g.edge_indices()
    # 'a' frozen on xpu, output staged at t=0.05; force b cross-device —
    # its read of the staged output cannot begin before avail
    res = solve_list_schedule(devs, specs, edges, bus="serialized",
                              pinned={0: 2, 1: 1}, ext={0: (0.04, 0.05)})
    assert res.assign[0] == 2 and res.assign[1] == 1
    assert res.task_finish[1] >= 0.05 - 1e-12
    # 'a' frozen on xpu with output NEVER staged: b must stay on xpu
    res2 = solve_list_schedule(devs, specs, edges, bus="serialized",
                               pinned={0: 2}, ext={0: (0.04, math.inf)})
    assert res2.assign[1] == 2
    assert math.isfinite(res2.makespan)


def test_rebase_partial_emits_frontier_events_only():
    from repro.core import POAS
    dom = TaskGraphDomain(_devices(), bus="serialized")
    plan = POAS(dom).plan(_block())
    spec = plan.schedule.spec
    frozen = spec.tasks[spec.order[0]].name
    i = spec.order[0]
    tl = spec.rebase_partial(ext={frozen: (1e-3, 2e-3)})
    names = {e.task for e in tl.events}
    assert frozen not in names
    assert names == {t.name for j, t in enumerate(spec.tasks)
                     if j != i and spec.assign[j] >= 0}


# -------------------------------------------- virtual-time re-planning ------


def _run_virtual(replan: bool, workloads, **kw):
    dom = TaskGraphDomain(_devices(), bus="serialized", dynamic=True)
    rt = CoExecutionRuntime(dom, executor="virtual", truth=_truth(),
                            feedback=True, max_inflight=1, replan=replan,
                            straggler_threshold=1.3, **kw)
    try:
        jobs = rt.run_stream(workloads)
        return rt, jobs
    finally:
        rt.shutdown()


def test_virtual_replan_migrates_frontier_and_beats_locked_in_plan():
    """Acceptance: a device throttling mid-DAG loses its not-yet-started
    successors to the re-plan, and the measured makespan is strictly —
    and substantially — better than the locked-in plan's."""
    g = _block()
    _, locked = _run_virtual(False, [g])
    rt, jobs = _run_virtual(True, [g])
    j = jobs[0]
    assert len(j.replans) == 1
    r = j.replans[0]
    assert r.spliced and r.frozen
    # the frontier really migrated: fewer frontier tasks on the throttled
    # device than the locked-in assignment kept there
    old, new = j.plan.schedule.spec.assign, r.spec.assign
    idx = {t.name: k for k, t in enumerate(r.spec.tasks)}
    moved = [n for n in r.spliced if new[idx[n]] != old[idx[n]]]
    assert moved, "re-plan spliced but moved nothing"
    assert j.span < locked[0].span - 1e-12
    assert locked[0].span / j.span >= 1.10
    # the protocol stayed sound across the splice point
    assert verify_stream_invariants(jobs) == []
    assert verify_graph_dependencies(j.final_spec, j.measured) == []
    # frozen tasks kept their measured events untouched
    frozen_events = [e for e in j.measured.events if e.task in set(r.frozen)]
    assert frozen_events
    assert min(e.start for e in frozen_events) < r.at


def test_virtual_replan_feeds_observations_at_detection_time():
    rt, jobs = _run_virtual(True, [_block()])
    j = jobs[0]
    assert j.replans
    # the straggler's measurement reached the scheduler: later models are
    # re-fitted (epoch bumped) and the re-solved spec uses them
    assert rt.dyn.epoch > 0
    assert rt.stats()["replans"] == 1
    # re-fit visible in the re-plan's spec: throttled xpu model got slower
    xpu_old = j.plan.schedule.spec.devices[2]
    xpu_new = j.replans[0].spec.devices[2]
    assert xpu_new.compute(1e9) > 1.5 * xpu_old.compute(1e9)


def test_virtual_replan_noop_without_straggler():
    dom = TaskGraphDomain(_devices(), bus="serialized", dynamic=True)
    with CoExecutionRuntime(dom, executor="virtual",
                            truth=truth_from_profiles(_devices()),
                            feedback=True, max_inflight=1,
                            replan=True) as rt:
        jobs = rt.run_stream([_block()] * 3)
    assert all(not j.replans for j in jobs)
    assert verify_stream_invariants(jobs) == []


def test_virtual_replan_only_hits_stale_planned_jobs():
    """Jobs planned AFTER the re-fit see the throttle in their models —
    no straggler slack, no re-plan; only the job caught in flight when the
    throttle appears is spliced."""
    rt, jobs = _run_virtual(True, [_block()] * 4)
    assert len(jobs[0].replans) == 1
    # once the models track the throttle, later jobs are planned correctly
    assert all(not j.replans for j in jobs[2:])
    assert verify_stream_invariants(jobs) == []
    for j in jobs:
        assert verify_graph_dependencies(j.final_spec, j.measured) == []


def test_ancestor_closed_freeze_freezes_pending_parent_of_started_child():
    """Regression: a device worker marks a stage group 'started' the moment
    it dequeues it — possibly while a cross-device parent is still pending
    (the group blocks in its dependency wait).  The monitor's freeze must
    close over ancestors, or the progress snapshot is not ancestor-closed
    and the re-plan would crash the job instead of rescuing it."""
    from repro.core.bus import BusTopology, GraphTimelineSpec, TaskSpec
    from repro.core.runtime import _ancestor_closed_freeze
    devs = _devices()
    spec = GraphTimelineSpec(
        devices=tuple(devs),
        tasks=(TaskSpec("a", 1e9, out_bytes=1e6), TaskSpec("b", 1e9),
               TaskSpec("c", 1e9)),
        edges=((0, 1),), assign=(2, 1, 0), order=(0, 1, 2),
        topology=BusTopology.serialized(devs))
    # 'b' was dequeued (started) while its parent 'a' is still pending
    frozen, frontier = _ancestor_closed_freeze(spec, ["b"])
    assert frozen == ["a", "b"]
    assert frontier == ["c"]
    # and the closed set passes the workload-level validation
    g = TaskGraph(nodes=(TaskNode("a", 1e9, out_bytes=1e6),
                         TaskNode("b", 1e9), TaskNode("c", 1e9)),
                  edges=(("a", "b"),))
    sub, _ = g.frontier_subgraph(frozen)
    assert [t.name for t in sub.nodes] == ["c"]


# ------------------------------------------------ threaded splice -----------


def test_threaded_replan_splices_live_job_with_clean_invariants():
    """Acceptance (threaded half): the StreamCore revokes the frontier's
    not-yet-granted tickets and re-issues them on the re-planned devices —
    dependency and per-link serialization invariants hold across the
    splice point, and the measured grant order matches the spliced plan."""
    g = _block()
    spans = {}
    for replan in (False, True):
        dom = TaskGraphDomain(_devices(), bus="serialized", dynamic=True)
        with CoExecutionRuntime(dom, executor="threads", truth=_truth(),
                                feedback=True, max_inflight=1,
                                time_scale=10.0, replan=replan,
                                straggler_threshold=1.3) as rt:
            jobs = rt.run_stream([g], timeout=120)
            j = jobs[0]
            assert j.error is None
            spans[replan] = j.span
            assert verify_stream_invariants(jobs) == []
            assert verify_graph_dependencies(j.final_spec, j.measured) == []
            if replan:
                assert len(j.replans) == 1
                assert j.replans[0].spliced
                assert rt.pump.observations > 0
    # the spliced run beats the locked-in one by the acceptance margin
    # (wall clock; the model-level gap is ~2x at this throttle, so 1.10x
    # leaves generous headroom for scheduler noise)
    assert spans[False] / spans[True] >= 1.10


def test_virtual_copy_straggler_trips_link_monitor():
    """Satellite of DESIGN.md SS11/SS13: a device whose host<->device copies
    blow past their planned link occupancy trips the *copy*-slack monitor
    (reason="copy-straggler") and splices the frontier, with the same
    invariants as the compute path."""
    truth = truth_from_profiles(
        _devices(),
        copy_slowdown=lambda uid, name: 10.0 if name == "xpu" else 1.0)
    dom = TaskGraphDomain(_devices(), bus="serialized", dynamic=True)
    with CoExecutionRuntime(dom, executor="virtual", truth=truth,
                            feedback=True, max_inflight=1, replan=True,
                            straggler_threshold=1.3) as rt:
        jobs = rt.run_stream([_block()])
    j = jobs[0]
    assert j.error is None
    assert j.replans, "copy throttle never tripped the monitor"
    assert j.replans[0].reason == "copy-straggler"
    assert j.replans[0].spliced
    assert verify_stream_invariants(jobs) == []
    assert verify_graph_dependencies(j.final_spec, j.measured) == []


def test_threaded_copy_straggler_trips_link_monitor():
    """Threaded half: the StreamCore's measured copy events are checked
    against the planned per-stage link occupancy, and a slow link splices
    through the same reissue machinery as a slow device."""
    truth = truth_from_profiles(
        _devices(),
        copy_slowdown=lambda uid, name: 10.0 if name == "xpu" else 1.0)
    dom = TaskGraphDomain(_devices(), bus="serialized", dynamic=True)
    with CoExecutionRuntime(dom, executor="threads", truth=truth,
                            feedback=True, max_inflight=1, time_scale=10.0,
                            replan=True, straggler_threshold=1.3) as rt:
        jobs = rt.run_stream([_block()], timeout=120)
        j = jobs[0]
        assert j.error is None
        assert verify_stream_invariants(jobs) == []
        assert verify_graph_dependencies(j.final_spec, j.measured) == []
        assert j.replans
        assert any(r.reason == "copy-straggler" for r in j.replans)


def test_threaded_replan_keeps_stream_correct_across_following_jobs():
    """A splice must not wedge the persistent buses: jobs dispatched after
    the re-planned one still run, and the whole stream passes the
    cross-plan invariants."""
    dom = TaskGraphDomain(_devices(), bus="serialized", dynamic=True)
    with CoExecutionRuntime(dom, executor="threads", truth=_truth(),
                            feedback=True, max_inflight=2, time_scale=5.0,
                            replan=True, straggler_threshold=1.3) as rt:
        jobs = rt.run_stream([_block()] * 3, timeout=120)
        assert all(j.error is None for j in jobs)
        assert sum(len(j.replans) for j in jobs) >= 1
        assert verify_stream_invariants(jobs) == []
        for j in jobs:
            assert verify_graph_dependencies(j.final_spec, j.measured) == []


def test_streamcore_reissue_drops_started_tasks_from_splice():
    """A task that starts between the monitor's snapshot and the reissue
    call keeps its original placement — the replacement is discarded."""
    from repro.core import DeviceTask, StreamCore
    core = StreamCore()
    try:
        release = threading.Event()
        planned = {"pcie": [("a", "gpu", "copy_in"), ("b", "gpu", "copy_in"),
                            ("c", "cpu", "copy_in")]}
        tasks = [
            DeviceTask("gpu", copy_in=lambda: None,
                       compute=lambda: release.wait(10), copy_out=None,
                       task="a"),
            DeviceTask("gpu", copy_in=lambda: None, compute=lambda: None,
                       copy_out=None, task="b", deps=("a",)),
            DeviceTask("cpu", copy_in=lambda: None, compute=lambda: None,
                       copy_out=None, task="c"),
        ]
        h = core.dispatch(tasks, planned)
        time.sleep(0.05)   # 'a' is running, 'b' queued behind it; 'c' races
        pend = core.pending_tasks(h.job)
        assert "b" in pend and "a" not in pend
        # re-issue b (and try to re-issue the running a — must be dropped)
        repl = [
            DeviceTask("cpu", copy_in=lambda: None, compute=lambda: None,
                       copy_out=None, task="a"),
            DeviceTask("cpu", copy_in=lambda: None, compute=lambda: None,
                       copy_out=None, task="b", deps=("a",)),
        ]
        spliced = core.reissue(h, repl, {"pcie": [("a", "cpu", "copy_in"),
                                                  ("b", "cpu", "copy_in")]})
        assert "b" in spliced and "a" not in spliced
        release.set()
        tl = h.wait(30)
        assert not h.errors
        # b ran on its NEW device, after a completed on the old one
        comp = {e.task: e for e in tl.events if e.kind == "compute"}
        assert comp["b"].device == "cpu"
        assert comp["a"].device == "gpu"
        assert comp["b"].start >= comp["a"].end - 1e-9
    finally:
        core.shutdown()


# ---------------------------------------------- bugfix regressions ----------


def test_ticketbus_release_out_of_order_is_runtimeerror_not_assert():
    """`python -O` strips asserts: an out-of-order release must raise an
    explicit RuntimeError, never silently advance the grant head."""
    bus = TicketBus([("a", "copy_in"), ("b", "copy_in")])
    bus.acquire(("a", "copy_in"))
    with pytest.raises(RuntimeError, match="out-of-order release"):
        bus.release(("b", "copy_in"))
    # the head is undisturbed: the correct release still works
    bus.release(("a", "copy_in"))
    bus.acquire(("b", "copy_in"))
    bus.release(("b", "copy_in"))


def test_ticketbus_acquire_tolerates_concurrent_extend():
    """A dispatch→extend racing with a worker's acquire must not raise:
    acquire waits (bounded) for the ticket to be appended."""
    bus = TicketBus()
    t = ("a", "copy_in")

    def late_extend():
        time.sleep(0.05)
        bus.extend([t])

    thr = threading.Thread(target=late_extend)
    thr.start()
    bus.acquire(t)          # must block for the extend, not raise
    bus.release(t)
    thr.join()
    # a ticket that never arrives still raises (bounded wait, not a hang)
    with pytest.raises(ValueError, match="not in bus schedule"):
        bus.acquire(("never", "copy_in"), append_timeout=0.05)


def test_stats_percentiles_use_nearest_rank():
    """p50 of two samples is the smaller one (ceil(q*n)-1), not the max."""
    dom = GemmDomain([_dev("a", 1.0), _dev("b", 2.0, bw=16e9)],
                     bus="serialized")
    with CoExecutionRuntime(dom, executor="virtual", feedback=False,
                            carry_clocks=False, max_inflight=1) as rt:
        rt.run_stream([GemmWorkload(1024, 1024, 1024),
                       GemmWorkload(4096, 4096, 4096)])
        stats = rt.stats()
        spans = sorted(j.span for j in rt.jobs)
    assert spans[0] < spans[1]
    assert stats["p50_job_span_s"] == pytest.approx(spans[0])
    assert stats["p95_job_span_s"] == pytest.approx(spans[1])


def test_benchmark_regression_guard_flags_drift(tmp_path, monkeypatch):
    """run.py's guard: makespans may not rise, speedups may not fall,
    beyond tolerance; thread* (wall-clock) paths are exempt."""
    run = pytest.importorskip("benchmarks.run")
    monkeypatch.chdir(tmp_path)
    import json
    base = {"machines": {"m": {
        "coexec": {"coexec_makespan_s": 1.0, "speedup_vs_best_single": 1.5},
        "straggler": {"threads": {"replan_speedup": 2.0},
                      "virtual": {"replan_speedup": 1.5}}}}}
    (tmp_path / "BENCH_graph.json").write_text(json.dumps(base))
    baselines = run.load_baselines()
    metrics = baselines["BENCH_graph.json"]
    assert "/machines/m/straggler/virtual/replan_speedup" in metrics
    assert not any("threads" in k for k in metrics)   # wall clock exempt
    bad = {"machines": {"m": {
        "coexec": {"coexec_makespan_s": 1.2, "speedup_vs_best_single": 1.2},
        "straggler": {"threads": {"replan_speedup": 0.1},
                      "virtual": {"replan_speedup": 1.5}}}}}
    (tmp_path / "BENCH_graph.json").write_text(json.dumps(bad))
    problems = run.check_regressions(baselines, 0.10)
    assert len(problems) == 2
    assert any("rose above" in p for p in problems)
    assert any("fell below" in p for p in problems)
    ok = {"machines": {"m": {
        "coexec": {"coexec_makespan_s": 1.05,
                   "speedup_vs_best_single": 1.45},
        "straggler": {"virtual": {"replan_speedup": 1.4}}}}}
    (tmp_path / "BENCH_graph.json").write_text(json.dumps(ok))
    assert run.check_regressions(baselines, 0.10) == []


def test_verify_flags_any_copyout_before_compute_not_just_first():
    """Regression: zip(comps[-1:], outs) only checked the FIRST output
    event; a later out-event starting before compute end slipped through."""
    events = [
        BusEvent("gpu", "copy_in", 0.0, 0.1, "pcie", 0, "t"),
        BusEvent("gpu", "compute", 0.1, 1.0, None, 0, "t"),
        BusEvent("gpu", "copy_out", 1.0, 1.2, "pcie", 0, "t"),
        # chunk 1 out-event starts BEFORE compute ended — must be flagged
        BusEvent("gpu", "copy_out", 0.5, 0.8, "pcie", 1, "t"),
    ]
    job = StreamJob(uid=0, workload=None,
                    measured=Timeline(sorted(events,
                                             key=lambda e: e.start)))
    problems = verify_stream_invariants([job])
    assert any("copy_out before compute ended" in p for p in problems)
