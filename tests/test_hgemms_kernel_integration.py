"""hgemms partitions executed through the Pallas matmul kernel (interpret
mode) — the full paper pipeline down to the TPU compute unit."""
import numpy as np
import pytest

from repro.core import HGemms, paper_mach1
from repro.kernels.matmul import matmul_pallas


def test_poas_partitions_via_pallas_kernel():
    import jax.numpy as jnp
    hg = HGemms(paper_mach1())
    m, n, k = 384, 256, 192
    plan = hg.plan(m, n, k)
    rng = np.random.default_rng(0)
    a = rng.standard_normal((m, k)).astype(np.float32)
    b = rng.standard_normal((k, n)).astype(np.float32)
    c = np.zeros((m, n), np.float32)
    for asg in plan.adapted.assignments:
        if asg.m == 0:
            continue
        rows = slice(asg.row0, asg.row0 + asg.m)
        c[rows] = np.asarray(matmul_pallas(
            jnp.asarray(a[rows]), jnp.asarray(b),
            block_m=64, block_n=128, block_k=64, interpret=True))
    np.testing.assert_allclose(c, a @ b, rtol=1e-4, atol=1e-3)


def test_subproducts_cover_each_partition():
    """Adapt-phase sub-products tile each device slice exactly."""
    hg = HGemms(paper_mach1())
    plan = hg.plan(4096, 1024, 2048)
    for asg in plan.adapted.assignments:
        if asg.m == 0 or not asg.sub_products:
            continue
        area = sum(t.m * t.k for t in asg.sub_products)
        assert area == asg.m * plan.adapted.k
        # no tile exceeds the slice bounds
        for t in asg.sub_products:
            assert 0 <= t.row0 and t.row0 + t.m <= asg.m
            assert 0 <= t.k0 and t.k0 + t.k <= plan.adapted.k
