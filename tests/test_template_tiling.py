"""Template-tiled hierarchical solves (DESIGN.md §15).

Detection: builder blocks and the generic fallback both partition
repetitive DAGs; canonical signatures never merge blocks that differ in
any one node's costs (the collision property).  Solving: the stitched
placement's reported finish times are byte-identical to the engine's
from-scratch simulation of the same assignment (ground truth), the
makespan never loses to the best all-one-device schedule (the floor
contract), the template cache shares representative placements across
stacks of different depths, and the domain auto-selects the tiled path
exactly when the detector finds repeated structure.
"""
import dataclasses

import pytest

from repro.core import (BusTopology, CopyModel, DeviceProfile,
                        LinearTimeModel, NO_COPY, TaskGraph,
                        TaskGraphDomain, TaskNode, TemplatePlanCache,
                        detect_templates, graph_finish_times,
                        solve_hierarchical, solve_list_schedule, ssm_stack,
                        transformer_block, transformer_stack)


def _devs():
    return [
        DeviceProfile("cpu", "cpu", LinearTimeModel(a=1 / 5e12, b=1e-4),
                      NO_COPY),
        DeviceProfile("gpu0", "gpu", LinearTimeModel(a=1 / 60e12, b=5e-5),
                      CopyModel(16e9, dtype_size=4)),
        DeviceProfile("gpu1", "gpu", LinearTimeModel(a=1 / 25e12, b=8e-5),
                      CopyModel(8e9, dtype_size=4)),
    ]


def _chain_of_blocks(repeats: int, *, perturb: int | None = None,
                     with_blocks: bool = True) -> TaskGraph:
    """``repeats`` copies of a 4-node diamond block chained tail→head;
    ``perturb`` bumps one node's ops in that block (collision fixture)."""
    nodes, edges, blocks = [], [], []
    for r in range(repeats):
        ops = [4e11, 2e11, 3e11, 1e11]
        if r == perturb:
            ops[1] *= 1.5
        names = [f"b{r}.n{k}" for k in range(4)]
        nodes += [TaskNode(names[0], ops=ops[0], in_bytes=1e6,
                           out_bytes=2e6),
                  TaskNode(names[1], ops=ops[1], out_bytes=1e6),
                  TaskNode(names[2], ops=ops[2], out_bytes=1e6),
                  TaskNode(names[3], ops=ops[3], out_bytes=2e6)]
        edges += [(names[0], names[1]), (names[0], names[2]),
                  (names[1], names[3]), (names[2], names[3])]
        if r > 0:
            edges.append((f"b{r-1}.n3", names[0]))
        blocks.append(tuple(names))
    return TaskGraph(nodes=tuple(nodes), edges=tuple(edges),
                     blocks=tuple(blocks) if with_blocks else ())


# -- detection ---------------------------------------------------------------


def test_builder_stack_emits_block_partition():
    g = transformer_stack(layers=6, microbatches=2, groups=4)
    assert len(g.blocks) == 12
    part = g.template_partition()
    assert part is not None
    assert len(part.instances) == 12
    # first / middle / last layers differ in boundary arity, nothing else
    assert part.n_templates == 3
    assert sorted(part.repeats().values()) == [2, 2, 8]
    covered = sorted(i for inst in part.instances for i in inst)
    assert covered == list(range(len(g.nodes)))


def test_generic_fallback_detects_without_blocks():
    g = _chain_of_blocks(8, with_blocks=False)
    assert g.blocks == ()
    part = detect_templates(g, min_repeats=4)
    assert part is not None
    assert len(part.instances) == 8
    assert max(part.repeats().values()) >= 4
    covered = sorted(i for inst in part.instances for i in inst)
    assert covered == list(range(len(g.nodes)))


def test_template_collision_one_node_cost_differs():
    """Blocks differing only in ONE node's ops must NOT merge."""
    clean = detect_templates(_chain_of_blocks(8), min_repeats=2)
    bumped = detect_templates(_chain_of_blocks(8, perturb=3), min_repeats=2)
    assert clean is not None and bumped is not None
    assert bumped.n_templates == clean.n_templates + 1
    # the perturbed instance sits alone in its template
    t3 = bumped.template_of[3]
    assert bumped.repeats()[t3] == 1
    assert all(bumped.template_of[a] != t3 for a in range(8) if a != 3)


def test_template_collision_bytes_differ():
    g = _chain_of_blocks(8)
    node = g.nodes[13]  # b3.n1
    bumped = TaskGraph(
        nodes=g.nodes[:13]
        + (dataclasses.replace(node, out_bytes=node.out_bytes + 64.0),)
        + g.nodes[14:],
        edges=g.edges, blocks=g.blocks)
    part = detect_templates(bumped, min_repeats=2)
    clean = detect_templates(g, min_repeats=2)
    assert part is not None and clean is not None
    assert part.n_templates > clean.n_templates


def test_detection_declines_irregular_graphs():
    assert detect_templates(transformer_block()) is None      # one block
    assert detect_templates(_chain_of_blocks(4)) is None      # < min_repeats
    assert _chain_of_blocks(4).template_partition(min_repeats=2) is not None


def test_signatures_are_name_blind():
    a = detect_templates(_chain_of_blocks(8), min_repeats=2)
    g = transformer_stack(layers=1, microbatches=8, groups=2, name="x")
    h = transformer_stack(layers=1, microbatches=8, groups=2, name="y")
    pa = detect_templates(g, min_repeats=2)
    pb = detect_templates(h, min_repeats=2)
    assert pa is not None and pb is not None
    assert pa.signatures == pb.signatures
    assert a is not None and a.signatures != pa.signatures


# -- memoization (the PlanCache hot path) ------------------------------------


def test_cost_signature_memoized_and_blocks_excluded():
    g = transformer_stack(layers=2, microbatches=2)
    assert g.cost_signature() is g.cost_signature()
    assert g.task_specs() is g.task_specs()
    assert g.edge_indices() is g.edge_indices()
    bare = TaskGraph(nodes=g.nodes, edges=g.edges)   # blocks stripped
    assert bare.cost_signature() == g.cost_signature()


# -- the solve: exactness, floor, cache sharing ------------------------------


def test_hierarchical_matches_engine_ground_truth():
    devs = _devs()
    g = transformer_stack(layers=6, microbatches=2, groups=4)
    part = g.template_partition()
    r = solve_hierarchical(devs, g.task_specs(), g.edge_indices(),
                           partition=part, template_cache=TemplatePlanCache())
    truth = graph_finish_times(devs, g.task_specs(), g.edge_indices(),
                               r.assign, topology=BusTopology.from_spec(
                                   "serialized", devs), order=r.order)
    assert r.task_finish == truth
    assert r.makespan == max(truth)


def test_hierarchical_never_loses_to_one_device():
    devs = _devs()
    g = _chain_of_blocks(12)   # a pure chain: single device is optimal-ish
    part = g.template_partition(min_repeats=2)
    r = solve_hierarchical(devs, g.task_specs(), g.edge_indices(),
                           partition=part, template_cache=TemplatePlanCache())
    topo = BusTopology.from_spec("serialized", devs)
    floor = min(
        max(graph_finish_times(devs, g.task_specs(), g.edge_indices(),
                               [j] * len(g), topology=topo))
        for j in range(len(devs)))
    assert r.makespan <= floor + 1e-12


def test_hierarchical_within_bound_of_flat():
    devs = _devs()
    g = transformer_stack("stablelm-12b", layers=4, microbatches=2, groups=4)
    flat = solve_list_schedule(devs, g.task_specs(), g.edge_indices(),
                               refine=False)
    hier = solve_hierarchical(devs, g.task_specs(), g.edge_indices(),
                              partition=g.template_partition(),
                              template_cache=TemplatePlanCache())
    assert hier.makespan <= 1.10 * flat.makespan


def test_template_cache_shared_across_depths():
    devs = _devs()
    cache = TemplatePlanCache()
    shallow = transformer_stack(layers=6, microbatches=1, groups=4)
    deep = transformer_stack(layers=20, microbatches=1, groups=4)
    solve_hierarchical(devs, shallow.task_specs(), shallow.edge_indices(),
                       partition=shallow.template_partition(),
                       template_cache=cache)
    misses = cache.misses
    assert misses == 3 and cache.hits == 0
    # different depth, same block geometry: every template is a cache hit
    solve_hierarchical(devs, deep.task_specs(), deep.edge_indices(),
                       partition=deep.template_partition(),
                       template_cache=cache)
    assert cache.misses == misses
    assert cache.hits == 3


def test_template_cache_lru_and_clear():
    cache = TemplatePlanCache(capacity=2)
    cache.put("a", (0,))
    cache.put("b", (1,))
    cache.put("c", (2,))
    assert cache.get("a") is None
    assert cache.get("c") == (2,)
    cache.clear()
    assert len(cache) == 0 and cache.hits == 0


# -- runtime wiring ----------------------------------------------------------


def test_domain_auto_selects_hierarchical():
    devs = _devs()
    g = transformer_stack(layers=6, microbatches=2, groups=4)
    dom = TaskGraphDomain(devs)
    hier = dom.optimize(devs, g)
    ref = solve_hierarchical(devs, g.task_specs(), g.edge_indices(),
                             partition=g.template_partition())
    assert hier.makespan == ref.makespan and hier.assign == ref.assign
    flat = TaskGraphDomain(devs, hierarchical=False).optimize(devs, g)
    assert flat.iterations != hier.iterations   # different solve paths ran
    # irregular graph: auto falls back to the flat path
    blk = transformer_block()
    assert blk.template_partition() is None
    a = dom.optimize(devs, blk)
    b = TaskGraphDomain(devs, hierarchical=False).optimize(devs, blk)
    assert a.makespan == b.makespan and a.assign == b.assign


def test_domain_end_to_end_schedule_valid():
    from repro.core.graph import verify_graph_dependencies
    devs = _devs()
    g = ssm_stack(layers=4, microbatches=2, seq=2048, chunk=512)
    dom = TaskGraphDomain(devs)
    assert g.template_partition(min_repeats=2) is not None
    opt = dom.optimize(devs, g)
    plan = dom.adapt(devs, opt, g)
    sched = dom.schedule(devs, plan, g)
    assert verify_graph_dependencies(g, sched.timeline) == []
