"""Pallas flash-attention kernel vs oracle (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ref import flash_attention_ref


def _qkv(key, B, Sq, Skv, H, KH, D, dtype):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, Sq, H, D), jnp.float32).astype(dtype)
    k = jax.random.normal(kk, (B, Skv, KH, D), jnp.float32).astype(dtype)
    v = jax.random.normal(kv, (B, Skv, KH, D), jnp.float32).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("B,S,H,KH,D,bq,bk", [
    (1, 128, 4, 4, 64, 64, 64),
    pytest.param(2, 256, 8, 2, 64, 128, 64,      # GQA 4:1
                 marks=pytest.mark.slow),
    (1, 96, 4, 1, 128, 32, 32),      # MQA, ragged blocks
    pytest.param(2, 128, 2, 2, 32, 128, 128,     # single block pair
                 marks=pytest.mark.slow),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_causal_allclose(B, S, H, KH, D, bq, bk, dtype):
    q, k, v = _qkv(jax.random.PRNGKey(S + H), B, S, S, H, KH, D, dtype)
    out = flash_attention_pallas(q, k, v, causal=True, block_q=bq,
                                 block_k=bk, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [
    16, pytest.param(64, marks=pytest.mark.slow)])
def test_flash_window(window):
    q, k, v = _qkv(jax.random.PRNGKey(0), 1, 128, 128, 4, 2, 32, jnp.float32)
    out = flash_attention_pallas(q, k, v, causal=True, window=window,
                                 block_q=32, block_k=32, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_flash_noncausal():
    q, k, v = _qkv(jax.random.PRNGKey(1), 2, 64, 64, 4, 4, 32, jnp.float32)
    out = flash_attention_pallas(q, k, v, causal=False, block_q=32,
                                 block_k=32, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_flash_matches_model_oracle():
    """The model-stack chunked flash and the Pallas kernel agree."""
    from repro.models.layers import flash_attention as model_flash
    q, k, v = _qkv(jax.random.PRNGKey(2), 2, 128, 128, 8, 2, 64, jnp.float32)
    a = flash_attention_pallas(q, k, v, causal=True, block_q=64, block_k=64,
                               interpret=True)
    b = model_flash(q, k, v, causal=True, kv_chunk=64)
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)
